"""Version-compat shims for the installed jax."""
from __future__ import annotations

import inspect

try:
    from jax import shard_map as shard_map
except ImportError:  # older jax
    from jax.experimental.shard_map import shard_map as shard_map

# jax renamed shard_map's replication-check kwarg (check_rep -> check_vma);
# SHARD_MAP_KW holds whichever spelling this jax version accepts.
_params = inspect.signature(shard_map).parameters
if "check_vma" in _params:
    SHARD_MAP_KW = {"check_vma": False}
elif "check_rep" in _params:
    SHARD_MAP_KW = {"check_rep": False}
else:  # pragma: no cover
    SHARD_MAP_KW = {}
