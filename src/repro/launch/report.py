"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from artifacts.

    PYTHONPATH=src python -m repro.launch.report --art-dir artifacts/dryrun
"""
from __future__ import annotations

import argparse

from .roofline import load_artifacts, terms


def dryrun_table(arts, mesh):
    rows = ["| arch | shape | kind | devices | compile_s | flops/dev "
            "| bytes/dev | coll B/dev | mem/dev GiB |",
            "|---|---|---|---|---|---|---|---|---|"]
    for a in sorted(arts, key=lambda a: (a["arch"], a["shape"])):
        if (a["mesh"] != mesh or a.get("q_overrides") or a.get("a_overrides")
                or a.get("preset", "full8") != "full8"):
            continue
        mem = a["mem_analysis"].get("peak_bytes_est", 0) / 2 ** 30
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['kind']} | {a['devices']} "
            f"| {a['compile_s']:.0f} | {a['flops_per_device']:.2e} "
            f"| {a['bytes_per_device']:.2e} "
            f"| {a['collective_bytes_per_device']:.2e} | {mem:.2f} |")
    return "\n".join(rows)


def roofline_table(arts, mesh="single"):
    rows = ["| arch | shape | compute_s | compute_s(int8) | memory_s "
            "| collective_s | dominant | frac(bf16) | useful | next lever |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for a in sorted(arts, key=lambda a: (a["arch"], a["shape"])):
        if (a["mesh"] != mesh or a.get("q_overrides") or a.get("a_overrides")
                or a.get("preset", "full8") != "full8"):
            continue
        t = terms(a)
        lever = {
            "memory": "fuse quantize chains / 16-bit carriers / fewer "
                      "elementwise passes",
            "collective": "int8 weight gathers + bf16 TP boundaries",
            "compute": "drop remat recompute / int8 MXU (2x peak)",
        }[t["dominant"]]
        rows.append(
            f"| {a['arch']} | {a['shape']} | {t['compute_s']:.2e} "
            f"| {t['compute_int8_s']:.2e} | {t['memory_s']:.2e} "
            f"| {t['collective_s']:.2e} | {t['dominant']} "
            f"| {t['roofline_fraction']:.1%} | {t['useful_ratio']:.2f} "
            f"| {lever} |")
    return "\n".join(rows)


def variant_table(arts, arch, shape, mesh="single"):
    """Baseline + tagged variants for one hillclimbed cell."""
    rows = ["| variant | compute_s | memory_s | collective_s | dominant "
            "| mem/dev GiB |",
            "|---|---|---|---|---|---|"]
    for a in arts:
        if (a["arch"], a["shape"], a["mesh"]) != (arch, shape, mesh):
            continue
        t = terms(a)
        tag = (",".join(f"{k}={v}" for k, v in
                        {**a.get("q_overrides", {}),
                         **a.get("a_overrides", {})}.items()) or "baseline")
        mem = a["mem_analysis"].get("peak_bytes_est", 0) / 2 ** 30
        rows.append(f"| {tag} | {t['compute_s']:.3e} | {t['memory_s']:.3e} "
                    f"| {t['collective_s']:.3e} | {t['dominant']} "
                    f"| {mem:.2f} |")
    return "\n".join(rows)


def kernel_table() -> str:
    """Active kernel dispatch (kernel/oracle per op, fused/unfused per
    numeric mode) — what the examples' startup banners print, as a table."""
    from repro.core.qconfig import preset
    from repro.kernels import autotune
    from repro.kernels.ops import dispatch_report

    rep = dispatch_report()
    rows = [f"backend: {rep['backend']}", "",
            "| op | route |", "|---|---|"]
    rows += [f"| {op} | {route} |" for op, route in rep["ops"].items()]
    rows += ["", "| mode | bwd/ubn path |", "|---|---|"]
    for mode in ("sim", "native"):
        r = dispatch_report(preset("full8", mode))
        rows.append(f"| {mode} | {'fused' if r['fused'] else 'unfused'} |")
    tuned = autotune.report_rows()
    wc = rep["wire_codec"]
    rows += ["", f"wire codec default: {wc['default']} — {wc['why']}"]
    rows += ["", f"autotune cache: {rep['autotune']['entries']} entries "
                 f"({rep['autotune']['dir']})"]
    if tuned:
        rows += ["", "| op | tuned tiles | us | sig |", "|---|---|---|---|"]
        rows += [f"| {op} | {tiles} | {us:.1f} | `{sig}` |"
                 for op, sig, tiles, us in tuned]
    return "\n".join(rows)


def sharding_table(arch: str = "granite-3-8b", tp: int = 2) -> str:
    """The DP×TP sharding plan (DESIGN.md §9) for one arch: which parameter
    axes live on the model axis and what crosses devices during a step."""
    from repro.configs import get as get_arch
    from repro.core.qconfig import preset
    from repro.launch.shard import tp_param_specs
    from repro.models import build_model

    acfg = get_arch(arch).reduced()
    qcfg = preset("full8", "native")
    model = build_model(acfg, qcfg, tp_size=tp)
    import jax
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = tp_param_specs(model, params)
    rows = [f"arch: {arch} (reduced)  tp={tp}", "",
            "| param | shape | spec |", "|---|---|---|"]
    for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree_util.tree_leaves_with_path(specs)):
        rows.append(f"| {jax.tree_util.keystr(path)} | {leaf.shape} "
                    f"| {spec} |")
    rows += ["",
             "| wire | payload | when |", "|---|---|---|",
             "| grad sync (data axis) | int16 ring + scalar f32 pmax "
             "| every step — DP-invariant by construction |",
             "| TP boundary (model axis) | f32 activation/error psum "
             "| tp > 1, Megatron enter/exit pairs |",
             "| ZeRO-1 param gather | int32 on the 2^(1-k_WU) grid "
             "| opt_shard=zero1 |"]
    return "\n".join(rows)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--art-dir", default="artifacts/dryrun")
    p.add_argument("--section", default="all",
                   choices=["all", "dryrun", "roofline", "kernels",
                            "sharding"])
    args = p.parse_args(argv)
    if args.section == "kernels":
        print("### Kernel dispatch\n")
        print(kernel_table())
        return
    if args.section == "sharding":
        print("### Sharding contract (DESIGN.md §9)\n")
        print(sharding_table())
        return
    arts = load_artifacts(args.art_dir)
    if args.section in ("all", "dryrun"):
        print("### Dry-run — single pod (16x16 = 256 chips)\n")
        print(dryrun_table(arts, "single"))
        print("\n### Dry-run — multi-pod (2x16x16 = 512 chips)\n")
        print(dryrun_table(arts, "multi"))
    if args.section in ("all", "roofline"):
        print("\n### Roofline (single pod)\n")
        print(roofline_table(arts, "single"))
    if args.section == "all":
        print("\n### Kernel dispatch\n")
        print(kernel_table())


if __name__ == "__main__":
    main()
