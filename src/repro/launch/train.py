"""Train-step / serve-step builders + the CLI training driver.

`make_train_step` closes the full WAGEUBN loop: quantized forward, quantized
backward (inside the model's custom vjps), CQ/Q gradient quantization +
quantized Momentum + fixed-point update (inside the optimizer).  Stochastic
rounding keys derive from the step counter => bit-exact restart.
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import get as get_arch
from repro.core.qconfig import preset
from repro.models import build_model
from repro.optim import (dr_bits_schedule, fixed_point_lr, init_momentum,
                         momentum_update)

SEED = 17


def make_train_step(model, qcfg, labels_tree, lr=0.05, mom=0.75,
                    dr_bits: int = 8, n_micro: int = 1):
    """n_micro > 1 accumulates gradients over microbatches (lax.scan) —
    activation memory scales down by n_micro while the numeric result is
    the mean-of-microbatch gradients (the paper's G of the full batch)."""
    lrq = fixed_point_lr(lr, qcfg)

    def train_step(params, opt_state, batch, step_idx):
        key = jax.random.fold_in(jax.random.PRNGKey(SEED), step_idx)
        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batch, key)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)
            if getattr(model, "mesh", None) is not None:
                # anchor the microbatch layout: leading dim unsharded, batch
                # over dp (3-axis meshes mis-partition the reshape+slice)
                from jax.sharding import NamedSharding, PartitionSpec as PS
                mb = jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(
                        x, NamedSharding(model.mesh,
                                         PS(None, model.dp,
                                            *((None,) * (x.ndim - 2))))),
                    mb)

            def acc_step(g_acc, b_i):
                (l, _), g = jax.value_and_grad(
                    model.loss, has_aux=True)(params, b_i, key)
                return jax.tree.map(jnp.add, g_acc, g), l

            g0 = jax.tree.map(jnp.zeros_like, params)
            grads, losses = jax.lax.scan(acc_step, g0, mb)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = jnp.mean(losses)
            metrics = {"loss": loss}
        params, opt_state = momentum_update(
            qcfg, params, grads, opt_state, labels_tree,
            jax.random.fold_in(key, 1), lrq, mom=mom, dr_bits=dr_bits)
        return params, opt_state, metrics

    return train_step


def make_serve_step(model):
    def serve_step(params, cache, tokens):
        return model.serve_step(params, cache, tokens)
    return serve_step


def make_paged_decode_step(model, sampler, k_scale=None, v_scale=None,
                           key=None):
    """Fused continuous-batching decode step for the serving engine.

    step(params, slots, k_pages, v_pages, table, tokens, ctr) ->
    (new_slots, new_k_pages, new_v_pages, tokens).  One trace serves every
    engine step: the lane batch is padded to max_lanes, pages/table drive
    the paged attention, and the sampler picks next tokens on device.
    k_scale/v_scale are the pool's per-layer pow2 scales and `key` the
    base PRNG key — all closed over so the engine can donate the page
    buffers, and so the per-step sampling key derives INSIDE the fused
    trace (fold_in of `ctr`, the engine's sampling counter) instead of as
    a separately dispatched host-side computation per step.
    For non-paged families (SSM) the page arrays pass through untouched.
    """
    paged = model.decode_state_spec()["kv_layers"] > 0
    key = jax.random.PRNGKey(0) if key is None else key

    def step(params, slots, k_pages, v_pages, table, tokens, ctr):
        view = None
        if paged:
            view = {"k_pages": k_pages, "v_pages": v_pages,
                    "k_scale": k_scale, "v_scale": v_scale, "table": table}
        logits, new_slots, new_pages = model.paged_decode_step(
            params, slots, view, tokens)
        toks = sampler(logits, jax.random.fold_in(key, ctr))
        if paged:
            return new_slots, new_pages["k_pages"], new_pages["v_pages"], \
                toks
        return new_slots, k_pages, v_pages, toks

    return step


def make_prefill(model, shape_name):
    from repro.configs.base import LM_SHAPES
    s, b, _ = LM_SHAPES[shape_name]
    a = model.a

    if a.family == "encdec":
        def prefill(params, frames):
            return model.prefill(params, frames, s // a.tgt_ratio)
        return prefill
    if a.family == "ssm":
        def prefill(params, tokens):
            return model.prefill(params, tokens)
        return prefill

    def prefill(params, tokens):
        return model.prefill(params, tokens, s)
    return prefill


# --------------------------------------------------------------------------
# CLI driver (CPU-scale smoke training with the full substrate engaged)
# --------------------------------------------------------------------------


def main(argv=None):
    p = argparse.ArgumentParser("repro.launch.train")
    p.add_argument("--arch", required=True)
    p.add_argument("--preset", default="full8",
                   choices=["full8", "e2_16", "fp32"])
    p.add_argument("--mode", default="sim", choices=["fp32", "sim", "native"])
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=0.05)
    p.add_argument("--reduced", action="store_true",
                   help="use the reduced smoke config (CPU scale)")
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--save-every", type=int, default=25)
    args = p.parse_args(argv)

    acfg = get_arch(args.arch)
    if args.reduced:
        acfg = acfg.reduced()
    qcfg = preset(args.preset, args.mode if args.preset != "fp32" else None)
    from repro.kernels.ops import dispatch_banner
    print(dispatch_banner(qcfg))
    model = build_model(acfg, qcfg)

    from repro.data import TokenTask
    task = TokenTask(vocab=acfg.vocab, seq_len=args.seq,
                     global_batch=args.batch)

    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt = init_momentum(params)
    labels_tree = model.labels(params)
    step_fn = jax.jit(make_train_step(model, qcfg, labels_tree, lr=args.lr),
                      donate_argnums=(0, 1))

    ckpt = None
    start = 0
    if args.ckpt_dir:
        from repro.checkpoint import CheckpointManager
        ckpt = CheckpointManager(args.ckpt_dir)
        if ckpt.latest_step() is not None:
            (params, opt), start, _ = ckpt.restore((params, opt))
            print(f"resumed from step {start}")

    t0 = time.time()
    for step in range(start, args.steps):
        batch = jax.tree.map(jnp.asarray, task.batch(step))
        params, opt, metrics = step_fn(params, opt, batch,
                                       jnp.int32(step))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"({time.time() - t0:.1f}s)")
        if ckpt and (step + 1) % args.save_every == 0:
            ckpt.save(step + 1, (params, opt))
    if ckpt:
        ckpt.wait()


if __name__ == "__main__":
    main()
