"""Train-step / serve-step builders + the CLI training driver.

`make_train_step` closes the full WAGEUBN loop: quantized forward, quantized
backward (inside the model's custom vjps), CQ/Q gradient quantization +
quantized Momentum + fixed-point update (inside the optimizer).  Stochastic
rounding keys derive from the step counter => bit-exact restart.

`make_sharded_train_step` is the DP×TP production step (DESIGN.md §9): one
full-manual shard_map over a ("data", "model") mesh whose gradient sync
rides the integer wire (runtime/compress.wire_sync_mean) instead of XLA's
f32 all-reduce.  The training algorithm is parameterized by `n_shards` (the
quantization granularity — how many virtual batch shards the step computes
independently before the exact integer reduction), NOT by the device count:
running the same (global batch, n_shards) on 1 device or on dp devices
produces bit-identical weights (tests/test_sharded_train.py).
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs import get as get_arch
from repro.core.qconfig import preset
from repro.models import build_model
from repro.optim import (apply_leaf_update, dr_bits_schedule, fixed_point_lr,
                         init_momentum, momentum_update, parse_boundaries,
                         quantize_grad_leaf)

SEED = 17


def make_train_step(model, qcfg, labels_tree, lr=0.05, mom=0.75,
                    dr_bits: int | None = None, n_micro: int = 1):
    """n_micro > 1 accumulates gradients over microbatches (lax.scan) —
    activation memory scales down by n_micro while the numeric result is
    the mean-of-microbatch gradients (the paper's G of the full batch).

    dr_bits: static CQ range width for this trace (None = qcfg.k_gw, the
    schedule base) — drivers with --dr-boundaries build one step fn per
    scheduled width."""
    lrq = fixed_point_lr(lr, qcfg)

    def train_step(params, opt_state, batch, step_idx):
        key = jax.random.fold_in(jax.random.PRNGKey(SEED), step_idx)
        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batch, key)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)
            if getattr(model, "mesh", None) is not None:
                # anchor the microbatch layout: leading dim unsharded, batch
                # over dp (3-axis meshes mis-partition the reshape+slice)
                from jax.sharding import NamedSharding, PartitionSpec as PS
                mb = jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(
                        x, NamedSharding(model.mesh,
                                         PS(None, model.dp,
                                            *((None,) * (x.ndim - 2))))),
                    mb)

            def acc_step(g_acc, b_i):
                (l, _), g = jax.value_and_grad(
                    model.loss, has_aux=True)(params, b_i, key)
                return jax.tree.map(jnp.add, g_acc, g), l

            g0 = jax.tree.map(jnp.zeros_like, params)
            grads, losses = jax.lax.scan(acc_step, g0, mb)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = jnp.mean(losses)
            metrics = {"loss": loss}
        params, opt_state = momentum_update(
            qcfg, params, grads, opt_state, labels_tree,
            jax.random.fold_in(key, 1), lrq, mom=mom, dr_bits=dr_bits)
        return params, opt_state, metrics

    return train_step


# --------------------------------------------------------------------------
# sharded DP×TP training step (shard_map + integer-wire gradient sync)
# --------------------------------------------------------------------------


def _pad_flat(x, n: int):
    flat = x.reshape(-1)
    return jnp.pad(flat, (0, n - flat.size)) if flat.size < n else flat


def _quant_update_leaf(cfg, lab) -> bool:
    """Leaves whose updated values land on the k_WU grid (Eq. 24) — these
    all-gather as integer payloads in the ZeRO-1 layout."""
    return cfg.quantize and lab != "exempt" and cfg.quant_u


def _zero1_update(cfg, params, grads, state, labels, key, lr, mom, dr_bits,
                  dp: int):
    """ZeRO-1 Momentum step inside the shard_map body.

    The accumulator lives as flat per-device chunks (launch/shard.py); the
    gradient is quantized on the FULL leaf (CQ amax + stochastic bits are
    leaf-global), then each device applies the elementwise update to its
    chunk only and the updated chunks all-gather back — as int32 payloads on
    the fixed 2^(1-k_WU) grid for quantized leaves (exact: the update
    already lands on that grid), fp32 for exempt leaves.  Bit-identical to
    the replicated `momentum_update` by the elementwise-chunking argument in
    optim/momentum.py.
    """
    from repro.optim import MomentumState

    r = lax.axis_index("data")
    leaves, treedef = jax.tree.flatten(params)
    glist = treedef.flatten_up_to(grads)
    alist = treedef.flatten_up_to(state.acc)
    llist = treedef.flatten_up_to(labels)
    new_p, new_a = [], []
    for i, (p, g, a, lab) in enumerate(zip(leaves, glist, alist, llist)):
        gq = quantize_grad_leaf(cfg, g, lab, jax.random.fold_in(key, i),
                                dr_bits)
        c = a.shape[0]                       # local chunk length
        p_c = lax.dynamic_slice(_pad_flat(p, dp * c), (r * c,), (c,))
        g_c = lax.dynamic_slice(_pad_flat(gq, dp * c), (r * c,), (c,))
        q_c, a_c = apply_leaf_update(cfg, p_c, g_c, a, lab, lr, mom)
        if _quant_update_leaf(cfg, lab):     # k_WU grid -> integer gather
            step = 2.0 ** (1 - cfg.k_wu)
            data = jnp.round(q_c / step).astype(jnp.int32)
            full = lax.all_gather(data, "data", axis=0).reshape(-1)
            full = full.astype(jnp.float32) * step
        else:
            full = lax.all_gather(q_c, "data", axis=0).reshape(-1)
        new_p.append(full[: p.size].reshape(p.shape))
        new_a.append(a_c)
    return (jax.tree.unflatten(treedef, new_p),
            MomentumState(acc=jax.tree.unflatten(treedef, new_a),
                          step=state.step + 1))


def make_sharded_train_step(model, qcfg, labels_tree, mesh, params, *,
                            lr=0.05, mom=0.75, dr_bits: int | None = None,
                            n_shards: int | None = None, wire_bits: int = 16,
                            grad_sync: str = "int_ring",
                            wire_codec: str = "packed",
                            opt_shard: str = "replicated"):
    """DP×TP shard_map training step over a ("data", "model") mesh.

    Args:
      model: built with tp_size == mesh model-axis size (build_model).
      params: a concrete (global) param tree — used only to derive the
        partition specs; pass the tree you will train.
      n_shards: virtual batch shards (quantization granularity).  Default
        dp.  Must be a multiple of dp; the global batch must divide by it.
      wire_bits: integer wire width for gradient sync (4/8/16/32).  Sub-8
        widths at fan-ins past the classic bound ride staged int16 hops
        (runtime/compress.wire_plan) with the same exact-sum guarantee.
      grad_sync: "int_ring" (integer wire, DP-invariant) or "psum" (XLA
        fp32 all-reduce baseline — the thing the jaxpr tests prove the
        int_ring path does NOT contain).
      wire_codec: "packed" (wire_sync_tree: one stacked pmax, fused
        pre-sum, single double-buffered ring whose int8 hops pack
        two-per-int16 — DESIGN.md §13) or "leaf" (per-leaf
        wire_sync_mean rings — the pre-codec wire, kept for the
        train/wire_codec bench comparison); "auto" picks per backend
        (runtime/compress.default_wire_codec: packed on TPU, leaf on CPU
        where XLA serializes ppermutes).  Bitwise-identical results.
      opt_shard: "replicated" | "zero1" (Momentum accumulator sharded over
        data as flat chunks; requires tp == 1; see launch/shard.py).

    Returns (step_fn, state_specs): call `jax.jit(step_fn)` on arrays
    placed per state_specs — a dict with "params"/"opt"/"batch" spec trees
    (launch/shard.shard_arrays places them).

    Invariance contract (DESIGN.md §9): each virtual shard's forward and
    backward runs shard-locally (per-shard amax granularity; the fused
    Pallas kernels stay legal because no collective ever appears inside a
    kernel body); the ONE cross-device scale reduction is wire_sync_mean's
    lax.pmax, and every gradient reduction that crosses devices is an exact
    integer sum — so weights after the step are a pure function of
    (global batch, n_shards), not of the device layout.
    """
    from repro.compat import SHARD_MAP_KW as _SM_KW
    from repro.compat import shard_map as _shard_map
    from repro.launch import shard as S
    from repro.runtime.compress import (default_wire_codec, wire_sync_mean,
                                        wire_sync_tree)

    if wire_codec == "auto":
        wire_codec, _ = default_wire_codec()
    dp, tp = S.mesh_dims(mesh)
    if getattr(model, "tp_size", 1) != tp:
        raise ValueError(f"model.tp_size={getattr(model, 'tp_size', 1)} "
                         f"!= mesh model axis {tp}")
    if opt_shard == "zero1" and tp != 1:
        raise ValueError("opt_shard='zero1' requires tp == 1")
    n_shards = dp if n_shards is None else n_shards
    if n_shards % dp:
        raise ValueError(f"n_shards={n_shards} must be a multiple of dp={dp}")
    vs_local = n_shards // dp
    lrq = fixed_point_lr(lr, qcfg)

    def sync_grads(grads):
        if grad_sync != "int_ring":                     # f32-wire baseline
            return jax.tree.map(
                lambda g: lax.pmean(jnp.mean(g, axis=0), "data"), grads)
        if wire_codec == "packed":
            return wire_sync_tree(grads, "data", n_shards=n_shards,
                                  n_dev=dp, bits=wire_bits)
        return jax.tree.map(                            # per-leaf rings
            lambda g: wire_sync_mean(g, "data", n_shards=n_shards,
                                     n_dev=dp, bits=wire_bits), grads)

    def body(params, opt_state, batch, step_idx):
        key = jax.random.fold_in(jax.random.PRNGKey(SEED), step_idx)

        def per_vshard(b_i):
            (l, _), g = jax.value_and_grad(
                model.loss, has_aux=True)(params, b_i, key)
            return l, g

        b_local = jax.tree.leaves(batch)[0].shape[0]
        if b_local % vs_local:
            raise ValueError(
                f"global batch {b_local * dp} must divide by "
                f"n_shards={n_shards} (dp={dp}, {vs_local} virtual shards "
                f"per device, local batch {b_local})")
        # (b_local, ...) -> (vs_local, b_vshard, ...): row-major, so virtual
        # shard v always covers the same global batch rows on any layout
        vb = jax.tree.map(
            lambda x: x.reshape((vs_local, x.shape[0] // vs_local)
                                + x.shape[1:]), batch)
        # lax.map (not vmap): each virtual shard traces the same unbatched
        # program a single-device run would, keeping per-shard f32 reduction
        # shapes layout-independent — the bit-exactness contract needs that
        losses, grads = lax.map(per_vshard, vb)
        grads = sync_grads(grads)
        loss = lax.pmean(jnp.mean(losses), "data")
        okey = jax.random.fold_in(key, 1)
        if opt_shard == "zero1":
            params2, opt2 = _zero1_update(
                qcfg, params, grads, opt_state, labels_tree, okey, lrq, mom,
                dr_bits, dp)
        else:
            params2, opt2 = momentum_update(
                qcfg, params, grads, opt_state, labels_tree, okey, lrq,
                mom=mom, dr_bits=dr_bits)
        return params2, opt2, {"loss": loss}

    pspecs = S.tp_param_specs(model, params)
    ospecs = (S.zero_opt_specs(params) if opt_shard == "zero1"
              else S.opt_specs(pspecs))
    # zero1 implies tp == 1, where pspecs is already the all-replicated
    # tree — params come back replicated either way
    step_fn = _shard_map(
        body, mesh=mesh,
        in_specs=(pspecs, ospecs, jax.sharding.PartitionSpec("data"),
                  jax.sharding.PartitionSpec()),
        out_specs=(pspecs, ospecs, jax.sharding.PartitionSpec()),
        **_SM_KW)
    specs = {"params": pspecs, "opt": ospecs,
             "batch": jax.sharding.PartitionSpec("data")}
    return step_fn, specs


def make_serve_step(model):
    def serve_step(params, cache, tokens):
        return model.serve_step(params, cache, tokens)
    return serve_step


def make_paged_decode_step(model, sampler, k_scale=None, v_scale=None,
                           key=None):
    """Fused continuous-batching decode step for the serving engine.

    step(params, slots, k_pages, v_pages, table, tokens, ctr) ->
    (new_slots, new_k_pages, new_v_pages, tokens).  One trace serves every
    engine step: the lane batch is padded to max_lanes, pages/table drive
    the paged attention, and the sampler picks next tokens on device.
    k_scale/v_scale are the pool's per-layer pow2 scales and `key` the
    base PRNG key — all closed over so the engine can donate the page
    buffers, and so the per-step sampling key derives INSIDE the fused
    trace (fold_in of `ctr`, the engine's sampling counter) instead of as
    a separately dispatched host-side computation per step.
    For non-paged families (SSM) the page arrays pass through untouched.
    """
    paged = model.decode_state_spec()["kv_layers"] > 0
    key = jax.random.PRNGKey(0) if key is None else key

    def step(params, slots, k_pages, v_pages, table, tokens, ctr):
        view = None
        if paged:
            view = {"k_pages": k_pages, "v_pages": v_pages,
                    "k_scale": k_scale, "v_scale": v_scale, "table": table}
        logits, new_slots, new_pages = model.paged_decode_step(
            params, slots, view, tokens)
        toks = sampler(logits, jax.random.fold_in(key, ctr))
        if paged:
            return new_slots, new_pages["k_pages"], new_pages["v_pages"], \
                toks
        return new_slots, k_pages, v_pages, toks

    return step


def make_chunked_prefill_step(model, chunk_pages: int, k_scale=None,
                              v_scale=None):
    """Chunked-prefill step for the serving engine (DESIGN.md §10).

    step(params, dense, k_pages, v_pages, table_row, tokens, start_page,
    n_pages) -> (dense, k_pages, v_pages, last_logits, page_snaps).

    ONE jit-stable trace processes up to `chunk_pages` FULL pages of a
    single lane's prompt: tokens is a fixed (chunk_pages * page,) block,
    `start_page` the first logical block index, `n_pages` the total full
    prompt pages — pages past it are masked (their table view zeroes to
    the trash page and their state/logit updates are discarded), so the
    same trace serves every chunk including the ragged last one.  The
    pages advance via an in-trace lax.scan — no host round-trip per page —
    and each page's numerics are scoped to that page (the radix cache's
    bitwise-determinism unit).  `page_snaps` stacks the dense state AFTER
    each page (leading axis chunk_pages): the page-boundary snapshots the
    radix tree stores for recurrent families.  `last_logits` carries the
    final ACTIVE page's last-token logits for first-token sampling of
    page-aligned prompts.
    """
    paged = model.decode_state_spec()["kv_layers"] > 0

    def step(params, dense, k_pages, v_pages, table_row, tokens,
             start_page, n_pages):
        page = tokens.shape[0] // chunk_pages
        toks = tokens.reshape(chunk_pages, page)

        def body(carry, inp):
            dn, kp, vp, lg = carry
            j, tj = inp
            active = start_page + j < n_pages
            view = None
            if paged:
                eff = jnp.where(active, table_row,
                                jnp.zeros_like(table_row))
                view = {"k_pages": kp, "v_pages": vp, "k_scale": k_scale,
                        "v_scale": v_scale, "table": eff}
            lg2, dn2, pages = model.prefill_page(
                params, dn, view, tj, (start_page + j) * page)
            dn2 = jax.tree.map(lambda a, b: jnp.where(active, a, b),
                               dn2, dn)
            lg = jnp.where(active, lg2, lg)
            if paged:
                kp, vp = pages["k_pages"], pages["v_pages"]
            return (dn2, kp, vp, lg), dn2

        lg0 = jnp.zeros((1, model.a.vocab_padded), jnp.float32)
        (dn, kp, vp, lg), snaps = lax.scan(
            body, (dense, k_pages, v_pages, lg0),
            (jnp.arange(chunk_pages), toks))
        return dn, kp, vp, lg, snaps

    return step


def make_prefill_token_step(model, k_scale=None, v_scale=None):
    """Single-token prefill append for the ragged prompt tail (< one page).

    step(params, dense, k_pages, v_pages, table_row, token, pos) ->
    (dense, k_pages, v_pages, logits).  Reuses the model's fused decode
    body at B=1 — writes the token's KV at `pos` through the lane's table
    row and advances recurrent state — but sampling stays with the caller
    (only the LAST tail token's logits feed the first sample).  One trace
    regardless of tail length; position-deterministic, so tail tokens
    inherit the same recompute-exactness as full pages (they are simply
    never published to the radix tree).
    """
    paged = model.decode_state_spec()["kv_layers"] > 0

    def step(params, dense, k_pages, v_pages, table_row, token, pos):
        slots = dict(dense, pos=pos)
        view = None
        if paged:
            view = {"k_pages": k_pages, "v_pages": v_pages,
                    "k_scale": k_scale, "v_scale": v_scale,
                    "table": table_row}
        logits, new_slots, pages = model.paged_decode_step(
            params, slots, view, token)
        new_dense = dict(new_slots, pos=dense["pos"])   # engine owns pos
        if paged:
            return new_dense, pages["k_pages"], pages["v_pages"], logits
        return new_dense, k_pages, v_pages, logits

    return step


def tp_serving_wrap(fn, mesh, in_specs, out_specs):
    """Manual-TP wrapper for a serving step function (DESIGN.md §12):
    shard_map over the same ("data", "model") mesh as training, with the
    sharded-decode contexts baked into the body — amax_sync (every
    quantizer scale becomes the global tp=1 value via a scalar pmax) and
    tp_int_wire (tp_exit reductions ride integer all_gathers).  The
    contexts are entered inside the body, so every retrace re-applies
    them; at trace time they cost nothing when tp == 1."""
    from repro.compat import SHARD_MAP_KW as _SM_KW
    from repro.compat import shard_map as _shard_map
    from repro.core import qfuncs as qf
    from repro.models import layers as mlayers

    from . import shard as S

    def body(*args):
        with qf.amax_sync(S.MODEL_AXIS), mlayers.tp_int_wire():
            return fn(*args)

    return _shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **_SM_KW)


def make_prefill(model, shape_name):
    from repro.configs.base import LM_SHAPES
    s, b, _ = LM_SHAPES[shape_name]
    a = model.a

    if a.family == "encdec":
        def prefill(params, frames):
            return model.prefill(params, frames, s // a.tgt_ratio)
        return prefill
    if a.family == "ssm":
        def prefill(params, tokens):
            return model.prefill(params, tokens)
        return prefill

    def prefill(params, tokens):
        return model.prefill(params, tokens, s)
    return prefill


# --------------------------------------------------------------------------
# CLI driver (CPU-scale smoke training with the full substrate engaged)
# --------------------------------------------------------------------------


def main(argv=None):
    p = argparse.ArgumentParser("repro.launch.train")
    from repro.core.qconfig import PRESETS
    p.add_argument("--arch", required=True)
    p.add_argument("--preset", default="full8",
                   choices=sorted(PRESETS))
    p.add_argument("--mode", default="sim", choices=["fp32", "sim", "native"])
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=0.05)
    p.add_argument("--reduced", action="store_true",
                   help="use the reduced smoke config (CPU scale)")
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--save-every", type=int, default=25)
    p.add_argument("--dp", type=int, default=1,
                   help="data-parallel mesh size (dp*tp > 1 engages the "
                        "shard_map step with integer-wire gradient sync)")
    p.add_argument("--tp", type=int, default=1,
                   help="tensor-parallel mesh size (transformer families)")
    p.add_argument("--n-shards", type=int, default=0,
                   help="virtual batch shards (quantization granularity); "
                        "0 = dp")
    p.add_argument("--wire-bits", type=int, default=16,
                   choices=[4, 8, 16, 32],
                   help="integer wire width for sharded gradient sync "
                        "(sub-8 widths stage onto int16 hops past the "
                        "classic fan-in bound)")
    p.add_argument("--grad-sync", default="int_ring",
                   choices=["int_ring", "psum"])
    p.add_argument("--wire-codec", default="auto",
                   choices=["auto", "packed", "leaf"],
                   help="int_ring codec: 'packed' = whole-tree sync (one "
                        "stacked pmax, fused pre-sum, double-buffered ring "
                        "with two-per-int16 hops at 8-bit); 'leaf' = "
                        "per-leaf rings (pre-codec wire); 'auto' = packed "
                        "on TPU, leaf on CPU (serialized-ppermute caveat)")
    p.add_argument("--dr-boundaries", default="",
                   help="comma-separated steps where CQ's dr width shrinks "
                        "one bit (paper §III-C), e.g. '30,40'; base width "
                        "is the preset's k_gw")
    p.add_argument("--opt-shard", default="replicated",
                   choices=["replicated", "zero1"])
    p.add_argument("--elastic", action="store_true",
                   help="drive the run through the ElasticRunner (async "
                        "QTensor checkpoints, restore-on-failure, bit-exact "
                        "DP reshard on membership change); requires "
                        "--ckpt-dir")
    p.add_argument("--resume", action="store_true",
                   help="resume from the latest checkpoint in --ckpt-dir "
                        "(elastic: works even if it was written under a "
                        "different --dp, as long as --n-shards matches)")
    p.add_argument("--rebalance-flags", type=int, default=0,
                   help="elastic: shrink dp to the next divisor of n_shards "
                        "after this many straggler flags (0 = off)")
    args = p.parse_args(argv)

    acfg = get_arch(args.arch)
    if args.reduced:
        acfg = acfg.reduced()
    qcfg = preset(args.preset, args.mode if args.preset != "fp32" else None)
    from repro.kernels.ops import dispatch_banner
    print(dispatch_banner(qcfg))
    from repro.runtime.compress import default_wire_codec
    if args.wire_codec == "auto":
        codec, codec_why = default_wire_codec()
    else:
        codec, codec_why = args.wire_codec, "forced by --wire-codec"
    bounds = parse_boundaries(args.dr_boundaries)
    if bounds and args.elastic:
        p.error("--dr-boundaries is not supported under --elastic yet")
    sharded = args.dp * args.tp > 1
    model = build_model(acfg, qcfg, tp_size=args.tp if sharded else 1)

    from repro.data import TokenTask
    task = TokenTask(vocab=acfg.vocab, seq_len=args.seq,
                     global_batch=args.batch)

    key = jax.random.PRNGKey(0)
    params = model.init(key)
    labels_tree = model.labels(params)

    if args.elastic:
        if not args.ckpt_dir:
            p.error("--elastic requires --ckpt-dir")
        from repro.checkpoint import CheckpointManager
        from repro.launch import shard as S
        from repro.runtime import ElasticRunner

        n_shards = args.n_shards or args.dp
        opt = (S.zero_init_momentum(params, args.dp)
               if args.opt_shard == "zero1" else init_momentum(params))
        ckpt = CheckpointManager(args.ckpt_dir)
        runner = ElasticRunner(
            model, qcfg, labels_tree, ckpt, task.batch, dp=args.dp,
            tp=args.tp, n_shards=n_shards, opt_shard=args.opt_shard,
            lr=args.lr, wire_bits=args.wire_bits, grad_sync=args.grad_sync,
            save_every=args.save_every,
            rebalance_flags=args.rebalance_flags)
        print(f"[elastic] dp={args.dp} tp={args.tp} n_shards={n_shards} "
              f"opt={args.opt_shard} save_every={args.save_every} "
              f"resume={args.resume}")
        t0 = time.time()
        params, opt, metrics = runner.run(params, opt, args.steps,
                                          resume=args.resume)
        rep = ckpt.size_report()
        print(f"[elastic] done in {time.time() - t0:.1f}s loss "
              f"{float(metrics['loss']):.4f} restarts={runner.restarts} "
              f"reshards={len(runner.reshards)}")
        print(f"[ckpt] {rep['ckpt_bytes_q']} B packed vs "
              f"{rep['ckpt_bytes_f32_dense']} B dense-f32 "
              f"({rep['ratio']:.2f}x)")
        return

    # one jitted step fn per scheduled dr width (dr_bits is a static trace
    # constant); with no --dr-boundaries this dict holds exactly one entry
    step_fns: dict[int, object] = {}
    if sharded:
        from repro.launch import shard as S
        from repro.launch.mesh import make_cpu_mesh
        mesh = make_cpu_mesh(args.dp, args.tp)
        opt = (S.zero_init_momentum(params, args.dp)
               if args.opt_shard == "zero1" else init_momentum(params))

        def fn_for(bits):
            if bits not in step_fns:
                raw, _ = make_sharded_train_step(
                    model, qcfg, labels_tree, mesh, params, lr=args.lr,
                    dr_bits=bits, n_shards=args.n_shards or None,
                    wire_bits=args.wire_bits, grad_sync=args.grad_sync,
                    wire_codec=codec, opt_shard=args.opt_shard)
                step_fns[bits] = jax.jit(raw, donate_argnums=(0, 1))
            return step_fns[bits]

        _, specs = make_sharded_train_step(
            model, qcfg, labels_tree, mesh, params, lr=args.lr,
            n_shards=args.n_shards or None, wire_bits=args.wire_bits,
            grad_sync=args.grad_sync, wire_codec=codec,
            opt_shard=args.opt_shard)
        params = S.shard_arrays(mesh, params, specs["params"])
        opt = S.shard_arrays(mesh, opt, specs["opt"])
        print(f"[shard] mesh dp={args.dp} tp={args.tp} "
              f"n_shards={args.n_shards or args.dp} "
              f"wire={args.grad_sync}:{args.wire_bits}b "
              f"codec={codec} ({codec_why}) opt={args.opt_shard}")
    else:
        opt = init_momentum(params)

        def fn_for(bits):
            if bits not in step_fns:
                step_fns[bits] = jax.jit(
                    make_train_step(model, qcfg, labels_tree, lr=args.lr,
                                    dr_bits=bits),
                    donate_argnums=(0, 1))
            return step_fns[bits]

    ckpt = None
    start = 0
    if args.ckpt_dir:
        from repro.checkpoint import CheckpointManager
        ckpt = CheckpointManager(args.ckpt_dir)
        if args.resume and ckpt.latest_step() is not None:
            (params, opt), start, _ = ckpt.restore((params, opt))
            print(f"resumed from step {start}")

    t0 = time.time()
    cur_bits = None
    for step in range(start, args.steps):
        bits = dr_bits_schedule(step, bounds, base_bits=qcfg.k_gw)
        if bits != cur_bits:
            if bounds:
                print(f"[dr] step {step}: CQ dr width -> {bits} bits")
            cur_bits = bits
        step_fn = fn_for(bits)
        if sharded:
            from repro.launch.shard import put_batch
            batch = put_batch(mesh, task.batch(step))
        else:
            batch = jax.tree.map(jnp.asarray, task.batch(step))
        params, opt, metrics = step_fn(params, opt, batch,
                                       jnp.int32(step))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"({time.time() - t0:.1f}s)")
        if ckpt and (step + 1) % args.save_every == 0:
            ckpt.save(step + 1, (params, opt))
    if ckpt:
        ckpt.wait()


if __name__ == "__main__":
    main()
