import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (the two lines above MUST run before any jax import — device count locks
# at first init.  REPRO_DEVICES overrides for CI-scale smoke runs.)
if os.environ.get("REPRO_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell we build the production mesh, attach NamedShardings to
ShapeDtypeStruct stand-ins for every input (weights, optimizer state, batch
or cache — no device allocation anywhere), lower the jitted step, compile,
and record memory_analysis / cost_analysis / the collective schedule into a
JSON artifact that §Roofline and §Perf read.

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-34b \
        --shape train_4k --mesh multi
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED, get as get_arch
from repro.configs.base import LM_SHAPES
from repro.core.qconfig import preset
from repro.launch.mesh import make_production_mesh, mesh_axes
from repro.launch.roofline import parse_collectives
from repro.launch.train import make_prefill, make_serve_step, make_train_step
from repro.models import build_model
from repro.optim import init_momentum


def _tiny() -> bool:
    return bool(os.environ.get("REPRO_DEVICES"))


def make_mesh(multi_pod: bool):
    if _tiny():
        shape = (2, 2, 2) if multi_pod else (2, 2)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        return jax.make_mesh(shape, axes)
    return make_production_mesh(multi_pod=multi_pod)


def _shard_sds(tree, pspec_tree, mesh):
    def f(sds, spec):
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(f, tree, pspec_tree)


def _count_params(params_sds, acfg):
    total = active = 0
    flat, _ = jax.tree_util.tree_flatten_with_path(params_sds)
    for path, leaf in flat:
        keys = [str(getattr(p, "key", "")) for p in path]
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if "embed" in keys:
            continue
        if acfg.moe_experts and any(k in ("wg", "wu", "wd") for k in keys) \
                and "moe" in keys:
            active += n * acfg.moe_topk / acfg.moe_experts
        else:
            active += n
    return total, active


def _model_flops(acfg, kind, shape_name, n_active):
    s, b, _ = LM_SHAPES[shape_name]
    if acfg.family == "encdec":
        tokens = b * (s + s // acfg.tgt_ratio)
    else:
        tokens = b * s
    if kind == "train":
        return 6.0 * n_active * tokens
    if kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * b       # decode: one token per sequence


def _compile_cell(acfg, shape, mesh, dp, tp, qcfg, sb, n_micro=1):
    """Lower + compile one configuration; returns (compiled, t_lower,
    t_compile)."""
    model = build_model(acfg, qcfg, mesh=mesh, dp_axes=dp, tp_axis=tp)
    specs, kind = model.input_specs(shape, sb=sb)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_sh = _shard_sds(params_sds, model.pspecs(), mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    if kind == "train":
        labels_tree = model.labels(params_sds)
        opt_sds = jax.eval_shape(init_momentum, params_sds)
        opt_sh = _shard_sds(
            opt_sds, type(opt_sds)(acc=model.pspecs(), step=P()), mesh)
        batch_sh = _shard_sds(specs, model.batch_pspec(), mesh)
        fn = make_train_step(model, qcfg, labels_tree, n_micro=n_micro)
        args = (params_sh, opt_sh, batch_sh,
                jax.ShapeDtypeStruct((), jnp.int32))
        jfn = jax.jit(fn, donate_argnums=(0, 1))
    elif kind == "prefill":
        fn = make_prefill(model, shape)
        bspec = model.batch_pspec()
        if acfg.family == "encdec":
            in_sh = _shard_sds(specs["frames"], bspec["frames"], mesh)
        else:
            in_sh = _shard_sds(specs["tokens"], bspec["tokens"], mesh)
        args = (params_sh, in_sh)
        cache_ps = model.cache_pspec(long=False)
        cache_out = jax.tree.map(
            lambda s: NamedSharding(mesh, s), cache_ps)
        if acfg.family == "encdec":
            out_sh = cache_out
        else:
            out_sh = (cache_out, NamedSharding(mesh, P(dp, None)))
        jfn = jax.jit(fn, out_shardings=out_sh)
    else:  # decode
        long = shape.startswith("long")
        cache_sh = _shard_sds(specs["cache"], model.cache_pspec(long=long),
                              mesh)
        tok_spec = P(dp) if specs["tokens"].shape[0] % dp_size == 0 else P()
        tok_sh = _shard_sds(specs["tokens"], tok_spec, mesh)
        fn = make_serve_step(model)
        args = (params_sh, cache_sh, tok_sh)
        jfn = jax.jit(fn, donate_argnums=(1,))

    t0 = time.time()
    lowered = jfn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    return compiled, kind, params_sds, t_lower, time.time() - t0


def _depth_points(acfg):
    """Two depth settings + extrapolation step count for affine cost fits.

    metric(full) = metric(A) + (metric(B) - metric(A)) * steps
    """
    if acfg.family == "hybrid":
        ae = acfg.attn_every
        gfull = acfg.n_layers // ae
        tail = acfg.n_layers - gfull * ae
        return (acfg.replace(n_layers=ae + tail),
                acfg.replace(n_layers=2 * ae + tail),
                float(gfull - 1))
    if acfg.family == "encdec":
        return (acfg.replace(enc_layers=2, dec_layers=2),
                acfg.replace(enc_layers=4, dec_layers=4),
                (acfg.enc_layers - 2) / 2.0)
    la = min(2, acfg.n_layers)
    lb = min(4, acfg.n_layers)
    steps = (acfg.n_layers - la) / max(lb - la, 1)
    return acfg.replace(n_layers=la), acfg.replace(n_layers=lb), steps


def _cost_metrics(compiled):
    ca = compiled.cost_analysis() or {}
    # older jax returns a one-element list of dicts, newer a flat dict
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    colls = parse_collectives(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": float(sum(v["bytes"] for v in colls.values())),
        "coll_wire": float(sum(v["wire_bytes"] for v in colls.values())),
    }, colls


def _parse_overrides(pairs):
    out = {}
    for kv in pairs or []:
        k, v = kv.split("=", 1)
        if v in ("true", "True"):
            v = True
        elif v in ("false", "False"):
            v = False
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        out[k] = v
    return out


def run_cell(arch: str, shape: str, multi_pod: bool, qpreset: str = "full8",
             mode: str = "native", q_over=None, a_over=None) -> dict:
    acfg = get_arch(arch)
    if _tiny():
        acfg = acfg.reduced()
    if a_over:
        acfg = acfg.replace(**a_over)
    mesh = make_mesh(multi_pod)
    dp, tp = mesh_axes(mesh)
    qcfg = preset(qpreset, mode)
    if q_over:
        qcfg = qcfg.replace(**q_over)
    sb = (64, 8) if _tiny() else None
    s, b, _ = LM_SHAPES[shape]
    if _tiny():
        s, b = sb

    # 1) FULL compile: the pass/fail gate + memory analysis.
    # Train cells use microbatched grad accumulation (one sequence per
    # device per microbatch) — the production memory policy; cost compiles
    # below stay n_micro=1 (same total work, exact loop-free accounting).
    dp_size = 1
    for ax in dp:
        dp_size *= mesh.shape[ax]
    n_micro = 1
    if LM_SHAPES[shape][2] == "train" and not _tiny():
        n_micro = max(1, b // dp_size)
    compiled, kind, params_sds, t_lower, t_compile = _compile_cell(
        acfg, shape, mesh, dp, tp, qcfg, sb, n_micro=n_micro)
    ma = compiled.memory_analysis()
    raw, colls = _cost_metrics(compiled)

    # 2) two depth-point cost compiles with single-trip inner loops
    #    (XLA cost analysis counts while bodies ONCE; unchunked attention /
    #    scan makes inner loops trip-1 = exact, and depth is extrapolated
    #    affinely — see EXPERIMENTS.md §Dry-run "cost accounting").
    #    The roofline table is single-pod only (per assignment), so
    #    multi-pod cells skip the cost compiles — their FULL compile above
    #    is the multi-pod deliverable (the pod axis shards, memory fits).
    steps = 0.0
    if multi_pod:
        cost = dict(raw)
    else:
        st = s if acfg.family != "encdec" else max(s, s // acfg.tgt_ratio)
        unchunked = dict(q_chunk=st, kv_chunk=st, unroll_layers=True)
        if acfg.family == "hybrid":
            # SSD intra-chunk: single-chunk variants stall constant folding
            # and fully unrolled chunk scans blow up XLA optimization time;
            # keep the chunk scan rolled (bodies counted once).  The SSD
            # intra-chunk share of zamba2 FLOPs is small vs projections +
            # shared attention, so this is a documented <~20% undercount on
            # that component only (cost_note in the artifact).
            unchunked.update(scan_chunk=acfg.scan_chunk)
        else:
            # mamba1 uses associative_scan (loop-free: exact at any chunk)
            unchunked.update(scan_chunk=st)
        acfg_a, acfg_b, steps = _depth_points(acfg.replace(**unchunked))
        comp_a, _, _, _, _ = _compile_cell(acfg_a, shape, mesh, dp, tp, qcfg,
                                           sb)
        ca_a, _ = _cost_metrics(comp_a)
        if steps > 0:
            comp_b, _, _, _, _ = _compile_cell(acfg_b, shape, mesh, dp, tp,
                                               qcfg, sb)
            ca_b, _ = _cost_metrics(comp_b)
        else:
            ca_b = ca_a
        cost = {k: ca_a[k] + (ca_b[k] - ca_a[k]) * steps for k in ca_a}

    n_total, n_active = _count_params(params_sds, acfg)
    art = {
        "arch": arch, "shape": shape, "n_micro": n_micro,
        "q_overrides": q_over or {}, "a_overrides": a_over or {},
        "mesh": "multi" if multi_pod else "single",
        "kind": kind, "devices": mesh.devices.size,
        "preset": qpreset, "qmode": mode,
        "lower_s": t_lower, "compile_s": t_compile,
        "flops_per_device": cost["flops"],
        "bytes_per_device": cost["bytes"],
        "collective_bytes_per_device": cost["coll"],
        "collective_wire_bytes_per_device": cost["coll_wire"],
        "raw_once_through": raw,
        "depth_extrapolation_steps": steps,
        "cost_note": ("hybrid: SSD chunk-scan bodies counted once "
                      "(<~20% undercount on the intra-chunk component)"
                      if acfg.family == "hybrid" and not multi_pod else ""),
        "collectives": colls,
        "mem_analysis": {
            "arg_bytes": int(ma.argument_size_in_bytes),
            "out_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_est": int(ma.argument_size_in_bytes
                                  + ma.output_size_in_bytes
                                  + ma.temp_size_in_bytes
                                  - ma.alias_size_in_bytes),
        } if ma else {},
        "n_params": n_total, "n_params_active": n_active,
        "model_flops_global": _model_flops(acfg, kind, shape, n_active),
    }
    return art


def cells_for(arch: str):
    return get_arch(arch).shapes


def main(argv=None):
    p = argparse.ArgumentParser("repro.launch.dryrun")
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--mesh", default="both",
                   choices=["single", "multi", "both"])
    p.add_argument("--all", action="store_true")
    p.add_argument("--preset", default="full8")
    p.add_argument("--qmode", default="native")
    p.add_argument("--out-dir", default="artifacts/dryrun")
    p.add_argument("--force", action="store_true")
    p.add_argument("--tag", default="")
    p.add_argument("--set-q", action="append", default=[],
                   help="QConfig override key=val (repeatable), e.g. "
                        "--set-q tp_comm_dtype=bf16")
    p.add_argument("--set-arch", action="append", default=[],
                   help="ArchConfig override key=val, e.g. --set-arch "
                        "remat=none")
    args = p.parse_args(argv)
    q_over = _parse_overrides(args.set_q)
    a_over = _parse_overrides(args.set_arch)

    os.makedirs(args.out_dir, exist_ok=True)
    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        shapes = [args.shape] if args.shape else cells_for(arch)
        for shape in shapes:
            for mp in meshes:
                name = f"{arch}_{shape}_{'multi' if mp else 'single'}"
                if args.tag:
                    name += f"_{args.tag}"
                out = os.path.join(args.out_dir, name + ".json")
                if os.path.exists(out) and not args.force:
                    print(f"[skip] {name} (exists)")
                    continue
                print(f"[cell] {name} ...", flush=True)
                try:
                    art = run_cell(arch, shape, mp, args.preset,
                                                   args.qmode, q_over, a_over)
                    with open(out, "w") as f:
                        json.dump(art, f, indent=1)
                    print(f"  ok: compile {art['compile_s']:.1f}s, "
                          f"flops/dev {art['flops_per_device']:.3e}, "
                          f"coll/dev {art['collective_bytes_per_device']:.3e}B",
                          flush=True)
                    if art["mem_analysis"]:
                        print(f"  mem/dev: "
                              f"{art['mem_analysis']['peak_bytes_est']/2**30:.2f}"
                              " GiB (args+temp+out)", flush=True)
                except Exception as e:  # noqa: BLE001
                    failures.append((name, repr(e)))
                    print(f"  FAIL: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for n, e in failures:
            print(" ", n, e)
        raise SystemExit(1)
    print("\nall requested dry-run cells compiled OK")


if __name__ == "__main__":
    main()
