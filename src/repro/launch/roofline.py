"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch × shape × mesh), all in seconds:
    compute    = HLO_FLOPs_per_device / peak_FLOP/s          (MXU)
    memory     = HLO_bytes_per_device / HBM_bw               (HBM)
    collective = collective_bytes_per_device / link_bw       (ICI)

cost_analysis() is per-device for SPMD executables (verified empirically:
a (256,512)x(512,1024) matmul over 8 devices reports 2MNK/8 flops), so the
per-device forms above equal the spec's global/(chips*rate) forms.

Hardware constants (TPU v5e-class, per assignment): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.  int8 MXU peak is 2x bf16 — both fractions
are reported; the headline roofline fraction uses the bf16 constant per the
assignment, the int8 column shows what the WAGEUBN datapath unlocks.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re

PEAK_BF16 = 197e12
PEAK_INT8 = 394e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_RE = re.compile(
    r"=\s+(?P<lhs>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all"
    r"|collective-permute)(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"\b(pred|bf16|f16|f32|f64|s8|s16|s32|s64|u8|u16|u32"
                       r"|u64)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def parse_collectives(hlo_text: str) -> dict:
    """Per-device collective byte accounting from the scheduled HLO.

    Scheduled HLO names (not re-types) operands, so we read the RESULT shape
    and convert to operand bytes per op semantics:
        all-reduce:         operand == result
        all-gather:         operand == result / group_size
        reduce-scatter:     operand == result * group_size
        all-to-all / collective-permute: operand == result
    Also records a ring wire-traffic estimate per op ("wire_bytes"):
        all-reduce 2*(g-1)/g * size; all-gather/reduce-scatter (g-1)/g * full
        size; permute/all-to-all = size.
    Returns {op: {"bytes", "wire_bytes", "count"}}.
    """
    out: dict = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        result_bytes = sum(_shape_bytes(d, s)
                           for d, s in _SHAPE_RE.findall(m.group("lhs")))
        if m.group("start"):
            result_bytes //= 2      # (operand, result) tuple of async op
        g = max(_group_size(line), 1)
        if op == "all-gather":
            operand = result_bytes // g
            wire = result_bytes * (g - 1) // g
        elif op == "reduce-scatter":
            operand = result_bytes * g
            wire = operand * (g - 1) // g
        elif op == "all-reduce":
            operand = result_bytes
            wire = 2 * result_bytes * (g - 1) // g
        else:
            operand = result_bytes
            wire = result_bytes
        rec = out.setdefault(op, {"bytes": 0, "wire_bytes": 0, "count": 0})
        rec["bytes"] += operand
        rec["wire_bytes"] += wire
        rec["count"] += 1
    return out


def terms(art: dict) -> dict:
    """Roofline terms (seconds) + fractions for one artifact dict."""
    flops = art["flops_per_device"]
    mem_bytes = art["bytes_per_device"]
    coll_bytes = art["collective_bytes_per_device"]
    t_c = flops / PEAK_BF16
    t_c8 = flops / PEAK_INT8
    t_m = mem_bytes / HBM_BW
    t_l = coll_bytes / LINK_BW
    dominant = max(("compute", t_c), ("memory", t_m),
                   ("collective", t_l), key=lambda kv: kv[1])[0]
    total = max(t_c, t_m, t_l)
    chips = art["devices"]
    model_flops = art.get("model_flops_global", 0.0)
    hlo_global = flops * chips
    return {
        "compute_s": t_c, "compute_int8_s": t_c8, "memory_s": t_m,
        "collective_s": t_l, "dominant": dominant,
        "roofline_fraction": (t_c / total) if total else 0.0,
        "useful_ratio": (model_flops / hlo_global) if hlo_global else 0.0,
        "step_lower_bound_s": total,
    }


def measured_fraction(flops: float, mem_bytes: float, dt_s: float,
                      coll_bytes: float = 0.0) -> dict:
    """%-of-roofline for a MEASURED step time (the bench harness hook).

    The roofline floor is max(compute, memory, collective) seconds at the
    reference chip's peaks; the fraction is floor / measured.  Reported at
    BOTH MXU peaks — "pct_bf16" (f32/bf16 peak) and "pct_int8" (the 2x
    int8 peak the paper's data paths target): a fused-int8 step that looks
    healthy against the bf16 peak but poor against the int8 peak is
    leaving the MXU's 2x on the table, which is exactly the regression
    this field exists to attribute.  On the CPU CI container the absolute
    fractions are tiny (the constants model a TPU chip) — the signal is
    their trajectory between commits, not their magnitude.
    """
    t_m = mem_bytes / HBM_BW
    t_l = coll_bytes / LINK_BW
    out = {}
    for tag, peak in (("pct_bf16", PEAK_BF16), ("pct_int8", PEAK_INT8)):
        floor = max(flops / peak, t_m, t_l)
        out[tag] = (floor / dt_s) if dt_s > 0 else 0.0
    return out


def load_artifacts(art_dir: str):
    arts = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(f) as fh:
            arts.append(json.load(fh))
    return arts


def render_table(arts, mesh_filter="single") -> str:
    rows = ["| arch | shape | kind | compute_s | memory_s | collective_s |"
            " dominant | roofline_frac | useful_ratio |",
            "|---|---|---|---|---|---|---|---|---|"]
    for a in arts:
        if a["mesh"] != mesh_filter:
            continue
        t = terms(a)
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['kind']} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} "
            f"| {t['collective_s']:.3e} | {t['dominant']} "
            f"| {t['roofline_fraction']:.2%} | {t['useful_ratio']:.2f} |")
    return "\n".join(rows)


def main(argv=None):
    p = argparse.ArgumentParser("repro.launch.roofline")
    p.add_argument("--art-dir", default="artifacts/dryrun")
    p.add_argument("--mesh", default="single")
    args = p.parse_args(argv)
    arts = load_artifacts(args.art_dir)
    print(render_table(arts, args.mesh))
    print()
    for a in arts:
        if a["mesh"] != args.mesh:
            continue
        t = terms(a)
        print(f"{a['arch']:24s} {a['shape']:12s} dominant={t['dominant']:10s}"
              f" bound={t['step_lower_bound_s']:.4e}s peak/dev="
              f"{a['mem_analysis'].get('peak_bytes_est', 0)/2**30:.2f}GiB")


if __name__ == "__main__":
    main()
