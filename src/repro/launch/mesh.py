"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh(n_data: int = 1, n_model: int = 1, pod: int = 0):
    """Small mesh over available devices (tests / smoke runs)."""
    if pod:
        return jax.make_mesh((pod, n_data, n_model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def mesh_axes(mesh):
    """(dp_axes, tp_axis) convention used throughout the framework."""
    names = mesh.axis_names
    dp = tuple(n for n in names if n in ("pod", "data"))
    return dp, "model"
