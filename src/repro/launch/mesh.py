"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh(n_data: int = 1, n_model: int = 1, pod: int = 0):
    """Small mesh over available devices (tests / smoke runs)."""
    if pod:
        return jax.make_mesh((pod, n_data, n_model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_replica_meshes(n_replicas: int, tp: int = 1):
    """Disjoint (1, tp) serving meshes carved from the device list — one per
    data-parallel serving replica, so replicas never contend for a device."""
    import numpy as np
    devs = jax.devices()
    need = n_replicas * tp
    if len(devs) < need:
        raise ValueError(
            f"{n_replicas} replicas x tp={tp} needs {need} devices, "
            f"have {len(devs)}")
    from jax.sharding import Mesh
    return [Mesh(np.array(devs[i * tp:(i + 1) * tp]).reshape(1, tp),
                 ("data", "model")) for i in range(n_replicas)]


def mesh_axes(mesh):
    """(dp_axes, tp_axis) convention used throughout the framework."""
    names = mesh.axis_names
    dp = tuple(n for n in names if n in ("pod", "data"))
    return dp, "model"
