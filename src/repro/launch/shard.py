"""DP×TP partition rules for the shard_map training step (DESIGN.md §9).

The sharded train step (launch/train.make_sharded_train_step) runs as ONE
full-manual shard_map over a ("data", "model") mesh:

  data  — batch parallelism.  The global batch splits into `n_shards`
          VIRTUAL shards (the quantization granularity — a static property
          of the algorithm); each device runs n_shards/dp of them and
          gradient sync rides the integer wire (runtime/compress.py).
  model — manual tensor parallelism.  Transformer families shard attention
          heads / FFN features / experts; the recurrent families shard
          mamba1's d_inner channels and mamba2's SSD heads (DESIGN.md §12).
          Params arrive pre-sliced via the specs below and the Megatron
          tp_enter/tp_exit pair in models/layers.py carries the boundary
          reductions.  Families without a manual-TP implementation are
          DP-only (build_model raises).

This module owns the per-family sharding RULES: which parameter axes live
on the model axis, how optimizer state mirrors them (including the ZeRO-1
flat-chunk layout for the Momentum accumulator), and how batches split.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

DATA_AXIS = "data"
MODEL_AXIS = "model"

# Leaves sharded over the model axis, by parameter name: value = the axis
# (negative, FROM THE END of the leaf) that carries the shard.  Negative
# indexing makes one rule serve stacked (L, ...) per-layer leaves, stacked
# (L, e, ...) expert leaves and UNstacked shared-block leaves (the hybrid
# family reuses one attention block, so its wq is 2-D) alike.
# Column-sharded (output features / heads / experts): wq wk wv w_gate w_up
# wg wu; row-sharded (input features, partial outputs psum'ed by tp_exit):
# wo w_down wd.
_TP_SHARDED_AXIS = {
    "wq": -1, "wk": -1, "wv": -1, "w_gate": -1, "w_up": -1,  # (.., d, f_tp)
    "wo": -2, "w_down": -2,                                  # (.., f_tp, d)
    "wg": -3, "wu": -3, "wd": -3,                            # (L, e_tp, ..)
}

# Per-family extensions for the recurrent blocks (DESIGN.md §12): mamba1
# splits the d_inner channel axis (x_proj/out_proj row-sharded, dt_proj
# column-sharded, per-channel vectors sliced); mamba2 splits SSD heads and
# keeps every channel-mixing projection replicated.  Names absent here and
# in the base table stay replicated.
_TP_FAMILY_AXIS = {
    "ssm": {"x_proj": -2, "dt_proj": -1, "dt_bias": -1, "A_log": -2,
            "D_skip": -1, "out_proj": -2},
    "hybrid": {"dt_proj": -1, "dt_bias": -1, "A_log": -1, "D_skip": -1},
}


def mesh_dims(mesh):
    """(dp, tp) sizes of a ("data", "model") training mesh."""
    names = set(mesh.axis_names)
    if names != {DATA_AXIS, MODEL_AXIS}:
        raise ValueError(
            f"sharded training wants a (data, model) mesh, got {names}")
    return mesh.shape[DATA_AXIS], mesh.shape[MODEL_AXIS]


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def tp_param_specs(model, params):
    """PartitionSpec tree for `params`: model-axis shards per the family
    rules above, everything else replicated.  With tp_size == 1 every leaf
    is replicated (pure DP)."""
    if getattr(model, "tp_size", 1) == 1:
        return jax.tree.map(lambda _: P(), params)

    table = dict(_TP_SHARDED_AXIS)
    fam = getattr(getattr(model, "a", None), "family", "")
    table.update(_TP_FAMILY_AXIS.get(fam, {}))

    def spec(path, leaf):
        ax = table.get(_leaf_name(path))
        if ax is None:
            return P()
        ax = ax % leaf.ndim
        return P(*((MODEL_AXIS if i == ax else None)
                   for i in range(leaf.ndim)))

    return jax.tree_util.tree_map_with_path(spec, params)


def decode_slot_specs(model, slots):
    """PartitionSpec dict for the serving engine's dense decode slots: the
    model's decode_state_spec()["tp_axes"] names the stacked-slot axis each
    key shards over the model axis (recurrent channel/head state); every
    other key — positions, conv windows — is replicated."""
    if getattr(model, "tp_size", 1) == 1:
        return {k: P() for k in slots}
    tp_axes = model.decode_state_spec().get("tp_axes", {})

    def spec(k, leaf):
        ax = tp_axes.get(k)
        if ax is None:
            return P()
        return P(*((MODEL_AXIS if i == ax else None)
                   for i in range(leaf.ndim)))

    return {k: spec(k, v) for k, v in slots.items()}


def page_pool_spec(model):
    """Spec for an int8 KV page array (kv_layers, n_pages, page, n_kv, dh):
    KV heads column-shard over the model axis (each rank's pages hold its
    local n_kv/tp heads — the page-shard layout of DESIGN.md §12).
    Pageless families (pure SSM) get the replicated spec for their dummy
    (0,) placeholder arrays."""
    if getattr(model, "tp_size", 1) == 1:
        return P()
    if model.decode_state_spec()["kv_layers"] == 0:
        return P()
    return P(None, None, None, MODEL_AXIS, None)


def batch_specs(batch):
    """Batches split over the data axis on their leading dimension."""
    return jax.tree.map(lambda _: P(DATA_AXIS), batch)


def opt_specs(param_specs):
    """MomentumState specs for the replicated-optimizer layout: the
    accumulator mirrors the params, the step counter is replicated."""
    from repro.optim import MomentumState
    return MomentumState(acc=param_specs, step=P())


def shard_arrays(mesh, tree, specs):
    """device_put every leaf with its NamedSharding (host -> mesh)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs)


def put_batch(mesh, batch):
    """Place a host batch on the mesh, split over the data axis."""
    sharding = NamedSharding(mesh, P(DATA_AXIS))
    return jax.tree.map(
        lambda x: jax.device_put(jnp.asarray(x), sharding), batch)


# --------------------------------------------------------------------------
# ZeRO-1 layout: Momentum accumulator as flat per-device chunks
# --------------------------------------------------------------------------
#
# Each leaf's accumulator is stored FLAT, padded to dp equal chunks, global
# shape (dp * chunk,), sharded P("data") — so each device holds exactly the
# chunk it updates.  The update itself is elementwise (optim/momentum.py
# apply_leaf_update), so chunking cannot change a bit of the result; the
# gradient quantization (CQ amax + stochastic bits) always runs on the FULL
# leaf before chunking for the same reason.


def zero_chunk_len(size: int, dp: int) -> int:
    return -(-size // dp)


def zero_init_momentum(params, dp: int):
    """MomentumState with flat padded (dp * chunk,) accumulator leaves."""
    from repro.optim import MomentumState
    acc = jax.tree.map(
        lambda p: jnp.zeros((dp * zero_chunk_len(p.size, dp),), p.dtype),
        params)
    return MomentumState(acc=acc, step=jnp.zeros((), jnp.int32))


def zero_opt_specs(params):
    """Specs for the ZeRO-1 MomentumState: accumulator chunks over data."""
    from repro.optim import MomentumState
    return MomentumState(acc=jax.tree.map(lambda _: P(DATA_AXIS), params),
                         step=P())


def zero_template(params, dp: int):
    """ShapeDtypeStruct MomentumState for the ZeRO-1 layout under `dp` —
    the restore target for a checkpoint written under that membership."""
    from repro.optim import MomentumState
    acc = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct((dp * zero_chunk_len(p.size, dp),),
                                       p.dtype), params)
    return MomentumState(acc=acc,
                         step=jax.ShapeDtypeStruct((), jnp.int32))


def zero_reshard(acc_tree, params, dp_new: int):
    """Re-chunk flat ZeRO-1 accumulator leaves for a new DP membership:
    (dp_old * chunk_old,) -> (dp_new * chunk_new,).

    Bit-exact by the layout's own algebra: the logical accumulator is the
    first `p.size` entries of the flat leaf and the tail is padding that
    both STARTS zero (zero_init_momentum) and STAYS zero (the elementwise
    update of a zero-param/zero-grad slot is zero — launch/train.py
    `_zero1_update`), so resharding is exactly unpad + repad with zeros.
    Runs on host numpy: reshard happens between memberships, off-mesh.
    """
    def f(a, p):
        flat = np.asarray(a).reshape(-1)[: int(np.prod(p.shape, dtype=int))]
        c = zero_chunk_len(flat.size, dp_new)
        return np.pad(flat, (0, dp_new * c - flat.size))
    return jax.tree.map(f, acc_tree, params)
