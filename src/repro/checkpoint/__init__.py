from . import qsave
from .manager import CheckpointManager

__all__ = ["CheckpointManager", "qsave"]
