"""Fault-tolerant checkpointing: atomic, async, retention, topology-agnostic.

Design (DESIGN.md §5/§11):
  * every leaf is saved as a full logical array keyed by its pytree
    path -> restore works under ANY mesh/sharding (elastic re-scale);
  * leaves are stored in the QTensor-native packed encoding (qsave.py):
    integer payloads + pow2 grid exponents, never densified to f32 —
    int8 QTensor payloads cost 1 byte/element on disk, k_WU-grid master
    weights 3, Momentum accumulators 2 (`packed=False` writes dense f32);
  * writes go to `<dir>/tmp-<step>` then os.rename -> a crash mid-write can
    never corrupt the latest checkpoint (atomic on POSIX); stale `tmp-*`
    dirs left by a killed writer are swept at construction;
  * an async writer thread overlaps serialization/packing with training
    steps; the device->host snapshot (`np.asarray` per leaf) is the only
    work on the caller's critical path;
  * retention keeps the newest `keep` checkpoints;
  * restore() optionally device_puts leaves onto a target mesh/sharding.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

from . import qsave


def _path_key(path) -> str:
    """Stable string key for a pytree path entry: dict keys (DictKey.key),
    sequence indices (SequenceKey.idx) and dataclass-pytree fields like
    QTensor's (GetAttrKey.name) all round-trip through checkpoints."""
    parts = []
    for p in path:
        for attr in ("key", "idx", "name"):
            if hasattr(p, attr):
                parts.append(str(getattr(p, attr)))
                break
        else:
            parts.append(str(p))
    return "/".join(parts)


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {_path_key(path): np.asarray(leaf) for path, leaf in flat}


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True,
                 packed: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self.packed = packed
        os.makedirs(directory, exist_ok=True)
        # sweep staging dirs abandoned by a killed writer: they are never
        # restorable (publish is the rename) and a name collision with a
        # future save of the same step must start from a clean slate
        for name in os.listdir(directory):
            if name.startswith("tmp-"):
                shutil.rmtree(os.path.join(directory, name),
                              ignore_errors=True)
        self._lock = threading.Lock()
        self._pending: threading.Thread | None = None
        self._write_error: BaseException | None = None
        self._fail_next_write = False       # chaos hook: die before publish
        self.last_report: dict | None = None

    # ------------- save -------------

    def save(self, step: int, tree, aux: dict | None = None, block=False):
        """Snapshot on the caller thread (cheap host copy), write async."""
        arrays = _flatten_with_paths(tree)
        meta = {"step": int(step), "aux": aux or {},
                "time": time.time()}
        if self.async_write and not block:
            self.wait()
            t = threading.Thread(target=self._write_guarded,
                                 args=(step, arrays, meta), daemon=True)
            t.start()
            self._pending = t
        else:
            self._write(step, arrays, meta)

    def _write_guarded(self, step, arrays, meta):
        try:
            self._write(step, arrays, meta)
        except BaseException as e:  # noqa: BLE001 — surfaced by wait()
            self._write_error = e

    def _write(self, step, arrays, meta):
        with self._lock:
            tmp = os.path.join(self.dir, f"tmp-{step}")
            final = os.path.join(self.dir, f"step-{step:010d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            if self.packed:
                payload, fmt = qsave.pack_tree(arrays)
                meta = dict(meta, qsave=fmt, report=qsave.report(fmt))
            else:
                payload = arrays
            np.savez(os.path.join(tmp, "arrays.npz"), **payload)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if self._fail_next_write:       # simulated kill -9 mid-save:
                self._fail_next_write = False   # tmp written, never published
                raise RuntimeError(f"injected writer crash at step {step}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)           # atomic publish
            self.last_report = meta.get("report")
            self._gc()

    def wait(self):
        """Join the pending async write; re-raise a writer-thread failure
        (the caller's crash/restart loop owns the recovery policy)."""
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        if self._write_error is not None:
            e, self._write_error = self._write_error, None
            raise e

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step-{s:010d}"),
                          ignore_errors=True)

    # ------------- restore -------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step-"):
                out.append(int(name.split("-")[1]))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def meta(self, step: int | None = None) -> dict:
        """meta.json of a checkpoint (step/aux/time + qsave format/report)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step-{step:010d}")
        with open(os.path.join(d, "meta.json")) as f:
            return json.load(f)

    def size_report(self, step: int | None = None) -> dict:
        """qsave bytes-vs-dense-f32 report + actual on-disk bytes."""
        if step is None:
            step = self.latest_step()
        meta = self.meta(step)
        d = os.path.join(self.dir, f"step-{step:010d}")
        disk = sum(os.path.getsize(os.path.join(d, n)) for n in os.listdir(d))
        rep = dict(meta.get("report") or {})
        rep["disk_bytes"] = disk
        return rep

    def restore(self, target_tree, step: int | None = None, mesh=None,
                pspec_tree=None):
        """Restore into the structure of `target_tree` (arrays or
        ShapeDtypeStructs — only .shape/.dtype are read).

        If mesh+pspec_tree given, leaves are placed with those shardings —
        this is the elastic-rescale path: a checkpoint written under one
        mesh restores under any other.  Leaf dtypes follow the target tree
        on BOTH paths.  Returns (tree, step, aux).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step-{step:010d}")
        data = np.load(os.path.join(d, "arrays.npz"))
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        fmt = meta.get("qsave")

        flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        need = {_path_key(path) for path, _ in flat}
        have = set(fmt) if fmt is not None else set(data.files)
        if need != have:
            raise ValueError(
                f"checkpoint step {step} does not match the target tree: "
                f"missing keys {sorted(need - have)[:8]}, "
                f"unexpected keys {sorted(have - need)[:8]} "
                f"(checkpoint has {len(have)} arrays, target wants "
                f"{len(need)})")
        leaves = []
        specs = (jax.tree_util.tree_leaves(pspec_tree)
                 if pspec_tree is not None else [None] * len(flat))
        from jax.sharding import NamedSharding
        for (path, ref), spec in zip(flat, specs):
            key = _path_key(path)
            arr = (qsave.unpack_array(data, key, fmt[key])
                   if fmt is not None else data[key])
            if arr.shape != ref.shape:
                raise ValueError(f"checkpoint leaf {key}: shape {arr.shape} "
                                 f"!= target {ref.shape}")
            arr = arr.astype(ref.dtype)
            if mesh is not None and spec is not None:
                leaves.append(jax.device_put(arr, NamedSharding(mesh, spec)))
            else:
                leaves.append(jax.device_put(arr))
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, meta["step"], meta["aux"]
