"""Fault-tolerant checkpointing: atomic, async, retention, topology-agnostic.

Design (DESIGN.md §5):
  * every leaf is saved as a full logical array (npz) keyed by its pytree
    path -> restore works under ANY mesh/sharding (elastic re-scale);
  * writes go to `<dir>/tmp-<step>` then os.rename -> a crash mid-write can
    never corrupt the latest checkpoint (atomic on POSIX);
  * an async writer thread overlaps serialization with training steps;
  * retention keeps the newest `keep` checkpoints;
  * restore() optionally device_puts leaves onto a target mesh/sharding.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _path_key(path) -> str:
    """Stable string key for a pytree path entry: dict keys (DictKey.key),
    sequence indices (SequenceKey.idx) and dataclass-pytree fields like
    QTensor's (GetAttrKey.name) all round-trip through checkpoints."""
    parts = []
    for p in path:
        for attr in ("key", "idx", "name"):
            if hasattr(p, attr):
                parts.append(str(getattr(p, attr)))
                break
        else:
            parts.append(str(p))
    return "/".join(parts)


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {_path_key(path): np.asarray(leaf) for path, leaf in flat}


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._pending: threading.Thread | None = None

    # ------------- save -------------

    def save(self, step: int, tree, aux: dict | None = None, block=False):
        """Snapshot on the caller thread (cheap host copy), write async."""
        arrays = _flatten_with_paths(tree)
        meta = {"step": int(step), "aux": aux or {},
                "time": time.time()}
        if self.async_write and not block:
            self.wait()
            t = threading.Thread(target=self._write, args=(step, arrays,
                                                           meta), daemon=True)
            t.start()
            self._pending = t
        else:
            self._write(step, arrays, meta)

    def _write(self, step, arrays, meta):
        with self._lock:
            tmp = os.path.join(self.dir, f"tmp-{step}")
            final = os.path.join(self.dir, f"step-{step:010d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)           # atomic publish
            self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step-{s:010d}"),
                          ignore_errors=True)

    # ------------- restore -------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step-"):
                out.append(int(name.split("-")[1]))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target_tree, step: int | None = None, mesh=None,
                pspec_tree=None):
        """Restore into the structure of `target_tree`.

        If mesh+pspec_tree given, leaves are placed with those shardings —
        this is the elastic-rescale path: a checkpoint written under one
        mesh restores under any other.
        Returns (tree, step, aux).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step-{step:010d}")
        data = np.load(os.path.join(d, "arrays.npz"))
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)

        flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        leaves = []
        specs = (jax.tree_util.tree_leaves(pspec_tree)
                 if pspec_tree is not None else [None] * len(flat))
        from jax.sharding import NamedSharding
        for (path, ref), spec in zip(flat, specs):
            key = _path_key(path)
            arr = data[key]
            assert arr.shape == ref.shape, (key, arr.shape, ref.shape)
            if mesh is not None and spec is not None:
                leaves.append(jax.device_put(arr, NamedSharding(mesh, spec)))
            else:
                leaves.append(jax.device_put(arr.astype(ref.dtype)))
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, meta["step"], meta["aux"]
