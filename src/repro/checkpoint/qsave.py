"""QTensor-native checkpoint encoding: integers + pow2 exponents on disk.

The training state of this stack is integer-structured by construction
(DESIGN.md §11): after the first optimizer step every "w" param leaf lies
on the fixed 2^(1-k_WU) grid (Eq. 24), Momentum accumulators on the
2^(1-k_Acc) grid (Eq. 20), norm params on their 2^(1-k) grids, and QTensor
leaves (KV caches, wire payloads) already carry int8/int16 payloads with
pow2 scales.  The dense-f32 npz format threw that structure away — 4 bytes
per element regardless of information content.

`pack_tree` recovers it losslessly, per leaf:

  * integer/bool leaves (QTensor payloads, step counters) store as-is —
    never densified to f32;
  * float leaves are scanned for their exact pow2 grid (one frexp pass over
    the mantissas: the grid exponent is the minimum least-significant-bit
    exponent).  On-grid leaves store as `payload * 2^e` with the smallest
    integer container that holds the payload:
        |payload| <= 2^7-1   -> int8              (1 B/elem, 4x)
        |payload| <= 2^15-1  -> int16             (2 B/elem, 2x)
        |payload| <= 2^23-1  -> int8 hi + uint16 lo  (3 B/elem, 1.33x —
                                the k_WU=24 master-weight case)
        |payload| <= 2^31-1  -> int32
    off-grid leaves (fresh inits, exempt fp32 leaves) fall back to raw f32.

Every encoding is bit-exact on roundtrip: grid values n * 2^e with
|n| < 2^24 are exactly representable in f32, and the pack/unpack arithmetic
runs in f64 where both the product and the payload are exact.

`export_int8` is the separate LOSSY artifact: every float leaf quantized to
an int8 QTensor on its pow2-amax grid — the forward-pass weight payloads a
serving engine consumes, ~4x smaller than dense f32.  It is NOT the resume
format (the 24-bit masters floor a bit-exact checkpoint at ~1.3x for the
param plane; see DESIGN.md §11 for the information-theoretic accounting).
"""
from __future__ import annotations

import numpy as np

# fmt entry: {"enc": one of ENCODINGS, "e": grid exponent, "n": elem count,
#             "dtype": source dtype string}
ENCODINGS = ("raw", "i8", "i16", "hilo", "i32")

_LO_SUFFIX = "//lo"


def grid_exponent(a: np.ndarray):
    """(e, max_payload) for the exact pow2 grid of `a`, or (None, None).

    e is the largest exponent such that every finite value of `a` is an
    integer multiple of 2^e; max_payload = max|a| / 2^e.  Exact: computed
    from f64 frexp mantissas (f32 inputs are exact in f64).
    """
    flat = np.asarray(a, np.float64).reshape(-1)
    nz = flat[flat != 0.0]
    if nz.size == 0:
        return 0, 0
    if not np.isfinite(nz).all():
        return None, None
    m, ex = np.frexp(nz)                      # nz = m * 2^ex, |m| in [.5, 1)
    m24 = np.abs(m) * (2.0 ** 53)             # f64 mantissa as an integer
    v = m24.astype(np.int64)
    if not np.array_equal(v.astype(np.float64), m24):
        return None, None                     # not exactly integral (paranoia)
    tz = np.log2((v & -v).astype(np.float64)).astype(np.int64)
    lsb = ex - 53 + tz                        # per-element lsb exponent
    e = int(lsb.min())
    bits = int((ex.max() - e))                # magnitude bits of max payload
    if bits > 31:
        return None, None
    max_payload = int(np.abs(nz).max() * (2.0 ** -e))
    return e, max_payload


def pack_array(a: np.ndarray):
    """-> (dict of arrays to store, fmt entry).  Lossless by construction."""
    a = np.asarray(a)
    base = {"n": int(a.size), "dtype": str(a.dtype)}
    if a.dtype.kind in "iub":                 # integer payloads stay integers
        return {"": a}, dict(base, enc="raw")
    if a.dtype not in (np.float32, np.float64):
        return {"": a}, dict(base, enc="raw")   # bf16/f16: passthrough
    e, mp = grid_exponent(a)
    if e is None:
        return {"": a}, dict(base, enc="raw")
    p = np.round(np.asarray(a, np.float64) * (2.0 ** -e)).astype(np.int64)
    if mp <= 2 ** 7 - 1:
        return {"": p.astype(np.int8)}, dict(base, enc="i8", e=e)
    if mp <= 2 ** 15 - 1:
        return {"": p.astype(np.int16)}, dict(base, enc="i16", e=e)
    if mp <= 2 ** 23 - 1:                     # the k_WU=24 master-weight case
        hi = (p >> 16).astype(np.int8)
        lo = (p - (hi.astype(np.int64) << 16)).astype(np.uint16)
        return {"": hi, _LO_SUFFIX: lo}, dict(base, enc="hilo", e=e)
    return {"": p.astype(np.int32)}, dict(base, enc="i32", e=e)


def unpack_array(load, key: str, fmt: dict) -> np.ndarray:
    """Inverse of pack_array given the npz mapping and this key's fmt."""
    enc = fmt["enc"]
    a = load[key]
    if enc == "raw":
        return a
    if enc == "hilo":
        p = (a.astype(np.int64) << 16) + load[key + _LO_SUFFIX].astype(np.int64)
    else:
        p = a.astype(np.int64)
    v = p.astype(np.float64) * (2.0 ** fmt["e"])
    return v.astype(np.dtype(fmt["dtype"]))


def pack_tree(arrays: dict):
    """{key: np.ndarray} -> (npz payload dict, {key: fmt entry})."""
    out, fmt = {}, {}
    for key, a in arrays.items():
        stored, f = pack_array(a)
        for suffix, arr in stored.items():
            out[key + suffix] = arr
        fmt[key] = f
    return out, fmt


def stored_bytes(fmt_entry: dict) -> int:
    n = fmt_entry["n"]
    enc = fmt_entry["enc"]
    if enc == "raw":
        return n * np.dtype(fmt_entry["dtype"]).itemsize
    return n * {"i8": 1, "i16": 2, "hilo": 3, "i32": 4}[enc]


def report(fmt: dict) -> dict:
    """Bytes-vs-dense-f32 accounting, same shape as PagePool.report()."""
    q = sum(stored_bytes(f) for f in fmt.values())
    dense = sum(4 * f["n"] for f in fmt.values())
    encs = {}
    for f in fmt.values():
        encs[f["enc"]] = encs.get(f["enc"], 0) + 1
    return {"ckpt_bytes_q": q,
            "ckpt_bytes_f32_dense": dense,
            "ratio": dense / max(q, 1),
            "leaf_encodings": encs}


def export_int8(tree, k: int = 8):
    """Serving-export snapshot: float leaves -> int8 QTensors (LOSSY).

    Quantizes through the "scaled" registry quantizer (pow2-amax grid, the
    forward-pass Q_A semantics) so the payloads are exactly what an int8
    engine would compute from the dense weights.  Non-float leaves pass
    through.  Checkpointing the result stores ~1 byte/element (payloads are
    integer dtype -> `pack_array` raw path) vs 4 for dense f32.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.qtensor import get_quantizer

    qz = get_quantizer("scaled", k)

    def f(x):
        x = jnp.asarray(x)
        if not jnp.issubdtype(x.dtype, jnp.floating):
            return x
        return qz.quantize(x).drop_carrier()

    return jax.tree.map(f, tree)
