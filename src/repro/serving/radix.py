"""Prefix-sharing radix cache over the int8 page pool.

A radix tree over prompt token IDs, one edge per FULL page of tokens, whose
nodes resolve to refcounted pages in the `PagePool`.  The WAGEUBN memory
model makes this exact where fp caches cannot be: a page's KV payload is
int8 on a fixed pow2 grid and — under the CHUNKED prefill path, where the
page is the quantization unit — a bitwise-deterministic function of the
token prefix that produced it.  Two prompts sharing a page-aligned prefix
therefore produce byte-identical pages, so a cache hit is provably
identical to recompute (DESIGN.md §10).

Contract:
  * key       — page-granular token IDs, scoped by a `quant_key` string
                (quantizer spec + page geometry + pool scales).  Engines
                with different quantizer configs never share entries; the
                key is part of the cache identity, not checked per lookup.
  * lookup    — longest cached prefix in FULL pages; always leaves at
                least the last prompt token uncached so the engine has
                logits to sample the first token from.  Returns the page
                ids plus the deepest node's dense-state snapshot (recurrent
                families: mamba conv window + SSD state at the page
                boundary; pure-attention families store None).
  * insert    — publishes a finished prefill's full prompt pages.  The
                tree takes one pool ref per published page (copy-on-write
                discipline: shared pages are read-only by construction —
                decode and suffix prefill both write at positions past the
                shared prefix).  If a concurrent identical prefill already
                published a page, the caller's duplicate is reported back
                for dedup (swap tables to the cached page, drop the
                private copy).
  * eviction  — LRU over zero-refcount subtrees: a node is evictable when
                only the tree holds its page (pool refcount == 1), and
                because any request referencing a descendant also refs
                every ancestor, evictable nodes always form whole
                subtrees.  Eviction unrefs leaves inward.
  * defrag    — `remap()` rewrites node page ids against the pool's
                defrag mapping; each shared page moves exactly once.
"""
from __future__ import annotations

import numpy as np

from .pool import PagePool


class _Node:
    __slots__ = ("key", "page", "dense", "children", "parent", "last_use")

    def __init__(self, key, page, dense, parent):
        self.key = key                  # bytes of this edge's page tokens
        self.page = page                # physical pool page id
        self.dense = dense              # state snapshot after this page
        self.children: dict[bytes, _Node] = {}
        self.parent = parent
        self.last_use = 0


class RadixCache:
    """Page-granular prefix cache over a `PagePool` (see module docstring).

    Args:
      pool: the PagePool whose pages the tree references.
      quant_key: string identifying the quantizer config + page geometry
        this cache's entries were produced under (cache identity).
      store_dense: keep per-node dense-state snapshots (recurrent
        families); pure-attention families pass False and nodes hold None.
    """

    def __init__(self, pool: PagePool, quant_key: str = "",
                 store_dense: bool = False):
        self.pool = pool
        self.quant_key = quant_key
        self.store_dense = store_dense
        self.page_size = pool.page_size
        self.root = _Node(b"", 0, None, None)   # sentinel, never evicted
        self._tick = 0
        # accounting
        self.hit_pages = 0
        self.lookup_pages = 0
        self.lookups = 0
        self.inserted_pages = 0
        self.deduped_pages = 0
        self.evicted_pages = 0

    # ---- keys ------------------------------------------------------------

    def _page_keys(self, prompt) -> list[bytes]:
        """One bytes key per FULL page of the prompt."""
        p = self.page_size
        arr = np.asarray(prompt, np.int32)
        return [arr[i * p:(i + 1) * p].tobytes()
                for i in range(len(arr) // p)]

    def _match_limit(self, prompt) -> int:
        """Max pages a lookup may reuse: every full page, except the last
        one when the prompt is page-aligned — the engine must recompute at
        least the final prompt token to have logits for the first sample."""
        nb_full = len(prompt) // self.page_size
        if nb_full and len(prompt) % self.page_size == 0:
            return nb_full - 1
        return nb_full

    # ---- queries ---------------------------------------------------------

    def match_pages(self, prompt) -> int:
        """Longest cached prefix in pages — side-effect free (admission
        capacity probe; `lookup` is the consuming call)."""
        node, n = self.root, 0
        for key in self._page_keys(prompt)[: self._match_limit(prompt)]:
            node = node.children.get(key)
            if node is None:
                break
            n += 1
        return n

    def lookup(self, prompt) -> tuple[list[int], object | None]:
        """Longest cached prefix: ([page ids], deepest node's dense
        snapshot or None).  Touches the path for LRU; the CALLER takes the
        pool refs (one per returned page) when it commits to the hit."""
        self._tick += 1
        self.lookups += 1
        limit = self._match_limit(prompt)
        self.lookup_pages += len(prompt) // self.page_size
        node, pids = self.root, []
        for key in self._page_keys(prompt)[:limit]:
            child = node.children.get(key)
            if child is None:
                break
            child.last_use = self._tick
            pids.append(child.page)
            node = child
        self.hit_pages += len(pids)
        return pids, (node.dense if node is not self.root else None)

    @property
    def hit_rate(self) -> float:
        """Fraction of looked-up prompt pages served from the tree."""
        return self.hit_pages / self.lookup_pages if self.lookup_pages else 0.0

    # ---- publish ---------------------------------------------------------

    def insert(self, prompt, page_ids, dense_snaps=None) -> dict[int, int]:
        """Publish a finished prefill's full prompt pages.

        Args:
          prompt: the request's token ids; page_ids: its page table
            (page_ids[i] holds page i's KV); dense_snaps: per-page dense
            state snapshots (index-aligned with full pages) or None.

        Returns {block index: existing page id} for blocks where the tree
        ALREADY held an identical page (a concurrent duplicate prefill):
        the caller should swap its table to the cached page, take a ref on
        it, and unref its private copy — byte-identical by the chunked
        determinism contract, so the swap is invisible to the request.
        """
        self._tick += 1
        node, dedup = self.root, {}
        for i, key in enumerate(self._page_keys(prompt)):
            child = node.children.get(key)
            if child is None:
                snap = (dense_snaps[i] if (self.store_dense and dense_snaps)
                        else None)
                child = _Node(key, page_ids[i], snap, node)
                self.pool.ref(page_ids[i])          # the tree's own hold
                node.children[key] = child
                self.inserted_pages += 1
            elif child.page != page_ids[i]:
                dedup[i] = child.page               # duplicate: reuse cached
                self.deduped_pages += 1
            child.last_use = self._tick
            node = child
        return dedup

    # ---- eviction --------------------------------------------------------

    def _evictable_leaves(self) -> list[_Node]:
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if (n is not self.root and not n.children
                    and self.pool.refcount(n.page) == 1):
                out.append(n)
        return out

    def evictable(self) -> int:
        """Pages reclaimable by eviction right now: nodes only the tree
        holds.  (Request-referenced subtrees pin their ancestors, so the
        refcount==1 set IS the union of evictable subtrees.)"""
        stack, n = [self.root], 0
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if node is not self.root and self.pool.refcount(node.page) == 1:
                n += 1
        return n

    def evict(self, n_pages: int) -> int:
        """Free up to n_pages via LRU over evictable leaves (leaves-inward
        so parents become evictable as their subtrees drain).  Returns the
        number of pages actually returned to the pool."""
        freed = 0
        while freed < n_pages:
            leaves = self._evictable_leaves()
            if not leaves:
                break
            victim = min(leaves, key=lambda n: (n.last_use, n.page))
            self.pool.unref(victim.page)
            del victim.parent.children[victim.key]
            self.evicted_pages += 1
            freed += 1
        return freed

    def clear(self) -> int:
        """Drop every tree-only hold (testing / shutdown)."""
        return self.evict(self.pool.n_pages)

    # ---- maintenance -----------------------------------------------------

    def remap(self, mapping: dict[int, int]) -> None:
        """Rewrite node page ids after a pool defrag (old -> new)."""
        stack = [self.root]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if node is not self.root:
                node.page = mapping.get(node.page, node.page)

    @property
    def n_nodes(self) -> int:
        stack, n = [self.root], 0
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            n += 1
        return n - 1                                # minus the root sentinel

    def stats(self) -> dict:
        return {
            "nodes": self.n_nodes, "evictable": self.evictable(),
            "lookups": self.lookups, "hit_pages": self.hit_pages,
            "lookup_pages": self.lookup_pages, "hit_rate": self.hit_rate,
            "inserted_pages": self.inserted_pages,
            "deduped_pages": self.deduped_pages,
            "evicted_pages": self.evicted_pages,
        }
