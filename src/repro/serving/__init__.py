"""Continuous-batching int8 serving engine over a paged QTensor KV pool.

Layout (DESIGN.md §7):
  pool.py      — PagePool: int8 QTensor pages + free-list allocator + the
                 int8-vs-fp32 byte accounting
  scheduler.py — request lifecycle (QUEUED->PREFILL->DECODE->DONE),
                 admission control, recompute preemption
  engine.py    — Engine: fused jit decode over padded lanes, sampling,
                 per-request metrics, StepWatchdog wiring
  api.py       — make_engine + poisson_traffic/run_load/naive_serve
"""
from .engine import (Engine, fused_decode_active, greedy_token,
                     make_sampler)
from .pool import PagePool
from .scheduler import Request, RequestState, Scheduler
from .api import make_engine, naive_serve, poisson_traffic, run_load

__all__ = [
    "Engine", "fused_decode_active", "greedy_token", "make_sampler",
    "PagePool", "Request",
    "RequestState", "Scheduler", "make_engine", "naive_serve",
    "poisson_traffic", "run_load",
]
