"""Continuous-batching int8 serving engine over a paged QTensor KV pool.

Layout (DESIGN.md §7, §10):
  pool.py      — PagePool: refcounted int8 QTensor pages + free-list
                 allocator + the int8-vs-fp32 byte accounting
  radix.py     — RadixCache: prefix-sharing radix tree over the pool
                 (page-granular lookup/insert, LRU eviction, defrag remap)
  scheduler.py — request lifecycle (QUEUED->PREFILL->DECODE->DONE),
                 bounded-skip admission, recompute preemption
  engine.py    — Engine: fused jit decode over padded lanes, monolithic or
                 chunked prefill, sampling, per-request metrics,
                 StepWatchdog wiring
  router.py    — Router: load-aware + radix-affinity placement across
                 data-parallel replicas, kill-replica failure drains
  api.py       — make_engine/make_sharded_engine/make_router +
                 poisson_traffic/shared_prefix_traffic/run_load/naive_serve
"""
from .engine import (Engine, fused_decode_active, greedy_token,
                     make_sampler)
from .pool import PagePool
from .radix import RadixCache
from .scheduler import Request, RequestState, Scheduler
from .router import Router, RouterRequest
from .api import (make_engine, make_router, make_sharded_engine,
                  naive_serve, poisson_traffic, run_load,
                  shared_prefix_traffic)

__all__ = [
    "Engine", "fused_decode_active", "greedy_token", "make_sampler",
    "PagePool", "RadixCache", "Request",
    "RequestState", "Router", "RouterRequest", "Scheduler",
    "make_engine", "make_router", "make_sharded_engine", "naive_serve",
    "poisson_traffic", "run_load", "shared_prefix_traffic",
]
