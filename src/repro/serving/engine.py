"""Continuous-batching int8 serving engine.

One `Engine` drives one model family through the uniform decode-state slot
API (`decode_state_spec` / `init_slots` / `slot_from_cache` /
`paged_decode_step`): attention KV lives as int8 QTensor pages in a
`PagePool`, recurrent SSM state in dense per-lane slots — both behind the
same fused, jit-stable decode step over a padded batch of `max_lanes` lanes.

Control plane (host, numpy): `Scheduler` admission/preemption, per-lane
page tables, request bookkeeping.  Data plane (device, one trace): page
gather -> decode attention on int8 payloads -> token write-back into pages
-> sampling.  Dead lanes ride along masked (their table rows point at the
trash page and their positions never advance).

Per-step flow (Engine.step):
  1. admit new requests into free lanes (inflight batching: monolithic
     prefills join this very step's decode batch; chunked admissions start
     streaming prefill work)
  2. chunked mode only: run up to `prefill_budget` prompt tokens of
     prefill work — page-sized chunks through ONE jit-stable trace plus a
     ragged tail token-by-token — interleaved with decode so a long prompt
     never blocks running lanes for more than one budget's worth of work
  3. allocate decode pages at page boundaries; preempt the longest-context
     request when the pool is exhausted (recompute preemption)
  4. one fused decode step over all DECODE lanes (mid-prefill lanes ride
     along masked: their table rows zero to the trash page); append
     sampled tokens
  5. retire finished requests, unref their pages

Prefix sharing (DESIGN.md §10): with `radix_cache=True` the chunked
engine fronts the pool with a `RadixCache` — admission looks up the
longest page-aligned cached prefix (refs those pages instead of
recomputing them), finished prefills publish their full prompt pages, and
allocation pressure evicts LRU tree-only subtrees before preempting live
requests.  Chunked prefill makes the hits exact: the page is the
quantization unit, so a page's int8 payload is a bitwise-deterministic
function of its token prefix.

The decode loop performs exactly ONE jitted device computation per step
(asserted by tests/test_serving.py): the sampling key derives inside the
fused trace (fold_in of a host counter), the device page table re-uploads
only when the host copy changed, and the single host sync per step is the
sampled-token readback.

A `StepWatchdog` (runtime/fault.py) times every fused decode step; flagged
stragglers are logged and surface in `metrics()["straggler_steps"]`.
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.fault import StepWatchdog

from .pool import PagePool
from .radix import RadixCache
from .scheduler import Request, RequestState, Scheduler


def greedy_token(logits, vocab: int):
    """argmax over the unpadded vocab — THE greedy sampling primitive (the
    serve example / engine / naive baselines all share this slice)."""
    return jnp.argmax(logits[..., :vocab], axis=-1).astype(jnp.int32)


def make_sampler(vocab: int, temperature: float = 0.0, top_k: int = 0):
    """(logits (B, Vp), key) -> (B,) int32 token ids.

    temperature <= 0 is greedy (key ignored); otherwise softmax sampling at
    `temperature`, optionally restricted to the top-k logits.
    """
    if temperature <= 0.0:
        return lambda logits, key: greedy_token(logits, vocab)

    def sampler(logits, key):
        lg = logits[..., :vocab] / temperature
        if top_k:
            kth = jax.lax.top_k(lg, top_k)[0][..., -1:]
            lg = jnp.where(lg < kth, -jnp.inf, lg)
        return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)
    return sampler


class Engine:
    """Continuous-batching serving engine over the paged QTensor KV pool.

    Args:
      model: a built model exposing the decode-state slot API
        (`decode_state_spec` / `init_slots` / `slot_from_cache` /
        `paged_decode_step`); params: its parameter pytree.
      max_lanes: decode batch width (padded; dead lanes ride along masked).
      page_size: tokens per KV page; n_pages: pool size (default
        1 + max_lanes * ceil(max_ctx / page_size) — every lane can hold a
        full-context request); max_ctx: per-request prompt + generation cap.
      temperature/top_k: sampling policy (0.0 = greedy); seed: PRNG seed.
      prefill_mode: "monolithic" (default — whole prompt in one prefill
        call at admission) or "chunked" (page-sized chunks streamed
        through one jit-stable trace, interleaved with decode).
      prefill_chunk: pages per chunked-prefill trace invocation;
      prefill_budget: prompt tokens of prefill work per engine step
        (default prefill_chunk * page_size — one chunk's worth).
      radix_cache: front the pool with a prefix-sharing RadixCache
        (requires prefill_mode="chunked", where pages are bitwise-
        deterministic in their token prefix, and a paged family).
      max_skip / starvation_limit: bounded-skip admission policy knobs
        (see Scheduler).
      watchdog: StepWatchdog timing each fused step; clock: time source.

    Raises ValueError if the model family is not servable, the pool
    cannot hold one max-context request (the progress guarantee), or
    radix_cache is requested without chunked prefill / a paged pool.
    """

    def __init__(self, model, params, *, max_lanes: int = 4,
                 page_size: int = 8, n_pages: int | None = None,
                 max_ctx: int = 64, temperature: float = 0.0,
                 top_k: int = 0, seed: int = 0,
                 prefill_mode: str = "monolithic", prefill_chunk: int = 4,
                 prefill_budget: int | None = None,
                 radix_cache: bool = False, max_skip: int = 4,
                 starvation_limit: int = 8,
                 watchdog: StepWatchdog | None = None, clock=time.monotonic,
                 mesh=None):
        from repro.launch.train import (make_chunked_prefill_step,
                                        make_paged_decode_step,
                                        make_prefill_token_step,
                                        tp_serving_wrap)

        self.model, self.params = model, params
        self.clock = clock
        if not hasattr(model, "decode_state_spec"):
            raise ValueError(
                f"family {model.a.family!r} has no decode-state slot API "
                "(servable: lm / vlm / moe / ssm / hybrid)")
        spec = model.decode_state_spec()
        self.paged = spec["kv_layers"] > 0
        self.tp_size = int(getattr(model, "tp_size", 1) or 1)
        self.tp_mesh = mesh if self.tp_size > 1 else None
        if self.tp_size > 1:
            # TP decode runs the step fns under shard_map (DESIGN.md §12);
            # only the chunked prefill path is wrapped — monolithic prefill
            # would need a spec per prompt length, defeating the jit-stable
            # trace the sharded engine relies on.
            if prefill_mode != "chunked":
                raise ValueError(
                    "tp_size > 1 serving requires prefill_mode='chunked' "
                    "(the sharded engine wraps only the jit-stable chunked "
                    "traces in shard_map)")
            if mesh is None:
                raise ValueError(
                    "tp_size > 1 serving needs a ('data', 'model') mesh "
                    "passed as Engine(..., mesh=...); the model itself "
                    "builds WITHOUT one (manual TP — shard_map binds the "
                    "axis names, exactly like the sharded train step)")
        self.page_size = page_size
        self.max_ctx = max_ctx
        self.n_blocks = -(-max_ctx // page_size)

        self.pool = None
        if self.paged:
            if n_pages is None:
                n_pages = 1 + max_lanes * self.n_blocks
            self.pool = PagePool(n_pages, page_size, spec["kv_layers"],
                                 spec["n_kv"], spec["dh"])
            if self.pool.usable < self.n_blocks:
                raise ValueError(
                    f"pool of {n_pages} pages cannot hold one max_ctx="
                    f"{max_ctx} request ({self.n_blocks} pages needed)")
        self.scheduler = Scheduler(self.pool, max_skip=max_skip,
                                   starvation_limit=starvation_limit)
        self.watchdog = watchdog or StepWatchdog()

        self.max_lanes = max_lanes
        self.lane_req: list[Request | None] = [None] * max_lanes
        self.table = np.zeros((max_lanes, self.n_blocks), np.int32)
        self._table_dev = None          # device mirror, rebuilt when dirty
        self.h_tokens = np.zeros((max_lanes,), np.int32)
        self.slots = model.init_slots(max_lanes)
        self._dense_axes = spec["dense_axes"]

        self.key = jax.random.PRNGKey(seed)
        self._sample_ctr = 0
        sampler = make_sampler(model.a.vocab, temperature, top_k)
        # prefill sampling: the fold_in runs inside the jit, keyed by the
        # host counter — same key stream, one dispatch
        self._sample_jit = jax.jit(
            lambda logits, ctr: sampler(logits,
                                        jax.random.fold_in(self.key, ctr)))
        scales = ((self.pool.k_scale, self.pool.v_scale)
                  if self.paged else (None, None))
        self._decode_step = make_paged_decode_step(model, sampler, *scales,
                                                   key=self.key)
        if self.tp_size > 1:
            from jax.sharding import PartitionSpec as P

            import repro.launch.shard as S
            pspecs = S.tp_param_specs(model, params)
            slot_specs = S.decode_slot_specs(model, self.slots)
            pg = S.page_pool_spec(model) if self.paged else P()
            self._decode_step = tp_serving_wrap(
                self._decode_step, mesh,
                in_specs=(pspecs, slot_specs, pg, pg, P(), P(), P()),
                out_specs=(slot_specs, pg, pg, P()))
        self._decode_jit = jax.jit(self._decode_step,
                                   donate_argnums=(1, 2, 3))
        if self.paged:
            prefill = lambda p, t, n: model.prefill(p, t, n)  # noqa: E731
        else:
            prefill = lambda p, t, n: model.prefill(p, t)     # noqa: E731
        self._prefill_jit = jax.jit(prefill, static_argnums=(2,))

        # ---- chunked prefill + radix prefix cache (DESIGN.md §10) --------
        if prefill_mode not in ("monolithic", "chunked"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        self.prefill_mode = prefill_mode
        self.chunked = prefill_mode == "chunked"
        self.radix = None
        self._pf_dense: dict[int, object] = {}  # rid -> mid-prefill state
        if self.chunked:
            self.prefill_chunk = prefill_chunk
            self.prefill_budget = (prefill_budget
                                   or prefill_chunk * page_size)
            raw_chunk = make_chunked_prefill_step(model, prefill_chunk,
                                                  *scales)
            raw_tail = make_prefill_token_step(model, *scales)
            self._dense0 = model.init_slots(1)  # zero pf-state template
            if self.tp_size > 1:
                dense_specs = S.decode_slot_specs(model, self._dense0)
                # page snapshots stack the dense state on a leading chunk
                # axis, shifting every sharded axis right by one
                snap_specs = {k: P(*((None,) + tuple(s)))
                              for k, s in dense_specs.items()}
                raw_chunk = tp_serving_wrap(
                    raw_chunk, mesh,
                    in_specs=(pspecs, dense_specs, pg, pg, P(), P(),
                              P(), P()),
                    out_specs=(dense_specs, pg, pg, P(), snap_specs))
                raw_tail = tp_serving_wrap(
                    raw_tail, mesh,
                    in_specs=(pspecs, dense_specs, pg, pg, P(), P(), P()),
                    out_specs=(dense_specs, pg, pg, P()))
            self._chunk_jit = jax.jit(raw_chunk, donate_argnums=(2, 3))
            self._tail_jit = jax.jit(raw_tail, donate_argnums=(2, 3))
            self._warmup()
        if radix_cache:
            if not self.chunked:
                raise ValueError(
                    "radix_cache requires prefill_mode='chunked' (only the "
                    "page-scoped quantization of chunked prefill makes "
                    "cached pages bitwise-exact in their token prefix)")
            if not self.paged:
                raise ValueError(
                    f"radix_cache needs a paged KV family "
                    f"(got {model.a.family!r})")
            self.radix = RadixCache(
                self.pool,
                quant_key=f"{model.a.family}/page{page_size}/{model.q}",
                store_dense=len(self._dense_axes) > 1)
            self.scheduler.cache = self.radix

        # metrics
        self.engine_steps = 0
        self.decode_steps = 0
        self.decode_wall_s = 0.0
        self.straggler_steps = 0

    # ---- submission ------------------------------------------------------

    def submit(self, prompt, max_new: int, arrival: float | None = None):
        """Queue one request.

        Args:
          prompt: (S,) int token ids (any array-like; flattened to int32);
          max_new: generation budget >= 1; arrival: submission timestamp on
          the engine clock (defaults to now — TTFT is measured from it).

        Returns:
          The request id (int), usable as the key into `drain()`'s result.

        Raises ValueError on an empty prompt, max_new < 1, or
        S + max_new > max_ctx.
        """
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) == 0 or max_new < 1:
            raise ValueError("need a non-empty prompt and max_new >= 1")
        if len(prompt) + max_new > self.max_ctx:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({max_new}) exceeds "
                f"max_ctx ({self.max_ctx})")
        req = self.scheduler.submit(
            prompt, max_new, self.clock() if arrival is None else arrival)
        return req.rid

    # ---- engine step -----------------------------------------------------

    def step(self) -> list[Request]:
        """One engine step: admit+prefill, ensure pages, fused decode.

        Returns:
          The requests that finished during this step (their `.generated`
          lists hold the sampled tokens).  One fused decode trace covers
          all live lanes; fresh admissions join the same step's batch.
        """
        finished = []
        free = [ln for ln, r in enumerate(self.lane_req) if r is None]
        for req in self.scheduler.admit(len(free)):
            if self.chunked:
                self._admit_chunked(req, free.pop(0))
            else:
                self._admit(req, free.pop(0))
                if req.done:             # max_new == 1: prefill completed it
                    self._release(req)
                    finished.append(req)

        if self.chunked:
            finished.extend(self._run_prefill_chunks())

        if self.paged:
            self._ensure_pages()

        live = [ln for ln, r in enumerate(self.lane_req)
                if r is not None and r.state is RequestState.DECODE]
        if live:
            t0 = time.monotonic()
            toks = self._decode()
            dt = time.monotonic() - t0
            self.decode_wall_s += dt
            if self.watchdog.observe(self.decode_steps, dt):
                self.straggler_steps += 1
            self.decode_steps += 1
            for ln in live:
                req = self.lane_req[ln]
                tok = int(toks[ln])
                req.generated.append(tok)
                self.h_tokens[ln] = tok
                if req.done:
                    self._release(req)
                    finished.append(req)
        self.engine_steps += 1
        now = self.clock()
        for req in finished:
            self.scheduler.finish(req, now)
        return finished

    def drain(self, max_steps: int = 100_000) -> dict[int, list[int]]:
        """Step until every submitted request completes.

        Returns:
          {request id: [generated token ids]} for all DONE requests.

        Raises RuntimeError if the queue has not emptied after max_steps.
        """
        for _ in range(max_steps):
            if (not self.scheduler.queue
                    and all(r is None for r in self.lane_req)):
                break
            self.step()
        else:
            raise RuntimeError(f"drain did not finish in {max_steps} steps")
        return {r.rid: list(r.generated)
                for r in self.scheduler.requests.values()
                if r.state is RequestState.DONE}

    # ---- admission / release / preemption --------------------------------

    def _admit(self, req: Request, lane: int) -> None:
        if req.queue_s is None:         # TTFT split: time spent QUEUED
            req.queue_s = self.clock() - req.arrival
        s = len(req.prompt)
        nb = 0
        if self.paged:
            nb = self.scheduler.pages_needed(req)  # prompt + 1 decode block
            req.page_ids = self.pool.alloc(nb, owner=req.rid)
            assert req.page_ids is not None     # admission checked capacity
        cache_len = nb * self.page_size
        cache, logits = self._prefill_jit(
            self.params, jnp.asarray(req.prompt)[None], cache_len)
        dense, kv = self.model.slot_from_cache(cache, 0)
        self.slots = _write_dense(self.slots, self._dense_axes,
                                  jnp.int32(lane), dense)
        if self.paged:
            pids = jnp.asarray(req.page_ids)
            k_req, v_req = kv                   # (L, nb*page, KV, dh) int8
            shp = (k_req.shape[0], nb, self.page_size) + k_req.shape[2:]
            self.pool.k = _scatter_pages(self.pool.k, pids,
                                         k_req.reshape(shp))
            self.pool.v = _scatter_pages(self.pool.v, pids,
                                         v_req.reshape(shp))
            self.table[lane] = 0
            self.table[lane, :nb] = req.page_ids
            self._table_dev = None

        tok0 = int(self._sample_jit(logits, self._next_ctr())[0])
        req.generated.append(tok0)
        if req.ttft is None:
            req.ttft = self.clock() - req.arrival
            req.prefill_s = req.ttft - req.queue_s
        req.lane = lane
        req.state = RequestState.DECODE
        self.lane_req[lane] = req
        self.h_tokens[lane] = tok0

    def _release(self, req: Request) -> None:
        if self.paged and req.page_ids:
            for pid in req.page_ids:    # shared pages just drop our hold
                self.pool.unref(pid)
        self._pf_dense.pop(req.rid, None)
        if req.lane >= 0:
            self.table[req.lane] = 0
            self.lane_req[req.lane] = None
            self._table_dev = None
        req.page_ids = []
        req.lane = -1

    def _preempt(self, req: Request) -> None:
        self._release(req)
        self.scheduler.preempt(req)

    def _alloc_pages(self, n: int, req: Request) -> list[int] | None:
        """Allocate under pressure: radix LRU eviction first, recompute
        preemption second.  Returns None iff `req` itself got preempted."""
        pid = self.pool.alloc(n, owner=req.rid)
        while pid is None and self.radix is not None \
                and self.radix.evictable() > 0:
            self.radix.evict(n - self.pool.free_count)
            pid = self.pool.alloc(n, owner=req.rid)
        while pid is None:
            live = [r for r in self.lane_req if r is not None]
            if not live:
                raise RuntimeError(
                    f"pool exhausted with no live lanes to preempt "
                    f"(need {n} pages, free {self.pool.free_count})")
            victim = self.scheduler.pick_victim(live)
            self._preempt(victim)
            if victim is req:
                return None
            pid = self.pool.alloc(n, owner=req.rid)
        return pid

    def _ensure_pages(self) -> None:
        """Grow DECODE lanes' page tables at block boundaries (mid-prefill
        lanes preallocated everything at admission); evict radix subtrees,
        then preempt, on exhaustion."""
        for lane in range(self.max_lanes):
            req = self.lane_req[lane]
            if req is None or req.state is not RequestState.DECODE:
                continue
            blk = req.pos // self.page_size
            if blk < len(req.page_ids):
                continue
            pid = self._alloc_pages(1, req)
            if pid is None:          # this lane itself was preempted
                continue
            self.table[lane, blk] = pid[0]
            self._table_dev = None
            req.page_ids.extend(pid)

    # ---- chunked prefill + radix prefix cache (DESIGN.md §10) ------------

    def _warmup(self) -> None:
        """Compile the chunked engine's traces ahead of the first request.

        Unlike monolithic prefill (whose jit is keyed on every distinct
        prompt length), the chunked engine runs FOUR shape-stable traces —
        chunk prefill, tail token, fused decode, sampling — so all of its
        compilation can happen at construction instead of inside the first
        requests' TTFT.  The warmup calls write only to the trash page
        (all-zero tables, n_pages=0 masks every chunk page) and the decode
        slots re-initialize after, so no observable state survives."""
        zrow = jnp.zeros((1, self.n_blocks), jnp.int32)
        toks = jnp.zeros((self.prefill_chunk * self.page_size,), jnp.int32)
        _, kp, vp, lg, _ = self._chunk_jit(
            self.params, self._dense0, *self._pages_for_jit(), zrow,
            toks, np.int32(0), np.int32(0))
        self._store_pages(kp, vp)
        _, kp, vp, _ = self._tail_jit(
            self.params, self._dense0, *self._pages_for_jit(), zrow,
            jnp.zeros((1,), jnp.int32), jnp.zeros((1,), jnp.int32))
        self._store_pages(kp, vp)
        # the ctr=0 key is never used live (the counter pre-increments)
        self._sample_jit(lg, np.int32(0))
        slots = dict(self.slots, pos=jnp.zeros((self.max_lanes,), jnp.int32))
        _, kp, vp, _ = self._decode_jit(
            self.params, slots, *self._pages_for_jit(),
            jnp.asarray(self.table), jnp.asarray(self.h_tokens),
            np.int32(0))
        self._store_pages(kp, vp)
        self.slots = self.model.init_slots(self.max_lanes)

    def _admit_chunked(self, req: Request, lane: int) -> None:
        """Claim a lane and pages; prefill streams in later engine steps.

        Radix lookup first: the longest cached page-aligned prefix is
        reused by reference (one pool ref per hit page), only the suffix
        pages are allocated, and for recurrent families the deepest node's
        dense snapshot seeds the mid-prefill state."""
        if req.queue_s is None:
            req.queue_s = self.clock() - req.arrival
        s = len(req.prompt)
        hit_pids, hit_dense = [], None
        if self.radix is not None:
            hit_pids, hit_dense = self.radix.lookup(req.prompt)
            for pid in hit_pids:
                self.pool.ref(pid)      # the request's hold on the hit
        req.n_shared = len(hit_pids)
        req.pf_pos = req.n_shared * self.page_size
        req.page_snaps = [None] * (s // self.page_size)
        if self.paged:
            nb_total = s // self.page_size + 1   # prompt + 1 decode block
            new_pids = self._alloc_pages(nb_total - req.n_shared, req)
            assert new_pids is not None  # not in lane_req yet: no self-kill
            req.page_ids = list(hit_pids) + new_pids
            self.table[lane] = 0
            self.table[lane, :nb_total] = req.page_ids
            self._table_dev = None
        self._pf_dense[req.rid] = (hit_dense if hit_dense is not None
                                   else self._dense0)
        req.lane = lane
        self.lane_req[lane] = req       # PREFILL state: masked in decode

    def _run_prefill_chunks(self) -> list[Request]:
        """Advance every mid-prefill lane by up to `prefill_budget` prompt
        tokens: full pages through the chunked trace (page-scoped
        quantization — the radix determinism unit), then the ragged tail
        token-by-token through the decode body.  Completing lanes sample
        their first token and publish their pages to the radix tree."""
        finished: list[Request] = []
        budget = self.prefill_budget
        page = self.page_size
        for lane in range(self.max_lanes):
            if budget <= 0:
                break
            req = self.lane_req[lane]
            if req is None or req.state is not RequestState.PREFILL:
                continue
            s = len(req.prompt)
            nb_full = s // page
            lg = None
            while budget >= page and req.pf_pos < nb_full * page:
                start = req.pf_pos // page
                allowed = min(self.prefill_chunk, nb_full - start,
                              budget // page)
                toks = np.zeros((self.prefill_chunk * page,), np.int32)
                chunk = req.prompt[start * page:(start + allowed) * page]
                toks[:len(chunk)] = chunk
                dn, kp, vp, lg, snaps = self._chunk_jit(
                    self.params, self._pf_dense[req.rid],
                    *self._pages_for_jit(), self._lane_table(lane),
                    jnp.asarray(toks), np.int32(start),
                    np.int32(start + allowed))
                self._store_pages(kp, vp)
                self._pf_dense[req.rid] = dn
                if self.radix is not None and self.radix.store_dense:
                    for j in range(allowed):
                        req.page_snaps[start + j] = jax.tree.map(
                            lambda a, j=j: a[j], snaps)
                req.pf_pos = (start + allowed) * page
                budget -= allowed * page
            while budget >= 1 and nb_full * page <= req.pf_pos < s:
                dn, kp, vp, lg = self._tail_jit(
                    self.params, self._pf_dense[req.rid],
                    *self._pages_for_jit(), self._lane_table(lane),
                    jnp.asarray(req.prompt[req.pf_pos:req.pf_pos + 1]),
                    jnp.full((1,), req.pf_pos, jnp.int32))
                self._store_pages(kp, vp)
                self._pf_dense[req.rid] = dn
                req.pf_pos += 1
                budget -= 1
            if req.pf_pos >= s:         # lg is this lane's final logits
                self._finish_prefill(req, lane, lg)
                if req.done:             # max_new == 1
                    self._release(req)
                    finished.append(req)
        return finished

    def _finish_prefill(self, req: Request, lane: int, logits) -> None:
        """Prefill done: sample the first token, move the mid-prefill dense
        state into the lane's decode slot, flip to DECODE, and publish the
        full prompt pages to the radix tree (deduping against concurrent
        identical prefills that published first)."""
        tok0 = int(self._sample_jit(logits, self._next_ctr())[0])
        req.generated.append(tok0)
        if req.ttft is None:
            req.ttft = self.clock() - req.arrival
            req.prefill_s = req.ttft - req.queue_s
        dense = self._pf_dense.pop(req.rid)
        self.slots = _write_dense(self.slots, self._dense_axes,
                                  jnp.int32(lane),
                                  _squeeze_dense(dense, self._dense_axes))
        req.state = RequestState.DECODE
        self.h_tokens[lane] = tok0
        self._table_dev = None          # lane unmasks in the decode table
        if self.radix is not None:
            nb_full = len(req.prompt) // self.page_size
            if nb_full:
                dedup = self.radix.insert(req.prompt,
                                          req.page_ids[:nb_full],
                                          req.page_snaps)
                for blk, cached in dedup.items():
                    self.pool.ref(cached)           # byte-identical page:
                    self.pool.unref(req.page_ids[blk])  # swap to cached
                    req.page_ids[blk] = cached
                    self.table[lane, blk] = cached
        req.page_snaps = []

    def _lane_table(self, lane: int):
        """One lane's page-table row as the (1, NB) view the B=1 prefill
        traces expect."""
        return jnp.asarray(self.table[lane:lane + 1])

    def _pages_for_jit(self):
        if self.paged:
            return self.pool.k, self.pool.v
        return jnp.zeros((0,), jnp.int8), jnp.zeros((0,), jnp.int8)

    def _store_pages(self, kp, vp) -> None:
        if self.paged:
            self.pool.k, self.pool.v = kp, vp

    # ---- fused decode ----------------------------------------------------

    def _decode(self) -> np.ndarray:
        pos = np.zeros((self.max_lanes,), np.int32)
        for ln, req in enumerate(self.lane_req):
            if req is not None and req.state is RequestState.DECODE:
                pos[ln] = req.pos
        slots = dict(self.slots, pos=jnp.asarray(pos))
        if self.paged:
            kp, vp = self.pool.k, self.pool.v
        else:       # distinct dummies: donated args must not alias
            kp = jnp.zeros((0,), jnp.int8)
            vp = jnp.zeros((0,), jnp.int8)
        if self._table_dev is None:     # re-upload only when tables changed
            # mid-prefill lanes decode masked: their rows point at the
            # trash page so the ride-along writes never touch real pages
            mask = np.array([r is not None
                             and r.state is not RequestState.DECODE
                             for r in self.lane_req])
            eff = self.table
            if mask.any():
                eff = self.table.copy()
                eff[mask] = 0
            self._table_dev = jnp.asarray(eff)
        new_slots, new_k, new_v, toks = self._decode_jit(
            self.params, slots, kp, vp, self._table_dev,
            jnp.asarray(self.h_tokens), self._next_ctr())
        self.slots = new_slots
        if self.paged:
            self.pool.k, self.pool.v = new_k, new_v
        # THE one host-device sync of the decode loop: the token readback
        return np.asarray(toks)

    def _next_ctr(self) -> np.int32:
        """Sampling-counter tick: the PRNG fold_in happens inside the jitted
        computations (same key stream as the legacy host-side fold)."""
        self._sample_ctr += 1
        return np.int32(self._sample_ctr)

    # ---- maintenance / metrics -------------------------------------------

    def defrag(self) -> int:
        """Compact pool pages; rewrites live page tables.  Returns moves."""
        if not self.paged:
            return 0
        mapping = self.pool.defrag()
        if mapping:
            trans = np.arange(self.pool.n_pages)
            for old, new in mapping.items():
                trans[old] = new
            self.table = trans[self.table].astype(np.int32)
            self._table_dev = None
            for req in self.lane_req:
                if req is not None:
                    req.page_ids = [int(trans[p]) for p in req.page_ids]
            if self.radix is not None:  # shared pages moved exactly once
                self.radix.remap(mapping)
        return len(mapping)

    def decode_jaxpr(self):
        """jaxpr of the fused decode step at this engine's exact shapes
        (introspection for tests / the serve bench's fusion check).

        Traces through a fresh wrapper so the inspection trace never
        shares jax's tracing cache with the live `_decode_jit` — callers
        (fused_decode_active) retrace under a patched dispatch, and a
        shared cache would hand the engine a kernel-route trace it cannot
        compile on CPU (or hand the caller the stale oracle-route one).
        """
        slots = dict(self.slots, pos=jnp.zeros((self.max_lanes,), jnp.int32))
        if self.paged:
            kp, vp = self.pool.k, self.pool.v
        else:
            kp = jnp.zeros((0,), jnp.int8)
            vp = jnp.zeros((0,), jnp.int8)
        fresh = lambda *a: self._decode_step(*a)  # noqa: E731
        return jax.make_jaxpr(fresh)(
            self.params, slots, kp, vp, jnp.asarray(self.table),
            jnp.asarray(self.h_tokens), np.int32(0))

    def metrics(self) -> dict:
        """Engine aggregates + per-request rollups.

        Returns a dict with: engine_steps, decode_steps, decode_wall_s,
        completed, generated_tokens, queue_depth, live_lanes, preemptions,
        skips (bounded-skip queue jumps), straggler_steps, ttft_mean_s /
        ttft_max_s and the TTFT split queue_ms_mean / prefill_ms_mean
        (over DONE requests), decode_tok_s, "pool" (the PagePool.report()
        dict) when paged, and "radix" (RadixCache.stats()) +
        prefix_hit_rate when the radix cache is on.
        """
        done = [r for r in self.scheduler.requests.values()
                if r.state is RequestState.DONE]
        ttfts = [r.ttft for r in done if r.ttft is not None]
        queues = [r.queue_s for r in done if r.queue_s is not None]
        prefills = [r.prefill_s for r in done if r.prefill_s is not None]
        # TPOT: decode time per generated token after the first (TTFT owns
        # the first token), per request — the tail-latency complement
        tpots = [(r.finish - r.arrival - r.ttft) / (len(r.generated) - 1)
                 for r in done
                 if r.finish is not None and r.ttft is not None
                 and len(r.generated) > 1]

        def pct(vals, q):
            return float(np.percentile(vals, q)) if vals else 0.0

        gen = sum(len(r.generated) for r in done)
        out = {
            "engine_steps": self.engine_steps,
            "decode_steps": self.decode_steps,
            "decode_wall_s": self.decode_wall_s,
            "completed": len(done),
            "generated_tokens": gen,
            "queue_depth": self.scheduler.queue_depth,
            "live_lanes": sum(r is not None for r in self.lane_req),
            "preemptions": self.scheduler.preemptions,
            "skips": self.scheduler.skips,
            "straggler_steps": self.straggler_steps,
            "ttft_mean_s": float(np.mean(ttfts)) if ttfts else 0.0,
            "ttft_max_s": float(np.max(ttfts)) if ttfts else 0.0,
            "ttft_p50_s": pct(ttfts, 50),
            "ttft_p99_s": pct(ttfts, 99),
            "tpot_mean_s": float(np.mean(tpots)) if tpots else 0.0,
            "tpot_p50_s": pct(tpots, 50),
            "tpot_p99_s": pct(tpots, 99),
            "queue_ms_mean": 1e3 * float(np.mean(queues)) if queues else 0.0,
            "prefill_ms_mean": (1e3 * float(np.mean(prefills))
                                if prefills else 0.0),
            "decode_tok_s": (gen / self.decode_wall_s
                             if self.decode_wall_s > 0 else 0.0),
        }
        if self.pool is not None:
            out["pool"] = self.pool.report(ctx_len=self.max_ctx)
        if self.radix is not None:
            out["radix"] = self.radix.stats()
            out["prefix_hit_rate"] = self.radix.hit_rate
        return out


def _write_dense(slots, axes, lane, vals):
    """Write one lane's dense decode state (batch axis differs per key)."""
    out = dict(slots)
    for name, ax in axes.items():
        if ax == 0:
            out[name] = slots[name].at[lane].set(vals[name])
        else:
            out[name] = slots[name].at[:, lane].set(vals[name])
    return out


def _squeeze_dense(dense, axes):
    """Drop the size-1 lane dim of a B=1 prefill-state tree so the values
    land in a lane slot via `_write_dense` (which indexes, not slices)."""
    return {name: (dense[name][0] if ax == 0 else dense[name][:, 0])
            for name, ax in axes.items()}


@partial(jax.jit, donate_argnums=(0,))
def _scatter_pages(pages, pids, chunk):
    """pages (L, P, page, KV, dh) <- chunk (L, nb, page, KV, dh) at pids."""
    return pages.at[:, pids].set(chunk)


def fused_decode_active(engine: Engine) -> bool:
    """Whether the engine's decode step streams KV pages through the fused
    paged-attention kernel (True) or fell back to gather-then-attend
    (False, e.g. sim mode or `fuse_kernels=False`).

    Decided from the decode-step jaxpr with the kernel dispatch forced, so
    the route is visible regardless of backend (the CPU oracle of the
    fused op gathers internally, which would otherwise mask it): the
    gather route materializes a dense per-lane KV view — an int8
    intermediate of shape (B, NB, page, KV, dh) / (B, NB*page, KV, dh)
    outside any pallas body — while the fused route never does.
    `benchmarks/serve_bench.py` reports this and CI fails on a silent
    fallback.
    """
    from repro.kernels import ops
    if not engine.paged:
        return False
    spec = engine.model.decode_state_spec()
    kv, dh = spec["n_kv"], spec["dh"]
    b, nb, page = engine.max_lanes, engine.n_blocks, engine.page_size
    dense = {(b, nb, page, kv, dh), (b, nb * page, kv, dh)}
    orig = ops._on_tpu
    ops._on_tpu = lambda: True
    try:
        jaxpr = engine.decode_jaxpr()
    finally:
        ops._on_tpu = orig
    for _, shape, dtype in ops.eqns_outside_pallas(jaxpr.jaxpr):
        if shape in dense and dtype == jnp.int8:
            return False
    return True
