"""Load-aware multi-replica request router (DESIGN.md §12).

A `Router` fronts N independent serving replicas — each an `Engine` with
its own PagePool, RadixCache and continuous-batching scheduler (and, under
TP, its own ("data", "model") mesh slice) — and places every incoming
request by:

  1. radix affinity — if any replica's radix tree already caches a
     page-aligned prefix of the prompt, route to the replica with the
     LONGEST cached prefix (shared-prefix traffic lands where its pages
     already live, so the hit is a reference, not a recompute);
  2. load — otherwise the replica with the most free pool pages, breaking
     ties by fewest outstanding requests (queue + live lanes), then by
     lowest replica index.

Both rules read only scheduler/pool state, so placement is a DETERMINISTIC
function of the submission sequence — replayed traffic routes identically
(asserted by tests/test_sharded_serving.py).

Engine request ids are per-engine counters and collide across replicas, so
the router owns its own id space and maps router-rid -> (replica,
engine-rid).

Failure drains reuse the recompute-preemption pattern: killing a replica
folds every outstanding request's generated tokens into its prompt and
resubmits the remainder on a survivor, then stitches pre-kill and
post-kill tokens back together — every request still completes with its
exact token budget, conditioned on everything it already emitted (token
VALUES across the fold carry DESIGN.md §7's amax-composition caveat, same
as engine preemption).  The
`_kill_replica` attribute is the chaos hook (same pattern as
checkpoint/manager.py's `_fail_next_write`): set it to a replica index and
the next `step()` executes the kill.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from .engine import Engine


@dataclass
class RouterRequest:
    """Router-side request record (the stitched cross-replica view)."""
    rid: int
    prompt: np.ndarray
    max_new: int
    arrival: float
    replica: int = -1                   # current / last placement
    engine_rid: int = -1                # id within that replica's engine
    generated: list = field(default_factory=list)
    ttft: float | None = None
    finish: float | None = None
    evacuations: int = 0                # replica deaths survived
    done: bool = False


class _SchedView:
    """Duck-type the scheduler surface `run_load` reads off an Engine."""

    def __init__(self, router: "Router"):
        self._r = router

    @property
    def queue(self):
        return [q for e in self._r.live_replicas() for q in e.scheduler.queue]

    @property
    def requests(self):
        return self._r.requests


class Router:
    """Route requests across serving replicas; aggregate fleet metrics.

    Args:
      replicas: list of independently constructed Engines (each owns its
        pool/scheduler/radix; under TP each was built on its own mesh).
      clock: shared time source (the replicas should use the same one so
        TTFT/TPOT aggregate on one axis).

    The Engine-compatible surface (submit/step/drain/metrics, plus the
    `scheduler`/`lane_req`/`clock` attributes `run_load` duck-types) lets
    every existing load harness drive a replica fleet unchanged.
    """

    def __init__(self, replicas: list[Engine], clock=time.monotonic):
        if not replicas:
            raise ValueError("Router needs at least one replica")
        self.replicas = list(replicas)
        self.clock = clock
        self.dead: set[int] = set()
        self.requests: dict[int, RouterRequest] = {}
        self._live: dict[tuple[int, int], RouterRequest] = {}
        self._next_rid = 0
        self.placements: list[int] = []   # replica index per submission
        self.scheduler = _SchedView(self)
        self._kill_replica: int | None = None  # chaos hook: die at next step
        self.kills = 0
        self.requeues = 0

    # ---- replica views ---------------------------------------------------

    def live_replicas(self) -> list[Engine]:
        return [e for i, e in enumerate(self.replicas) if i not in self.dead]

    @property
    def lane_req(self):
        return [r for e in self.live_replicas() for r in e.lane_req]

    # ---- placement -------------------------------------------------------

    def _affinity(self, idx: int, prompt) -> int:
        eng = self.replicas[idx]
        if eng.radix is None:
            return 0
        return eng.radix.match_pages(prompt)

    def _load_key(self, idx: int):
        eng = self.replicas[idx]
        free = eng.pool.free_count if eng.pool is not None else 1 << 30
        outstanding = (eng.scheduler.queue_depth
                       + sum(r is not None for r in eng.lane_req))
        return (-free, outstanding, idx)

    def place(self, prompt) -> int:
        """Deterministic placement: radix affinity first, load second."""
        alive = [i for i in range(len(self.replicas)) if i not in self.dead]
        if not alive:
            raise RuntimeError("all replicas dead")
        hits = {i: self._affinity(i, prompt) for i in alive}
        best_hit = max(hits.values())
        if best_hit > 0:
            alive = [i for i in alive if hits[i] == best_hit]
        return min(alive, key=self._load_key)

    # ---- submission / stepping -------------------------------------------

    def submit(self, prompt, max_new: int,
               arrival: float | None = None) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        arrival = self.clock() if arrival is None else arrival
        rid = self._next_rid
        self._next_rid += 1
        req = RouterRequest(rid=rid, prompt=prompt, max_new=max_new,
                            arrival=arrival)
        self.requests[rid] = req
        self._dispatch(req, prompt, max_new, arrival)
        self.placements.append(req.replica)
        return rid

    def _dispatch(self, req: RouterRequest, prompt, max_new: int,
                  arrival: float) -> None:
        idx = self.place(prompt)
        erid = self.replicas[idx].submit(prompt, max_new, arrival=arrival)
        req.replica, req.engine_rid = idx, erid
        self._live[(idx, erid)] = req

    def step(self) -> list[RouterRequest]:
        """One fleet step: honor a pending kill, then step every live
        replica (index order — determinism), fold finished engine requests
        into their router records."""
        if self._kill_replica is not None:
            idx, self._kill_replica = self._kill_replica, None
            self.kill_replica(idx)
        finished: list[RouterRequest] = []
        for i, eng in enumerate(self.replicas):
            if i in self.dead:
                continue
            for er in eng.step():
                req = self._live.pop((i, er.rid), None)
                if req is None:
                    continue
                req.generated.extend(er.generated)
                if req.ttft is None:
                    req.ttft = er.ttft
                req.finish = self.clock()
                req.done = True
                finished.append(req)
        return finished

    def drain(self, max_steps: int = 100_000) -> dict[int, list[int]]:
        """Step until every routed request completes; {rid: tokens}."""
        for _ in range(max_steps):
            if all(r.done for r in self.requests.values()):
                break
            self.step()
        else:
            raise RuntimeError(f"drain did not finish in {max_steps} steps")
        return {r.rid: list(r.generated) for r in self.requests.values()
                if r.done}

    # ---- failure drain ---------------------------------------------------

    def kill_replica(self, idx: int) -> int:
        """Drop a replica and requeue its outstanding work on survivors.

        Every in-flight request folds its already-generated tokens into the
        prompt (the scheduler's recompute-preemption move) and resubmits
        the remaining budget elsewhere; queued requests resubmit whole.
        Returns the number of requests evacuated.
        """
        if idx in self.dead:
            return 0
        self.dead.add(idx)
        self.kills += 1
        stranded = [(key, req) for key, req in self._live.items()
                    if key[0] == idx]
        moved = 0
        for key, req in stranded:
            del self._live[key]
            er = self.replicas[idx].scheduler.requests.get(req.engine_rid)
            pre = list(er.generated) if er is not None else []
            if er is not None and req.ttft is None:
                req.ttft = er.ttft          # first token predates the kill
            req.generated.extend(pre)
            req.evacuations += 1
            remaining = req.max_new - len(req.generated)
            if remaining <= 0:
                req.finish = self.clock()
                req.done = True
                continue
            folded = (np.concatenate([req.prompt,
                                      np.asarray(req.generated, np.int32)])
                      if req.generated else req.prompt)
            self._dispatch(req, folded, remaining, req.arrival)
            self.requeues += 1
            moved += 1
        return moved

    # ---- metrics ---------------------------------------------------------

    def metrics(self) -> dict:
        """Fleet aggregates: engine-metric sums plus router-level tail
        latency (p50/p99 TTFT and TPOT over ROUTED requests — the numbers
        a client of the fleet would observe) and placement counters."""
        done = [r for r in self.requests.values() if r.done]
        ttfts = [r.ttft for r in done if r.ttft is not None]
        tpots = [(r.finish - r.arrival - r.ttft) / (len(r.generated) - 1)
                 for r in done
                 if r.finish is not None and r.ttft is not None
                 and len(r.generated) > 1]

        def pct(vals, q):
            return float(np.percentile(vals, q)) if vals else 0.0

        reps = [e.metrics() for e in self.live_replicas()]
        wall = sum(m["decode_wall_s"] for m in reps)
        gen = sum(len(r.generated) for r in done)
        out = {
            "replicas": len(self.replicas),
            "replicas_dead": len(self.dead),
            "completed": len(done),
            "generated_tokens": gen,
            "decode_steps": sum(m["decode_steps"] for m in reps),
            "decode_wall_s": wall,
            # replicas decode concurrently: fleet throughput sums each
            # replica's own rate rather than dividing by summed wall time
            "decode_tok_s": sum(m["decode_tok_s"] for m in reps),
            "queue_depth": sum(m["queue_depth"] for m in reps),
            "preemptions": sum(m["preemptions"] for m in reps),
            "kills": self.kills,
            "requeues": self.requeues,
            "ttft_mean_s": float(np.mean(ttfts)) if ttfts else 0.0,
            "ttft_p50_s": pct(ttfts, 50),
            "ttft_p99_s": pct(ttfts, 99),
            "tpot_mean_s": float(np.mean(tpots)) if tpots else 0.0,
            "tpot_p50_s": pct(tpots, 50),
            "tpot_p99_s": pct(tpots, 99),
            "placements": [self.placements.count(i)
                           for i in range(len(self.replicas))],
        }
        hits = [m.get("prefix_hit_rate") for m in reps
                if "prefix_hit_rate" in m]
        if hits:
            lookups = sum(m["radix"]["lookups"] for m in reps
                          if "radix" in m)
            hit_pages = sum(m["radix"]["hit_pages"] for m in reps
                            if "radix" in m)
            out["prefix_hit_rate"] = (hit_pages / lookups
                                      if lookups else 0.0)
        return out
