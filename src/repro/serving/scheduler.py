"""Request lifecycle + continuous-batching scheduler policy.

State machine (DESIGN.md §7):

    QUEUED -> PREFILL -> DECODE -> DONE
                ^          |
                +-- preempt (recompute): pages freed, generated tokens fold
                    into the prompt, request requeues at the FRONT

Pure control plane: no jax here.  The scheduler decides *which* requests
run; the engine owns the device arrays and executes the decisions.

Policies:
  * admission — FIFO with BOUNDED SKIP: a request is admitted when a lane
    is free and the pool (free pages + radix-evictable pages, minus the
    prefix pages a cache hit would cover) can fund its prompt pages plus
    the first decode page.  Up to `max_skip` queued requests that don't
    fit may be jumped by smaller ones behind them — killing the
    head-of-line blocking a single huge prompt used to impose — but every
    jump increments the skipped request's counter, and once a request has
    been skipped `starvation_limit` times nothing passes it until it
    admits (the progress guarantee: pool >= one max-ctx request, so the
    head always eventually fits).
  * inflight batching — admissions happen every step, so fresh prefills
    join the running decode batch immediately.
  * preemption — on pool exhaustion the longest-context live request is
    victim (it frees the most pages and is closest to done per page spent).
    Recompute-style: its generated tokens are folded into the prompt and it
    re-prefills later, reproducing the exact decode state.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from enum import Enum

import numpy as np


class RequestState(Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # int32 (S,) — grows on recompute preempt
    max_new: int                    # total generation target
    arrival: float
    state: RequestState = RequestState.QUEUED
    generated: list = field(default_factory=list)
    lane: int = -1
    page_ids: list = field(default_factory=list)
    ttft: float | None = None       # first-token latency (first admission)
    queue_s: float | None = None    # TTFT split: submit -> first admission
    prefill_s: float | None = None  # TTFT split: admission -> first token
    finish: float | None = None
    preemptions: int = 0
    skipped: int = 0                # admissions that jumped this request
    n_folded: int = 0               # generated tokens recompute folded into
                                    # the prompt (don't double count)
    # chunked-prefill progress (engine-owned, reset on preemption)
    pf_pos: int = 0                 # prompt tokens already prefilled
    n_shared: int = 0               # prefix pages served by the radix cache
    page_snaps: list = field(default_factory=list)  # per-page dense snaps

    @property
    def ctx_len(self) -> int:
        return len(self.prompt) + len(self.generated) - self.n_folded

    @property
    def pos(self) -> int:
        """Next KV write position.  After prefill over S tokens with one
        sampled token, decode writes that token's KV at position S == the
        context length minus one; each later step advances by one."""
        return self.ctx_len - 1

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new


class Scheduler:
    """Queue + lifecycle bookkeeping; policies as documented above."""

    def __init__(self, pool=None, max_skip: int = 4,
                 starvation_limit: int = 8):
        self.pool = pool
        self.cache = None               # RadixCache (engine wires it up)
        self.max_skip = max_skip
        self.starvation_limit = starvation_limit
        self.queue: deque[Request] = deque()
        self.requests: dict[int, Request] = {}
        self._ids = itertools.count()
        self.admitted = 0
        self.preemptions = 0
        self.skips = 0                  # total queue jumps

    def submit(self, prompt: np.ndarray, max_new: int,
               arrival: float) -> Request:
        req = Request(next(self._ids), np.asarray(prompt, np.int32),
                      int(max_new), arrival)
        self.requests[req.rid] = req
        self.queue.append(req)
        return req

    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    def pages_needed(self, req: Request) -> int:
        """Prompt pages + the first decode page, minus the prefix pages a
        radix-cache hit would serve (shared pages cost only a ref)."""
        nb = len(req.prompt) // self.pool.page_size + 1
        if self.cache is not None:
            nb -= self.cache.match_pages(req.prompt)
        return nb

    def admissible(self, req: Request, free_lanes: int,
                   committed_pages: int = 0) -> bool:
        """`committed_pages` reserves pages already promised to earlier
        admissions in the same wave (they allocate after this check).
        Radix-evictable pages count as free: the engine evicts
        least-recently-used cache subtrees on allocation pressure."""
        if free_lanes <= 0:
            return False
        if self.pool is None:
            return True
        free = self.pool.free_count - committed_pages
        if self.cache is not None:
            # matched-prefix pages may themselves be tree-only (evictable)
            # right now, but committing to the hit refs them — don't count
            # the same page as both "served by the cache" and "reclaimable"
            free += max(0, self.cache.evictable()
                        - self.cache.match_pages(req.prompt))
        return free >= self.pages_needed(req)

    def admit(self, free_lanes: int) -> list[Request]:
        """Pop admissible requests for this step's prefill wave.

        Bounded-skip FIFO: scans past up to `max_skip` queued requests
        that don't currently fit, admitting later ones that do.  Every
        request jumped this way gets `.skipped += 1`; a request skipped
        `starvation_limit` times becomes a hard barrier no one passes.
        `max_skip=0` is strict FIFO (the pre-skip policy).
        """
        out: list[Request] = []
        committed, passed = 0, []
        idx = 0
        while idx < len(self.queue) and len(out) < free_lanes:
            req = self.queue[idx]
            if self.admissible(req, free_lanes - len(out), committed):
                del self.queue[idx]
                req.state = RequestState.PREFILL
                if self.pool is not None:
                    committed += self.pages_needed(req)
                out.append(req)
                self.admitted += 1
                for r in passed:
                    r.skipped += 1
                    self.skips += 1
            elif (len(passed) >= self.max_skip
                  or req.skipped >= self.starvation_limit):
                break
            else:
                passed.append(req)
                idx += 1
        return out

    def pick_victim(self, live: list[Request]) -> Request:
        """Longest context frees the most pages."""
        return max(live, key=lambda r: (r.ctx_len, r.rid))

    def preempt(self, req: Request) -> None:
        """Recompute preemption: fold generated into the prompt, requeue at
        the front so the victim reclaims capacity as soon as it exists."""
        req.prompt = np.concatenate(
            [req.prompt,
             np.asarray(req.generated[req.n_folded:], np.int32)])
        req.n_folded = len(req.generated)
        req.state = RequestState.QUEUED
        req.lane = -1
        req.page_ids = []
        req.pf_pos = 0
        req.n_shared = 0
        req.page_snaps = []
        req.preemptions += 1
        self.preemptions += 1
        self.queue.appendleft(req)

    def finish(self, req: Request, now: float) -> None:
        req.state = RequestState.DONE
        req.finish = now
