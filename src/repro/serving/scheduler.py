"""Request lifecycle + continuous-batching scheduler policy.

State machine (DESIGN.md §7):

    QUEUED -> PREFILL -> DECODE -> DONE
                ^          |
                +-- preempt (recompute): pages freed, generated tokens fold
                    into the prompt, request requeues at the FRONT

Pure control plane: no jax here.  The scheduler decides *which* requests
run; the engine owns the device arrays and executes the decisions.

Policies:
  * admission — FIFO; a request is admitted when a lane is free and the
    pool can cover its prompt pages plus the first decode page.  Head-of-
    line blocking is deliberate (no starvation of long prompts).
  * inflight batching — admissions happen every step, so fresh prefills
    join the running decode batch immediately.
  * preemption — on pool exhaustion the longest-context live request is
    victim (it frees the most pages and is closest to done per page spent).
    Recompute-style: its generated tokens are folded into the prompt and it
    re-prefills later, reproducing the exact decode state.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from enum import Enum

import numpy as np


class RequestState(Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # int32 (S,) — grows on recompute preempt
    max_new: int                    # total generation target
    arrival: float
    state: RequestState = RequestState.QUEUED
    generated: list = field(default_factory=list)
    lane: int = -1
    page_ids: list = field(default_factory=list)
    ttft: float | None = None       # first-token latency (first admission)
    finish: float | None = None
    preemptions: int = 0
    n_folded: int = 0               # generated tokens recompute folded into
                                    # the prompt (don't double count)

    @property
    def ctx_len(self) -> int:
        return len(self.prompt) + len(self.generated) - self.n_folded

    @property
    def pos(self) -> int:
        """Next KV write position.  After prefill over S tokens with one
        sampled token, decode writes that token's KV at position S == the
        context length minus one; each later step advances by one."""
        return self.ctx_len - 1

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new


class Scheduler:
    """Queue + lifecycle bookkeeping; policies as documented above."""

    def __init__(self, pool=None):
        self.pool = pool
        self.queue: deque[Request] = deque()
        self.requests: dict[int, Request] = {}
        self._ids = itertools.count()
        self.admitted = 0
        self.preemptions = 0

    def submit(self, prompt: np.ndarray, max_new: int,
               arrival: float) -> Request:
        req = Request(next(self._ids), np.asarray(prompt, np.int32),
                      int(max_new), arrival)
        self.requests[req.rid] = req
        self.queue.append(req)
        return req

    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    def pages_needed(self, req: Request) -> int:
        """Prompt pages + the first decode page."""
        return len(req.prompt) // self.pool.page_size + 1

    def admissible(self, req: Request, free_lanes: int,
                   committed_pages: int = 0) -> bool:
        """`committed_pages` reserves pages already promised to earlier
        admissions in the same wave (they allocate after this check)."""
        if free_lanes <= 0:
            return False
        if self.pool is None:
            return True
        return (self.pool.free_count - committed_pages
                >= self.pages_needed(req))

    def admit(self, free_lanes: int) -> list[Request]:
        """Pop FIFO-admissible requests for this step's prefill wave."""
        out, committed = [], 0
        while self.queue and self.admissible(self.queue[0],
                                             free_lanes - len(out),
                                             committed):
            req = self.queue.popleft()
            req.state = RequestState.PREFILL
            if self.pool is not None:
                committed += self.pages_needed(req)
            out.append(req)
            self.admitted += 1
        return out

    def pick_victim(self, live: list[Request]) -> Request:
        """Longest context frees the most pages."""
        return max(live, key=lambda r: (r.ctx_len, r.rid))

    def preempt(self, req: Request) -> None:
        """Recompute preemption: fold generated into the prompt, requeue at
        the front so the victim reclaims capacity as soon as it exists."""
        req.prompt = np.concatenate(
            [req.prompt,
             np.asarray(req.generated[req.n_folded:], np.int32)])
        req.n_folded = len(req.generated)
        req.state = RequestState.QUEUED
        req.lane = -1
        req.page_ids = []
        req.preemptions += 1
        self.preemptions += 1
        self.queue.appendleft(req)

    def finish(self, req: Request, now: float) -> None:
        req.state = RequestState.DONE
        req.finish = now
