"""Serving API surface: engine construction, synthetic traffic, load tests.

    from repro.serving import make_engine, poisson_traffic, run_load

    engine = make_engine("granite-3-8b", mode="native", max_lanes=4)
    traffic = poisson_traffic(rate=8.0, n_requests=12,
                              prompt_lens=(8, 16, 24), gen_lens=(4, 8))
    results, metrics = run_load(engine, traffic)

`poisson_traffic` is an open-loop generator: exponential inter-arrival
gaps at `rate` req/s with mixed prompt/generation lengths — the staggered
pattern that makes continuous batching pay.  `shared_prefix_traffic`
biases a fraction of prompts onto common page-aligned prefixes (the
system-prompt pattern the radix cache exploits).  `run_load` replays
traffic against
the engine's clock without closing the loop on completions, and
`naive_serve` is the sequential one-request-at-a-time baseline the ISSUE's
acceptance criterion compares against.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .engine import Engine, greedy_token
from .router import Router


def make_engine(arch: str, *, mode: str = "native", preset_name: str = "full8",
                reduced: bool = True, seed: int = 0,
                fuse_kernels: bool = True, **engine_kw) -> Engine:
    """Build (arch config, params, Engine) in one call; returns the Engine
    with `.model`/`.params` attached for callers that need them.
    `fuse_kernels=False` pins the unfused gather-then-attend decode route
    (bit-exact either way; the serve bench times both)."""
    from repro.configs import get
    from repro.core import preset
    from repro.models import build_model

    acfg = get(arch)
    if reduced:
        acfg = acfg.reduced()
    model = build_model(acfg, preset(preset_name, mode)
                        .replace(fuse_kernels=fuse_kernels))
    params = model.init(jax.random.PRNGKey(seed))
    return Engine(model, params, **engine_kw)


def make_sharded_engine(arch: str, *, tp: int = 1, mesh=None,
                        mode: str = "native", preset_name: str = "full8",
                        reduced: bool = True, seed: int = 0,
                        fuse_kernels: bool = True, **engine_kw) -> Engine:
    """`make_engine` with manual tensor parallelism: the model builds with
    `tp_size=tp` on a (1, tp) ("data", "model") mesh (constructed here if
    not supplied) and the engine runs its decode / chunked-prefill steps
    under shard_map with int8 KV pages head-sharded per rank (DESIGN.md
    §12).  tp > 1 requires chunked prefill — forced here."""
    from repro.configs import get
    from repro.core import preset
    from repro.launch.mesh import make_cpu_mesh
    from repro.models import build_model

    acfg = get(arch)
    if reduced:
        acfg = acfg.reduced()
    if tp > 1:
        if mesh is None:
            mesh = make_cpu_mesh(1, tp)
        engine_kw.setdefault("prefill_mode", "chunked")
        engine_kw["mesh"] = mesh
    # manual TP: the model builds WITHOUT a mesh (same as the sharded train
    # step) — shard_map in the engine binds the axis names
    model = build_model(acfg, preset(preset_name, mode)
                        .replace(fuse_kernels=fuse_kernels), tp_size=tp)
    params = model.init(jax.random.PRNGKey(seed))
    return Engine(model, params, **engine_kw)


def make_router(arch: str, *, replicas: int = 2, tp: int = 1,
                mode: str = "native", preset_name: str = "full8",
                reduced: bool = True, seed: int = 0,
                fuse_kernels: bool = True, **engine_kw) -> Router:
    """Build a `replicas`-way data-parallel serving tier behind a Router.

    Every replica is an independent engine (own PagePool / RadixCache /
    scheduler) built from the SAME seed, so greedy tokens are placement-
    invariant; under tp > 1 each replica gets its own disjoint (1, tp)
    device group.  Drives through `run_load` unchanged."""
    from repro.launch.mesh import make_replica_meshes

    meshes = (make_replica_meshes(replicas, tp) if tp > 1
              else [None] * replicas)
    engines = [make_sharded_engine(arch, tp=tp, mesh=m, mode=mode,
                                   preset_name=preset_name, reduced=reduced,
                                   seed=seed, fuse_kernels=fuse_kernels,
                                   **engine_kw)
               for m in meshes]
    return Router(engines, clock=engines[0].clock)


def poisson_traffic(rate: float, n_requests: int,
                    prompt_lens=(8, 16, 24), gen_lens=(4, 8, 12),
                    vocab: int = 128, seed: int = 0) -> list[dict]:
    """Open-loop Poisson arrivals with mixed lengths.

    Returns [{"arrival": seconds-from-start, "prompt": int32 array,
    "max_new": int}, ...] sorted by arrival.  Prompt lengths draw from a
    small discrete set so the engine's per-length prefill traces stay
    bounded (the jit cache is keyed on prompt shape).
    """
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    out = []
    for i in range(n_requests):
        s = int(rng.choice(prompt_lens))
        out.append({
            "arrival": float(arrivals[i]),
            "prompt": rng.integers(0, vocab, size=s).astype(np.int32),
            "max_new": int(rng.choice(gen_lens)),
        })
    return out


def shared_prefix_traffic(rate: float, n_requests: int, sharing: float = 0.5,
                          prefix_len: int = 16, n_prefixes: int = 2,
                          tail_lens=(4, 8), gen_lens=(4, 8),
                          vocab: int = 128, seed: int = 0) -> list[dict]:
    """Poisson arrivals where a `sharing` fraction of prompts open with one
    of `n_prefixes` common prefixes of `prefix_len` tokens (the system-
    prompt / few-shot-template pattern the radix cache exploits); the rest
    draw a fresh random prefix of the same length.  Keep `prefix_len` a
    multiple of the engine's page_size so the shared prefix is publishable
    page-for-page.  Same row format as `poisson_traffic`.
    """
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, vocab, size=prefix_len).astype(np.int32)
                for _ in range(n_prefixes)]
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    out = []
    for i in range(n_requests):
        tail = rng.integers(0, vocab,
                            size=int(rng.choice(tail_lens))).astype(np.int32)
        if rng.random() < sharing:
            head = prefixes[int(rng.integers(n_prefixes))]
        else:
            head = rng.integers(0, vocab, size=prefix_len).astype(np.int32)
        out.append({
            "arrival": float(arrivals[i]),
            "prompt": np.concatenate([head, tail]),
            "max_new": int(rng.choice(gen_lens)),
        })
    return out


def run_load(engine: Engine, traffic: list[dict],
             max_steps: int = 100_000) -> tuple[dict, dict]:
    """Replay open-loop traffic against the engine.

    Requests are submitted when the engine clock passes their arrival
    offset; when the engine is idle ahead of the next arrival it sleeps
    briefly instead of spinning.  Returns ({rid: tokens}, metrics).
    """
    t0 = engine.clock()
    pending = sorted(traffic, key=lambda r: r["arrival"])
    i = 0
    for _ in range(max_steps):
        now = engine.clock() - t0
        while i < len(pending) and pending[i]["arrival"] <= now:
            r = pending[i]
            engine.submit(r["prompt"], r["max_new"],
                          arrival=t0 + r["arrival"])
            i += 1
        idle = (not engine.scheduler.queue
                and all(ln is None for ln in engine.lane_req))
        if idle:
            if i >= len(pending):
                break
            time.sleep(min(pending[i]["arrival"] - now, 0.002))
            continue
        engine.step()
    else:
        raise RuntimeError(f"load did not finish in {max_steps} steps")
    results = {r.rid: list(r.generated)
               for r in engine.scheduler.requests.values()}
    return results, engine.metrics()


def naive_serve(model, params, traffic: list[dict]) -> tuple[list, dict]:
    """Sequential baseline: one request at a time, raw prefill + serve_step.

    No batching, no paging — the loop `examples/serve_int8.py --legacy`
    runs, measured the same way the engine is.  Returns (token lists,
    {"wall_s", "decode_steps", "decode_tok_s", "generated_tokens"}).
    """
    a = model.a
    prefill = jax.jit(
        (lambda p, t, n: model.prefill(p, t))
        if a.family == "ssm" else (lambda p, t, n: model.prefill(p, t, n)),
        static_argnums=(2,))
    step = jax.jit(model.serve_step)
    outs, decode_steps, decode_wall = [], 0, 0.0
    t0 = time.monotonic()
    for r in traffic:
        prompt = jnp.asarray(r["prompt"], jnp.int32)[None]
        cache, logits = prefill(params, prompt,
                                int(prompt.shape[1]) + int(r["max_new"]))
        tok = greedy_token(logits, a.vocab)
        gen = [int(tok[0])]
        td = time.monotonic()
        for _ in range(r["max_new"] - 1):
            cache, logits = step(params, cache, tok)
            tok = greedy_token(logits, a.vocab)
            gen.append(int(tok[0]))
            decode_steps += 1
        decode_wall += time.monotonic() - td
        outs.append(gen)
    wall = time.monotonic() - t0
    total = sum(len(g) for g in outs)
    return outs, {"wall_s": wall, "decode_steps": decode_steps,
                  "decode_wall_s": decode_wall, "generated_tokens": total,
                  "decode_tok_s": (total / decode_wall
                                   if decode_wall > 0 else 0.0)}
