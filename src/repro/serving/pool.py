"""Paged QTensor KV-cache pool: fixed-size int8 pages + pow2 scales.

The WAGEUBN serving memory model (DESIGN.md §7): all resident KV state is
int8 payload on a power-of-two grid, cut into fixed-size pages so lanes with
different context lengths share one physical arena with no per-request
reservation.  A free-list block allocator hands out logical pages; one
logical page owns that block's storage across ALL layers, so the device
arrays are (L, P, page, KV, dh) and the per-layer slice scans cleanly.

Page id 0 is the trash page: dead lanes' page tables point at it, their
decode writes collide there harmlessly, and the attention mask never reads
it.  The allocator therefore hands out ids 1..P-1.

Pages are REFCOUNTED so the radix prefix cache (radix.py) can share one
physical page between the tree and any number of live requests:
`alloc` hands a page out with refcount 1, `ref`/`unref` adjust it, and the
page returns to the free list only when the count hits zero.  The strict
`free` entry point refuses shared pages (refcount > 1) — a shared page can
only die by every holder unreffing it, which is what makes double-free and
use-after-free structurally impossible for cache hits (DESIGN.md §10).

Accounting proves the int8 story: `report()` compares the resident int8
footprint against the fp32 cache the same geometry would need — the ~4x
byte ratio is exactly ~4x more resident sequences at a fixed HBM budget.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


class PagePool:
    """Physical page arena + free-list allocator + accounting."""

    def __init__(self, n_pages: int, page_size: int, kv_layers: int,
                 n_kv: int, dh: int, scale: float = 2.0 ** -7):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the trash page)")
        self.n_pages = n_pages
        self.page_size = page_size
        self.kv_layers, self.n_kv, self.dh = kv_layers, n_kv, dh
        shape = (kv_layers, n_pages, page_size, n_kv, dh)
        self.k = jnp.zeros(shape, jnp.int8)
        self.v = jnp.zeros(shape, jnp.int8)
        self.k_scale = jnp.full((kv_layers,), scale, jnp.float32)
        self.v_scale = jnp.full((kv_layers,), scale, jnp.float32)
        # free list (LIFO for reuse locality); id 0 reserved as trash
        self._free = list(range(n_pages - 1, 0, -1))
        self._owner: dict[int, object] = {}
        self._refs: dict[int, int] = {}      # live page -> refcount (>= 1)
        # accounting
        self.allocs = 0
        self.frees = 0
        self.failed_allocs = 0
        self.peak_in_use = 0
        self.defrag_moves = 0

    # ---- allocator -------------------------------------------------------

    @property
    def usable(self) -> int:
        return self.n_pages - 1

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.usable - self.free_count

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def alloc(self, n: int, owner=None) -> list[int] | None:
        """Pop n pages off the free list, or None (no partial allocation).
        Each page comes out with refcount 1 (the allocating owner)."""
        if n > self.free_count:
            self.failed_allocs += 1
            return None
        ids = [self._free.pop() for _ in range(n)]
        for pid in ids:
            self._owner[pid] = owner
            self._refs[pid] = 1
        self.allocs += n
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return ids

    # ---- refcounts (shared prefix pages, DESIGN.md §10) ------------------

    def refcount(self, pid: int) -> int:
        """Total holders of a live page (0 for free pages / the trash)."""
        return self._refs.get(pid, 0)

    def ref(self, pid: int) -> None:
        """Add a holder to an allocated page (radix hit / tree publish)."""
        if pid not in self._refs:
            raise ValueError(f"ref of unallocated page {pid}")
        self._refs[pid] += 1

    def unref(self, pid: int) -> bool:
        """Drop one holder; the page frees when the count reaches zero.
        Returns True iff this call returned the page to the free list."""
        if pid not in self._refs:
            raise ValueError(f"unref of unallocated page {pid}")
        self._refs[pid] -= 1
        if self._refs[pid] > 0:
            return False
        del self._refs[pid]
        self._owner.pop(pid, None)
        self._free.append(pid)
        self.frees += 1
        return True

    def free(self, ids) -> None:
        """Strict release: every page must be exclusively held (refcount 1).
        Shared pages must be `unref`ed by each holder instead."""
        for pid in ids:
            if pid == 0 or pid in self._free:
                raise ValueError(f"double free / trash free of page {pid}")
            if self._refs.get(pid, 1) > 1:
                raise ValueError(
                    f"free of shared page {pid} "
                    f"({self._refs[pid] - 1} outstanding refs); use unref")
            self._refs.pop(pid, None)
            self._owner.pop(pid, None)
            self._free.append(pid)
        self.frees += len(ids)

    # ---- defrag ----------------------------------------------------------

    def defrag(self) -> dict[int, int]:
        """Compact live pages to the lowest physical ids.

        Payloads move (one gather per arena), owners keep their pages under
        new ids.  A SHARED page (refcount > 1) moves exactly once — the
        mapping carries one entry per physical page no matter how many
        holders reference it, and every holder (lane tables, request
        page-id lists, radix tree nodes) rewrites against that one entry.
        Returns the old->new id mapping so callers rewrite their page
        tables; identity entries are omitted.
        """
        live = sorted(self._refs)
        mapping = {old: new for new, old in enumerate(live, start=1)
                   if old != new}
        if mapping:
            src = np.arange(self.n_pages)
            for old, new in mapping.items():
                src[new] = old
            src = jnp.asarray(src)
            self.k = jnp.take(self.k, src, axis=1)
            self.v = jnp.take(self.v, src, axis=1)
            self._owner = {mapping.get(p, p): o
                           for p, o in self._owner.items()}
            self._refs = {mapping.get(p, p): c
                          for p, c in self._refs.items()}
            self._free = list(range(self.n_pages - 1, len(live), -1))
            self.defrag_moves += len(mapping)
        return mapping

    # ---- byte accounting -------------------------------------------------

    def report(self, ctx_len: int | None = None) -> dict:
        """int8-vs-fp32 footprint: same geometry, fp32 payloads instead.

        `capacity_seqs_*` counts resident sequences of `ctx_len` tokens that
        fit in THIS pool's byte budget under each payload dtype — the int8
        cache's 4x byte saving is 4x more lanes on the same HBM.
        """
        page_elems = (self.kv_layers * self.page_size * self.n_kv * self.dh)
        int8_bytes = 2 * self.n_pages * page_elems          # k + v, 1 B/elem
        scale_bytes = 2 * self.kv_layers * 4
        fp32_bytes = 4 * int8_bytes                          # same geometry
        rep = {
            "n_pages": self.n_pages, "page_size": self.page_size,
            "in_use": self.in_use, "free": self.free_count,
            "shared_pages": sum(c > 1 for c in self._refs.values()),
            "peak_in_use": self.peak_in_use,
            "allocs": self.allocs, "frees": self.frees,
            "failed_allocs": self.failed_allocs,
            "defrag_moves": self.defrag_moves,
            "pool_bytes_int8": int8_bytes + scale_bytes,
            "pool_bytes_fp32_equiv": fp32_bytes,
            "footprint_ratio": fp32_bytes / (int8_bytes + scale_bytes),
        }
        if ctx_len:
            per_seq = self.pages_for(ctx_len)
            budget = int8_bytes + scale_bytes
            fp32_pages = budget // (4 * 2 * page_elems)
            rep["capacity_seqs_int8"] = self.usable // per_seq
            rep["capacity_seqs_fp32"] = max(0, fp32_pages - 1) // per_seq
        return rep
