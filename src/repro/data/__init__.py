from .synthetic import (TokenTask, ImageTask, make_global_batch,
                        host_local_slice)

__all__ = ["TokenTask", "ImageTask", "make_global_batch", "host_local_slice"]
