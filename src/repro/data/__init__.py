from .imagenet import NpzImageTask, resolve_image_task, write_demo_dataset
from .synthetic import (TokenTask, ImageTask, make_global_batch,
                        host_local_slice)

__all__ = ["TokenTask", "ImageTask", "NpzImageTask", "make_global_batch",
           "host_local_slice", "resolve_image_task", "write_demo_dataset"]
