"""Real-data ImageNet-style input pipeline (npz shards on disk).

The paper's accuracy tables (Table I/II, Fig. 7) are ResNet/ImageNet runs;
this module feeds those benchmarks from REAL image bytes instead of the
synthetic Gaussian-blob proxy, while keeping every elastic/sharding
property of data/synthetic.py: batches are a pure function of
(seed, step, sample-index), so any host can materialize exactly its slice
of the global batch for any step.

On-disk format — a directory of ``*.npz`` shards, two layouts accepted:

  * ``images`` (N, H, W, 3) uint8 + ``labels`` (N,) int   — native layout
    (what `write_demo_dataset` emits);
  * ``data`` (N, 3*S*S) uint8 row-major CHW + ``labels`` (N,) 1-based int
    — the downsampled-ImageNet (Imagenet32/64) / CIFAR batch layout.

Files whose name contains ``val`` form the held-out split; without any,
the last ~10% of the training samples are reserved.  Pixels map to
(x - 128) / 128 in [-1, 1): EXACTLY the signed 8-bit fixed-point grid
2^(1-8) — real images enter the network already integer-quantized, the
paper's "8-bit input" claim for free.

Augmentation (pad-4 random crop + horizontal flip) is seeded per
(seed, step, shard-offset), so it is deterministic and layout-invariant
like everything else in the pipeline.

No PIL/TF/network dependency: numpy only.  ``python -m repro.data.imagenet
--write-demo DIR`` materializes a small learnable dataset in the native
layout so CI and tests exercise the real file-reading path.
"""
from __future__ import annotations

import argparse
import glob
import os
from dataclasses import dataclass, field

import numpy as np

from .synthetic import ImageTask, host_local_slice


def _load_npz(path: str):
    """One shard -> (images uint8 NHWC, labels int32 0-based)."""
    with np.load(path) as z:
        if "images" in z:
            imgs = np.asarray(z["images"], dtype=np.uint8)
            labels = np.asarray(z["labels"], dtype=np.int64)
        elif "data" in z:
            flat = np.asarray(z["data"], dtype=np.uint8)
            side = int(round((flat.shape[1] // 3) ** 0.5))
            imgs = flat.reshape(-1, 3, side, side).transpose(0, 2, 3, 1)
            labels = np.asarray(z["labels"], dtype=np.int64)
            if labels.min() >= 1:            # Imagenet32/CIFAR are 1-based
                labels = labels - 1
        else:
            raise ValueError(f"{path}: expected 'images' or 'data' key, "
                             f"got {sorted(z.files)}")
    if imgs.ndim != 4 or imgs.shape[-1] != 3:
        raise ValueError(f"{path}: bad image shape {imgs.shape}")
    return imgs, labels.astype(np.int32)


@dataclass
class NpzImageTask:
    """Disk-backed image task with the synthetic tasks' batch protocol.

    batch(step, shard_idx, n_shards) -> {"images": f32 (n,H,W,3) on the
    2^-7 grid, "labels": int32}; holdout_batch(i) serves the val split
    (no augmentation).  Samples are drawn through a per-epoch permutation
    (epoch = how many times `step * global_batch` has wrapped the train
    set), so every epoch visits each sample once in a seed-fixed order.
    """

    data_dir: str
    global_batch: int
    augment: bool = True
    seed: int = 0
    pad: int = 4

    _train: tuple = field(init=False, repr=False)
    _val: tuple = field(init=False, repr=False)

    def __post_init__(self):
        files = sorted(glob.glob(os.path.join(self.data_dir, "*.npz")))
        if not files:
            raise FileNotFoundError(
                f"no *.npz shards under {self.data_dir!r} (see "
                f"repro.data.imagenet module docstring for the layout)")
        val_files = [f for f in files if "val" in os.path.basename(f)]
        train_files = [f for f in files if f not in val_files] or files
        ti, tl = zip(*(_load_npz(f) for f in train_files))
        imgs, labels = np.concatenate(ti), np.concatenate(tl)
        if val_files:
            vi, vl = zip(*(_load_npz(f) for f in val_files))
            self._train = (imgs, labels)
            self._val = (np.concatenate(vi), np.concatenate(vl))
        else:                       # reserve the tail ~10% as holdout
            n_val = max(1, len(imgs) // 10)
            self._train = (imgs[:-n_val], labels[:-n_val])
            self._val = (imgs[-n_val:], labels[-n_val:])

    @property
    def img_size(self) -> int:
        return int(self._train[0].shape[1])

    @property
    def num_classes(self) -> int:
        return int(max(self._train[1].max(), self._val[1].max())) + 1

    @property
    def n_train(self) -> int:
        return len(self._train[0])

    def _epoch_perm(self, epoch: int) -> np.ndarray:
        rs = np.random.RandomState(
            (self.seed * 1_000_003 + epoch * 97) % (2 ** 31))
        return rs.permutation(self.n_train)

    def batch(self, step: int, shard_idx: int = 0, n_shards: int = 1) -> dict:
        start, count = host_local_slice(self.global_batch, shard_idx,
                                        n_shards)
        imgs, labels = self._train
        pos0 = step * self.global_batch + start
        # positions may straddle an epoch boundary: resolve per sample
        pos = pos0 + np.arange(count)
        epochs = pos // self.n_train
        idx = np.empty(count, dtype=np.int64)
        for e in np.unique(epochs):
            m = epochs == e
            idx[m] = self._epoch_perm(int(e))[pos[m] % self.n_train]
        x = imgs[idx]
        if self.augment:
            x = self._augment(x, step, start)
        return {"images": _to_grid(x), "labels": labels[idx].copy()}

    def holdout_batch(self, i: int) -> dict:
        imgs, labels = self._val
        n = len(imgs)
        idx = (i * self.global_batch + np.arange(self.global_batch)) % n
        return {"images": _to_grid(imgs[idx]), "labels": labels[idx].copy()}

    def _augment(self, x: np.ndarray, step: int, start: int) -> np.ndarray:
        n, s, _, c = x.shape
        p = self.pad
        padded = np.pad(x, ((0, 0), (p, p), (p, p), (0, 0)), mode="reflect")
        out = np.empty_like(x)
        for i in range(n):
            # per-GLOBAL-sample seeding: shard slices compose bitwise with
            # the full batch (any host materializes exactly its rows)
            rs = np.random.RandomState(
                (self.seed * 1_000_003 + step * 7919
                 + (start + i) * 101 + 13) % (2 ** 31))
            oy, ox = rs.randint(0, 2 * p + 1, size=2)
            flip = bool(rs.randint(0, 2))
            crop = padded[i, oy:oy + s, ox:ox + s]
            out[i] = crop[:, ::-1] if flip else crop
        return out


def _to_grid(x_u8: np.ndarray) -> np.ndarray:
    """uint8 -> f32 on the signed 2^(1-8) fixed-point grid in [-1, 1)."""
    return (x_u8.astype(np.float32) - 128.0) / 128.0


def write_demo_dataset(data_dir: str, *, n: int = 4096, img_size: int = 16,
                       num_classes: int = 8, seed: int = 0,
                       val_frac: float = 0.125) -> dict:
    """Materialize a small learnable dataset in the native npz layout.

    Same class-conditional-blob distribution as the synthetic ImageTask,
    but rendered to uint8 files — so tests/CI drive the REAL disk pipeline
    (shard loading, epoch permutation, augmentation, 8-bit input grid)
    with bytes that a reduced ResNet can actually learn.
    """
    os.makedirs(data_dir, exist_ok=True)
    rs = np.random.RandomState(seed)
    proto_rs = np.random.RandomState(seed + 12345)
    protos = proto_rs.randn(num_classes, img_size, img_size, 3)
    labels = rs.randint(0, num_classes, size=n).astype(np.int32)
    x = protos[labels] + 0.8 * rs.randn(n, img_size, img_size, 3)
    imgs = np.clip(np.round(x * 24.0 + 128.0), 0, 255).astype(np.uint8)
    n_val = max(1, int(n * val_frac))
    paths = {}
    for name, sl in (("train_000.npz", slice(0, n - n_val)),
                     ("val_000.npz", slice(n - n_val, n))):
        path = os.path.join(data_dir, name)
        np.savez(path, images=imgs[sl], labels=labels[sl])
        paths[name] = path
    return {"n_train": n - n_val, "n_val": n_val, "paths": paths}


def resolve_image_task(global_batch: int, *, data_dir: str | None = None,
                       synthetic: bool = False, img_size: int = 16,
                       num_classes: int = 8, seed: int = 1):
    """Benchmark data resolver: the real npz pipeline when a data dir is
    configured (REPRO_DATA_DIR or explicit), the synthetic blob task
    otherwise or when `synthetic` forces the fallback.

    Returns (task, tag) where tag is "real:<dir>" or "synthetic" — the
    paper-table benchmarks stamp it into every emitted row.
    """
    data_dir = data_dir or os.environ.get("REPRO_DATA_DIR", "")
    if data_dir and not synthetic:
        task = NpzImageTask(data_dir, global_batch=global_batch, seed=seed)
        return task, f"real:{os.path.basename(os.path.normpath(data_dir))}"
    task = ImageTask(img_size=img_size, num_classes=num_classes,
                     global_batch=global_batch, seed=seed)
    return task, "synthetic"


def main(argv=None):
    p = argparse.ArgumentParser("repro.data.imagenet")
    p.add_argument("--write-demo", metavar="DIR",
                   help="materialize a learnable demo dataset (native npz "
                        "layout) under DIR")
    p.add_argument("--n", type=int, default=4096)
    p.add_argument("--img-size", type=int, default=16)
    p.add_argument("--classes", type=int, default=8)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)
    if args.write_demo:
        info = write_demo_dataset(args.write_demo, n=args.n,
                                  img_size=args.img_size,
                                  num_classes=args.classes, seed=args.seed)
        print(f"[data] wrote demo dataset: {info['n_train']} train / "
              f"{info['n_val']} val ({args.img_size}x{args.img_size}, "
              f"{args.classes} classes) -> {args.write_demo}")
        return
    p.error("nothing to do (pass --write-demo DIR)")


if __name__ == "__main__":
    main()
