"""Deterministic, shardable synthetic data pipelines.

Every batch is a pure function of (seed, step, sample-index), so the
pipeline is elastic by construction: any host can materialize exactly its
slice of the global batch for any step (crash/restart, re-scale, or
straggler re-assignment never changes the data stream).  A background
prefetch thread overlaps host data generation with device compute.

Tasks:
  TokenTask  — "arith" (learnable: next token is a fixed affine function of
               the previous two, mod vocab — a convergence probe for the
               paper's accuracy experiments) or "uniform" (pure throughput).
  ImageTask  — class-conditional Gaussian blobs (learnable) for the ResNet
               reproduction.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import jax
import numpy as np


def host_local_slice(global_batch: int, shard_idx: int, n_shards: int):
    per = global_batch // n_shards
    return shard_idx * per, per


@dataclass
class TokenTask:
    vocab: int
    seq_len: int
    global_batch: int
    kind: str = "arith"          # arith | uniform
    seed: int = 0

    def sample(self, step: int, start: int, count: int) -> dict:
        rs = np.random.RandomState(
            (self.seed * 1_000_003 + step) % (2 ** 31))
        rs.randint(0, 2 ** 30, size=start + 1)  # decorrelate shard offsets
        rs = np.random.RandomState(
            (self.seed * 1_000_003 + step * 7919 + start) % (2 ** 31))
        v, s = self.vocab, self.seq_len
        if self.kind == "uniform":
            toks = rs.randint(0, v, size=(count, s + 1), dtype=np.int32)
        else:
            toks = np.empty((count, s + 1), dtype=np.int32)
            toks[:, 0] = rs.randint(0, v, size=count)
            toks[:, 1] = rs.randint(0, v, size=count)
            a, b, c = 3, 5, 7
            for t in range(2, s + 1):
                toks[:, t] = (a * toks[:, t - 1] + b * toks[:, t - 2] + c) % v
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def batch(self, step: int, shard_idx: int = 0, n_shards: int = 1) -> dict:
        start, count = host_local_slice(self.global_batch, shard_idx,
                                        n_shards)
        return self.sample(step, start, count)


@dataclass
class ImageTask:
    img_size: int
    num_classes: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int, shard_idx: int = 0, n_shards: int = 1) -> dict:
        start, count = host_local_slice(self.global_batch, shard_idx,
                                        n_shards)
        rs = np.random.RandomState(
            (self.seed * 1_000_003 + step * 7919 + start) % (2 ** 31))
        labels = rs.randint(0, self.num_classes, size=count).astype(np.int32)
        # class-conditional means on a fixed random direction per class
        proto_rs = np.random.RandomState(self.seed + 12345)
        protos = proto_rs.randn(self.num_classes, self.img_size,
                                self.img_size, 3).astype(np.float32)
        imgs = (protos[labels]
                + 0.8 * rs.randn(count, self.img_size, self.img_size, 3)
                ).astype(np.float32)
        return {"images": imgs, "labels": labels}

    def holdout_batch(self, i: int) -> dict:
        """Held-out eval batches: fresh steps the model never trains on —
        the same protocol NpzImageTask serves from its val split."""
        return self.batch(10_000 + i)


def make_global_batch(host_batch: dict, mesh, pspec_tree) -> dict:
    """Place a host batch onto the mesh with the given PartitionSpecs.

    Single-process: jax.device_put with NamedSharding.  (On a real multi-host
    pod this becomes jax.make_array_from_process_local_data — same call
    shape, the pipeline code does not change.)
    """
    from jax.sharding import NamedSharding

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))
    return jax.tree.map(put, host_batch, pspec_tree)


class Prefetcher:
    """Background thread that keeps `depth` batches ready."""

    def __init__(self, fn, start_step: int = 0, depth: int = 2):
        self._fn = fn
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._work, daemon=True)
        self._t.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._fn(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def get(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._t.join(timeout=2)
