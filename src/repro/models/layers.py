"""Shared model building blocks: RoPE, GQA attention (chunked / cached),
SwiGLU MLP, initializers — all built on the WAGEUBN quantized ops.

Attention adaptation of the paper's scheme (DESIGN.md §3): QK^T and PV are
activation-activation int8 matmuls (error quantizer = cfg.e_attn, default
QuantSpec("sq", 8)); softmax logits run on the fp32 VPU; probabilities are
quantized onto the k_A grid ([0,1], where direct quantization is exact-range).
"""
from __future__ import annotations

import contextlib
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import (QTensor, qact, qdense, qeinsum, qprobs, qrmsnorm,
                        qlayernorm, qt_carrier)
from repro.core import qfuncs as qf
from repro.core.qconfig import QConfig
from repro.kernels import ops as kops

Array = jax.Array

NEG_INF = -1e9


def target_logit(logits, labels):
    """Gather labels' logits WITHOUT all-gathering a vocab-sharded tensor:
    a masked sum partitions cleanly (local mask + tiny (B,S) all-reduce),
    where take_along_axis would gather the full logits to every device."""
    iota = lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    mask = iota == labels[..., None]
    return jnp.sum(jnp.where(mask, logits, 0.0), axis=-1)


def constrain(mesh, x, spec):
    """Anchor intermediate sharding (3-axis meshes defeat propagation)."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding
    return lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def maybe_remat(acfg, fn):
    if getattr(acfg, "remat", "full") == "full":
        return jax.checkpoint(fn, prevent_cse=False)
    return fn


# --------------------------------------------------------------------------
# manual tensor parallelism (Megatron f/g pair for shard_map bodies)
# --------------------------------------------------------------------------
#
# Inside the full-manual shard_map training step (launch/train.py) the model
# axis carries head/FFN/expert shards.  A column-sharded matmul needs no
# forward communication but its input cotangent is PARTIAL over the axis
# (each rank only back-propagates through its local output features);
# a row-sharded matmul produces partial outputs.  tp_enter / tp_exit are the
# classic conjugate pair: enter = identity fwd / psum bwd (placed where
# replicated activations feed sharded params), exit = psum fwd / identity
# bwd (placed where partial outputs rejoin the replicated stream).  The
# psums carry fp32 ACTIVATIONS/ERRORS (the TP boundary traffic DESIGN.md §9
# scopes out of the integer-wire gradient contract); parameter gradients
# never cross the model axis — sharded params get local grads, replicated
# params compute identical grads on every rank.


def _psum_float_leaves(axis, ct):
    return jax.tree.map(
        lambda t: t if t.dtype == jax.dtypes.float0 else lax.psum(t, axis),
        ct)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def tp_enter(axis: str, x):
    """Identity forward; psum over `axis` on the backward cotangent.

    `x` may be an Array or a QTensor (the payload passes through untouched,
    so decompose-once is preserved; only the carrier cotangent is reduced).
    """
    return x


def _tp_enter_fwd(axis, x):
    return x, None


def _tp_enter_bwd(axis, _, ct):
    return (_psum_float_leaves(axis, ct),)


tp_enter.defvjp(_tp_enter_fwd, _tp_enter_bwd)


# Integer-wire TP reduction (serving decode contract, DESIGN.md §12).
# Inside the sharded decode step every tp_exit partial is a sum of int32
# dot products times a SHARED pow2 scale (qeinsum raw outputs and their
# gate-weighted MoE combinations), so the cross-rank reduction can ride an
# integer collective: bitcast the fp32 partials to uint32, all_gather the
# payloads, bitcast back and sum locally.  The local fp32 adds are exact
# (every addend is an exact multiple of the shared scale, well under the
# 2^24 mantissa bound at CPU/test scale), so the result is bitwise equal
# to lax.psum — but the wire carries only integer words, which is what
# tests/test_sharded_serving.py's jaxpr assertion checks.
_TP_INT_WIRE = False


@contextlib.contextmanager
def tp_int_wire():
    """Within this (trace-time) context, tp_exit's forward reduction rides
    an integer all_gather instead of a float psum."""
    global _TP_INT_WIRE
    prev = _TP_INT_WIRE
    _TP_INT_WIRE = True
    try:
        yield
    finally:
        _TP_INT_WIRE = prev


def _wire_reduce(axis: str, y: Array) -> Array:
    if _TP_INT_WIRE and y.dtype == jnp.float32:
        w = lax.all_gather(lax.bitcast_convert_type(y, jnp.uint32), axis)
        return jnp.sum(lax.bitcast_convert_type(w, jnp.float32), axis=0)
    return lax.psum(y, axis)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def tp_exit(axis: str, y: Array) -> Array:
    """psum over `axis` forward (partial row-sharded outputs -> replicated);
    identity backward (the downstream cotangent is already replicated).
    Under tp_int_wire() the forward reduction is gather-bitcast-sum."""
    return _wire_reduce(axis, y)


def _tp_exit_fwd(axis, y):
    return _wire_reduce(axis, y), None


def _tp_exit_bwd(axis, _, ct):
    return (ct,)


tp_exit.defvjp(_tp_exit_fwd, _tp_exit_bwd)


def _gather_lastdim_impl(axis: str, x: Array) -> Array:
    w = lax.all_gather(lax.bitcast_convert_type(x, jnp.uint32), axis)
    w = lax.bitcast_convert_type(w, x.dtype)          # (tp, ..., local)
    return jnp.moveaxis(w, 0, -2).reshape(*x.shape[:-1], -1)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def tp_gather_lastdim(axis: str, x: Array) -> Array:
    """Concatenate rank-local last-dim slices into the replicated full axis
    (mamba2's head-sharded y rejoining the replicated norm/gate tail).

    Forward: integer-payload all_gather (bitcast, same wire contract as
    tp_exit) then a transpose/reshape — pure data movement, bitwise exact.
    Backward: each rank keeps its own slice of the cotangent.
    """
    return _gather_lastdim_impl(axis, x)


def _tp_gather_fwd(axis, x):
    return _gather_lastdim_impl(axis, x), x.shape[-1]


def _tp_gather_bwd(axis, local, ct):
    r = lax.axis_index(axis)
    return (lax.dynamic_slice_in_dim(ct, r * local, local, axis=-1),)


tp_gather_lastdim.defvjp(_tp_gather_fwd, _tp_gather_bwd)


def lscan(acfg, body, init, xs):
    """scan-over-layers honoring acfg.unroll_layers (cost-exact compiles)."""
    return lax.scan(body, init, xs, unroll=(True if acfg.unroll_layers
                                            else 1))


# --------------------------------------------------------------------------
# init (paper Eq. 9: MSRA + k_WU-grid discretization)
# --------------------------------------------------------------------------


def winit(cfg: QConfig, key, shape, fan_in: int) -> Array:
    w = jax.random.normal(key, shape, jnp.float32) / math.sqrt(fan_in)
    if cfg.quantize:
        lim = 1.0 - qf.d(cfg.k_wu)
        w = jnp.clip(qf.q_direct(w, cfg.k_wu), -lim, lim)
    return w


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope(x: Array, pos: Array, theta: float = 1e4) -> Array:
    """x: (..., S, H, dh), pos: (S,) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = pos.astype(jnp.float32)[:, None] * freqs[None, :]   # (S, half)
    cos = jnp.cos(ang)[:, None, :]
    sin = jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------


def _attn_scores(cfg, q, k):
    """(B,S,KV,G,dh) x (B,T,KV,dh) -> (B,S,KV,G,T) through qeinsum.

    q/k may be QTensors (e.g. the int8 KV cache in decode): their payloads
    feed the integer dot directly, with no re-decomposition."""
    return qeinsum(cfg, "bskgd,btkd->bskgt", cfg.e_attn, False, q, k)


def _attn_out(cfg, p, v):
    return qeinsum(cfg, "bskgt,btkd->bskgd", cfg.e_attn, False, p, v)


def _payload8(x) -> bool:
    """Single-plane int8 QTensor with a differentiable carrier — what the
    fused attention kernels consume."""
    return (isinstance(x, QTensor) and x.lo is None
            and x.data.dtype == jnp.int8 and x.carrier is not None)


def chunked_attention(cfg: QConfig, q: Array, k: Array, v: Array, *,
                      causal: bool, q_pos: Array, k_pos: Array,
                      q_chunk: int = 1024, kv_chunk: int = 512) -> Array:
    """Memory-efficient online-softmax attention (flash-style).

    q: (B, S, H, dh) on the activation grid; k/v: (B, T, KV, dh).
    Returns (B, S, H, dh) normalized output on the activation grid.

    Native mode with `cfg.fuse_kernels` routes the forward through the
    tiled Pallas flash kernel (kernels/ops.flash_attention_op) — int8
    payloads in, per-chunk decompositions in-register, bit-identical to
    the pure-JAX path below — via custom_vjp whose backward is the vjp of
    the unfused body (the per-chunk qeinsum Q_E2 semantics of Alg. 2 are
    unchanged).  Everything else takes the pure-JAX chunked path.
    """
    if (cfg.native and cfg.fuse_kernels
            and all(map(_payload8, (q, k, v)))
            and kops.flash_attention_fits(
                q.shape[0], min(q_chunk, q.shape[1]), q.shape[2],
                q.shape[3])):
        out = _flash_fused(cfg, causal, min(q_chunk, q.shape[1]),
                           min(kv_chunk, k.shape[1]), q, k, v, q_pos, k_pos)
        return qact(cfg, "none", out)
    return qact(cfg, "none", _chunked_core(
        cfg, q, k, v, causal=causal, q_pos=q_pos, k_pos=k_pos,
        q_chunk=q_chunk, kv_chunk=kv_chunk))


def _chunked_core(cfg: QConfig, q, k, v, *, causal: bool, q_pos: Array,
                  k_pos: Array, q_chunk: int, kv_chunk: int) -> Array:
    """Pure-JAX online-softmax body (pre-Q_A output): the sim-mode path
    and the fused route's vjp ground truth."""
    # the online-softmax rescale math + chunk padding/scanning run on the
    # fp32 grid carriers; QTensor inputs degrade here (differentiably) and
    # the per-chunk qeinsums re-enter the integer path
    q, k, v = qt_carrier(q), qt_carrier(k), qt_carrier(v)
    b, s, h, dh = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(dh)

    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, t)
    # pad sequence dims up to chunk multiples; padded kv slots are masked out
    s_orig = s
    sp = -s % q_chunk
    tp = -t % kv_chunk
    if sp:
        q = jnp.pad(q, ((0, 0), (0, sp), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, sp))
        s += sp
    k_valid = jnp.ones((t,), bool)
    if tp:
        k = jnp.pad(k, ((0, 0), (0, tp), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, tp), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, tp))
        k_valid = jnp.pad(k_valid, (0, tp))
        t += tp
    q = q.reshape(b, s, kv, g, dh)

    nq, nk = s // q_chunk, t // kv_chunk

    kc = k.reshape(b, nk, kv_chunk, kv, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nk, kv_chunk, kv, dh).transpose(1, 0, 2, 3, 4)
    kpc = k_pos.reshape(nk, kv_chunk)
    kvc = k_valid.reshape(nk, kv_chunk)

    def q_block(qi, qp):
        # qi: (B, qc, KV, G, dh); qp: (qc,)
        def kv_step(carry, inp):
            m, l, o = carry
            ki, vi, kp, kval = inp
            sc = _attn_scores(cfg, qi, ki) * scale     # (B,qc,KV,G,kc)
            mask = kval[None, :] if not causal else (
                (qp[:, None] >= kp[None, :]) & kval[None, :])
            sc = jnp.where(mask[None, :, None, None, :], sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            p = qprobs(cfg, p)                         # Q_A on probabilities
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            o = o * alpha[..., None] + _attn_out(cfg, p, vi)
            return (m_new, l, o), None

        m0 = jnp.full(qi.shape[:-1], NEG_INF, jnp.float32)
        l0 = jnp.zeros(qi.shape[:-1], jnp.float32)
        o0 = jnp.zeros(qi.shape, jnp.float32)
        (m, l, o), _ = lax.scan(kv_step, (m0, l0, o0), (kc, vc, kpc, kvc))
        return o / jnp.maximum(l, 1e-9)[..., None]

    qb = q.reshape(b, nq, q_chunk, kv, g, dh).transpose(1, 0, 2, 3, 4, 5)
    qpb = q_pos.reshape(nq, q_chunk)
    out = lax.map(lambda args: q_block(*args), (qb, qpb))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, dh)
    return out[:, :s_orig]


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _flash_fused(cfg: QConfig, causal: bool, q_chunk: int, kv_chunk: int,
                 q: QTensor, k: QTensor, v: QTensor, q_pos: Array,
                 k_pos: Array) -> Array:
    """Fused-forward attention: pad payloads to chunk multiples and run the
    tiled Pallas flash kernel.  Bit-identical to `_chunked_core` (the
    kernel re-derives every per-chunk decomposition in-register)."""
    b, s, h, dh = q.shape
    t = k.shape[1]
    sp, tp = -s % q_chunk, -t % kv_chunk
    q8, k8, v8 = q.data, k.data, v.data
    k_valid = jnp.ones((t,), jnp.int32)
    if sp:
        q8 = jnp.pad(q8, ((0, 0), (0, sp), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, sp))
    if tp:
        k8 = jnp.pad(k8, ((0, 0), (0, tp), (0, 0), (0, 0)))
        v8 = jnp.pad(v8, ((0, 0), (0, tp), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, tp))
        k_valid = jnp.pad(k_valid, (0, tp))
    out = kops.flash_attention_op(
        q8, k8, v8, q_pos, k_pos, k_valid, q.scale, k.scale, v.scale,
        causal=causal, sm_scale=1.0 / math.sqrt(dh), q_chunk=q_chunk,
        kv_chunk=kv_chunk, k_a=cfg.k_a)
    return out[:, :s]


def _flash_fused_fwd(cfg, causal, q_chunk, kv_chunk, q, k, v, q_pos, k_pos):
    out = _flash_fused(cfg, causal, q_chunk, kv_chunk, q, k, v, q_pos, k_pos)
    # int8 payload residuals only — the carriers are re-derived in the bwd
    return out, (q.drop_carrier(), k.drop_carrier(), v.drop_carrier(),
                 q_pos, k_pos)


def _flash_fused_bwd(cfg, causal, q_chunk, kv_chunk, res, ct):
    # backward = vjp of the unfused chunked body (per-chunk qeinsums apply
    # Q_E2 per Alg. 2); the fused forward is bit-identical to that body,
    # so this IS the fused op's gradient
    q, k, v, q_pos, k_pos = res
    qw, kw, vw = (t.with_carrier() for t in (q, k, v))
    _, vjp = jax.vjp(
        lambda a, b, c: _chunked_core(cfg, a, b, c, causal=causal,
                                      q_pos=q_pos, k_pos=k_pos,
                                      q_chunk=q_chunk, kv_chunk=kv_chunk),
        qw, kw, vw)
    dq, dk, dv = vjp(ct)
    zero = lambda x: np.zeros(np.shape(x), jax.dtypes.float0)  # noqa: E731
    return dq, dk, dv, zero(q_pos), zero(k_pos)


_flash_fused.defvjp(_flash_fused_fwd, _flash_fused_bwd)


def decode_attention(cfg: QConfig, q, k, v, *,
                     q_pos: Array, t_valid: Array) -> Array:
    """Single-step attention against a full (possibly int8) KV cache.

    q: (B, 1, H, dh); k/v: (B, T, KV, dh) — QTensors straight from the int8
    cache (their payloads feed the integer dots with no dequantize round
    trip) or grid fp32 arrays.  t_valid masks positions >= current length.
    """
    b, s, h, dh = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(dh)
    qr = q.reshape(b, s, kv, g, dh)
    sc = _attn_scores(cfg, qr, k) * scale              # (B,1,KV,G,T)
    kp = jnp.arange(t)
    mask = (kp[None, :] <= q_pos[:, None]) & (kp[None, :] < t_valid)
    sc = jnp.where(mask[:, None, None, None, :], sc, NEG_INF)
    m = jnp.max(sc, axis=-1, keepdims=True)
    p = jnp.exp(sc - m)
    p = qprobs(cfg, p / jnp.sum(p, axis=-1, keepdims=True))
    out = _attn_out(cfg, p, v).reshape(b, s, h, dh)
    return qact(cfg, "none", out)


def paged_decode_attention(cfg: QConfig, q, k_pages, v_pages, table, k_scale,
                           v_scale, *, q_pos: Array, t_valid: Array) -> Array:
    """Single-step attention against a PAGED int8 KV cache (one layer).

    k_pages/v_pages: (P, page, KV, dh) int8 physical pages; table: (B, NB)
    per-lane page table (logical block -> physical page id, 0 = trash page).

    Native mode with `cfg.fuse_kernels` takes the FUSED route
    (kernels/ops.paged_attention_op): int8 K/V pages stream through VMEM
    behind the scalar-prefetched table and the gathered contiguous KV view
    never exists in HBM — bit-exact against the gather route below, which
    remains for sim mode / non-QTensor queries (and defrag/tests keep the
    standalone page_gather kernel).  Either way everything stays int8 end
    to end: the paged cache is never dequantized or concatenated in fp32.
    """
    b, s, h, dh = q.shape
    if (cfg.native and cfg.fuse_kernels and s == 1 and _payload8(q)
            and kops.paged_attention_fits(h, table.shape[1]
                                          * k_pages.shape[1])):
        out = kops.paged_attention_op(
            q.data.reshape(b, h, dh), k_pages, v_pages, table, q_pos,
            t_valid, q.scale, k_scale, v_scale,
            sm_scale=1.0 / math.sqrt(dh), k_a=cfg.k_a)
        return qact(cfg, "none", out.reshape(b, s, h, dh))
    from repro.kernels.ops import page_gather_op
    page = k_pages.shape[1]
    nb = table.shape[1]
    k8 = page_gather_op(k_pages, table).reshape(
        b, nb * page, *k_pages.shape[2:])
    v8 = page_gather_op(v_pages, table).reshape(
        b, nb * page, *v_pages.shape[2:])
    return decode_attention(cfg, q, kv_qtensor(k8, k_scale),
                            kv_qtensor(v8, v_scale), q_pos=q_pos,
                            t_valid=t_valid)


def paged_prefill_attention(cfg: QConfig, q, k_pages, v_pages, table,
                            k_scale, v_scale, *, q_pos: Array) -> Array:
    """One PAGE of prefill attention against the paged int8 cache (one
    layer, one lane): the chunked-prefill data path (DESIGN.md §10).

    q: (1, S, H, dh) — S = page_size query tokens of a single lane whose
    KV page was just written into the pool; q_pos: (S,) their absolute
    positions.  k_pages/v_pages: (P, page, KV, dh) int8; table: (1, NB).
    Gathers the lane's pages (the current page included) and applies the
    per-position causal mask — positions beyond q_pos belong to pages not
    yet written this prefill and are masked, so stale arena contents never
    leak in.  Numerics mirror `decode_attention` (normalized probabilities
    onto the k_A grid); every amax spans only this lane's single page, so
    the output is a pure function of (prefix tokens, page tokens) — the
    determinism the radix cache's bitwise-hit contract rests on.
    """
    from repro.kernels.ops import page_gather_op
    b, s, h, dh = q.shape
    page = k_pages.shape[1]
    nb = table.shape[1]
    kv = k_pages.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(dh)
    k8 = page_gather_op(k_pages, table).reshape(
        b, nb * page, *k_pages.shape[2:])
    v8 = page_gather_op(v_pages, table).reshape(
        b, nb * page, *v_pages.shape[2:])
    qr = q.reshape(b, s, kv, g, dh)
    sc = _attn_scores(cfg, qr, kv_qtensor(k8, k_scale)) * scale
    kp = jnp.arange(nb * page)                       # (B,S,KV,G,T)
    mask = q_pos[:, None] >= kp[None, :]             # (S, T) causal+valid
    sc = jnp.where(mask[None, :, None, None, :], sc, NEG_INF)
    m = jnp.max(sc, axis=-1, keepdims=True)
    p = jnp.exp(sc - m)
    p = qprobs(cfg, p / jnp.sum(p, axis=-1, keepdims=True))
    out = _attn_out(cfg, p, kv_qtensor(v8, v_scale)).reshape(b, s, h, dh)
    return qact(cfg, "none", out)


# --------------------------------------------------------------------------
# int8 KV cache
# --------------------------------------------------------------------------


def kv_cache_init(n_layers: int, b: int, t: int, kv: int, dh: int):
    """int8 cache + per-layer pow2 scales (paper k_A applied to the cache).

    Stored as flat int8 + scale arrays (checkpoint/pspec friendly); cache
    reads wrap them back into QTensors via `kv_qtensor` so decode matmuls
    consume the payloads directly.
    """
    return {
        "k": jnp.zeros((n_layers, b, t, kv, dh), jnp.int8),
        "v": jnp.zeros((n_layers, b, t, kv, dh), jnp.int8),
        "k_scale": jnp.full((n_layers,), 2.0 ** -7, jnp.float32),
        "v_scale": jnp.full((n_layers,), 2.0 ** -7, jnp.float32),
        "pos": jnp.zeros((b,), jnp.int32),
    }


def kv_quantize(x, step):
    """Payload on the int8 cache grid.  QTensor inputs requantize payload-
    to-payload (a pow2 shift saturating to int8 — NO amax pass); arrays
    take the legacy path."""
    if isinstance(x, QTensor):
        return x.requantize(step, k=8)
    return jnp.clip(jnp.round(x / step), -127, 127).astype(jnp.int8)


def kv_qtensor(x8: Array, step: Array) -> QTensor:
    """Wrap a cache slice as a (non-differentiable) QTensor."""
    return QTensor(x8, step, 8)


def page_scatter_token(pages: Array, table: Array, pos: Array,
                       tok: Array) -> Array:
    """Write one decode step's quantized KV token into its page slot.

    pages: (P, page, KV, dh) int8; table: (B, NB); pos: (B,) the position
    being written; tok: (B, KV, dh) int8.  Lane b lands in
    pages[table[b, pos//page], pos%page].  Dead lanes' table rows are all 0,
    so their writes collide harmlessly on the trash page.
    """
    page = pages.shape[1]
    blk, off = pos // page, pos % page
    pid = jnp.take_along_axis(table, blk[:, None], axis=1)[:, 0]
    return pages.at[pid, off].set(tok)


def page_write(pages: Array, pid: Array, block: Array) -> Array:
    """Whole-page KV write: pages (P, page, KV, dh) <- block (page, KV, dh)
    at physical page `pid`.  The chunked-prefill step processes exactly one
    page-aligned block of positions at a time, so the write is a single
    dense page store (pid 0 = trash page absorbs masked-out chunks)."""
    return pages.at[pid].set(block)


def kv_dequantize(x8: Array, step: Array) -> Array:
    """DEPRECATED: decode paths consume the cache via `kv_qtensor` now (the
    payload feeds the integer dots directly); kept for external callers."""
    return x8.astype(jnp.float32) * step


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------


def swiglu(cfg: QConfig, x: Array, w_gate: Array, w_up: Array,
           w_down: Array, act: str = "silu") -> Array:
    gate = qact(cfg, act, qdense(cfg, x, w_gate))
    up = qact(cfg, "none", qdense(cfg, x, w_up))
    h = qact(cfg, "none", gate * up)
    return qdense(cfg, h, w_down)


def mlp(cfg: QConfig, x: Array, w_up: Array, w_down: Array,
        act: str = "gelu") -> Array:
    h = qact(cfg, act, qdense(cfg, x, w_up))
    return qdense(cfg, h, w_down)


def norm(cfg: QConfig, kind: str, x: Array, gamma: Array,
         beta: Array | None = None) -> Array:
    if kind == "rmsnorm":
        return qrmsnorm(cfg, x, gamma)
    return qlayernorm(cfg, x, gamma, beta)
