"""Zamba2-7b hybrid: Mamba2 backbone + a SHARED attention/MLP block applied
after every `attn_every` mamba layers (the shared block reuses one set of
parameters at every application, as in the Zamba papers; per-application
LoRA deltas are omitted — recorded in DESIGN.md).

Layer layout for L layers, ae = attn_every:
    [ae mamba] shared_attn [ae mamba] shared_attn ... [tail mamba]
Scan-over-groups keeps HLO O(1) in depth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import qact, qdense, qrmsnorm
from repro.core.qconfig import QConfig
from repro.configs.base import ArchConfig, LM_SHAPES
from . import layers as L
from . import ssm as S

Array = jax.Array


def _attn_shared(cfg, acfg, p, x, pos, mode, cache=None, tp_size=1,
                 tp_axis="model"):
    """One shared attention+MLP block (pre-norm, GQA, SwiGLU).

    Manual TP (tp_size > 1): the Megatron split — wq/wk/wv column-sharded
    (local heads), wo row-sharded (tp_exit rejoins), w_gate/w_up column- and
    w_down row-sharded.  KV pages hold the LOCAL n_kv/tp heads; attention
    (softmax included) is per-head, so every rank's output heads are exact
    slices of the tp=1 computation."""
    b, s, d = x.shape
    hl, kvl = acfg.n_heads // tp_size, acfg.n_kv // tp_size
    tp = tp_size > 1
    h = qact(cfg, "none", qrmsnorm(cfg, x, p["ln1"]))
    if tp:
        h = L.tp_enter(tp_axis, h)
    qh = qdense(cfg, h, p["wq"]).reshape(b, s, hl, acfg.dh)
    kh = qdense(cfg, h, p["wk"]).reshape(b, s, kvl, acfg.dh)
    vh = qdense(cfg, h, p["wv"]).reshape(b, s, kvl, acfg.dh)
    new_cache = None
    if mode == "train":
        qh = L.rope(qh, pos, acfg.rope_theta)
        kh = L.rope(kh, pos, acfg.rope_theta)
        qh, kh, vh = (qact(cfg, "none", t) for t in (qh, kh, vh))
        o = L.chunked_attention(cfg, qh, kh, vh, causal=True, q_pos=pos,
                                k_pos=pos, q_chunk=acfg.q_chunk,
                                kv_chunk=acfg.kv_chunk)
        new_cache = (L.kv_quantize(kh, 2.0 ** -7),
                     L.kv_quantize(vh, 2.0 ** -7))
    elif mode == "chunk":
        # chunked prefill: one lane, one full pool page of positions (see
        # transformer._attn / DESIGN.md §10 — page-scoped amaxes make the
        # written KV a pure function of the token prefix)
        qh = L.rope(qh, pos, acfg.rope_theta)
        kh = L.rope(kh, pos, acfg.rope_theta)
        qh, kh, vh = (qact(cfg, "none", t) for t in (qh, kh, vh))
        ks, vs = cache["k_scale"], cache["v_scale"]
        kp, vp = cache["k_pages"], cache["v_pages"]
        table = cache["table"]
        pid = table[0, pos[0] // kp.shape[1]]
        kp = L.page_write(kp, pid, L.kv_quantize(kh[0], ks))
        vp = L.page_write(vp, pid, L.kv_quantize(vh[0], vs))
        o = L.paged_prefill_attention(cfg, qh, kp, vp, table, ks, vs,
                                      q_pos=pos)
        new_cache = (kp, vp)
    else:
        pvec = pos
        qh = jax.vmap(lambda xi, pi: L.rope(xi, pi[None], acfg.rope_theta))(
            qh, pvec)
        kh = jax.vmap(lambda xi, pi: L.rope(xi, pi[None], acfg.rope_theta))(
            kh, pvec)
        qh, kh, vh = (qact(cfg, "none", t) for t in (qh, kh, vh))
        ks, vs = cache["k_scale"], cache["v_scale"]
        if "k_pages" in cache:  # paged serving cache (this group's pages)
            # fused paged-attention route inside paged_decode_attention
            # (native + fuse_kernels); gather route otherwise
            kp, vp = cache["k_pages"], cache["v_pages"]
            table = cache["table"]
            kp = L.page_scatter_token(kp, table, pvec,
                                      L.kv_quantize(kh[:, 0], ks))
            vp = L.page_scatter_token(vp, table, pvec,
                                      L.kv_quantize(vh[:, 0], vs))
            o = L.paged_decode_attention(cfg, qh, kp, vp, table, ks, vs,
                                         q_pos=pvec, t_valid=pvec.max() + 1)
            new_cache = (kp, vp)
        else:
            k8, v8 = cache["k"], cache["v"]
            bidx = jnp.arange(b)
            k8 = k8.at[bidx, pvec].set(L.kv_quantize(kh[:, 0], ks))
            v8 = v8.at[bidx, pvec].set(L.kv_quantize(vh[:, 0], vs))
            # the int8 cache IS the matmul operand: no dequantize round trip
            o = L.decode_attention(cfg, qh, L.kv_qtensor(k8, ks),
                                   L.kv_qtensor(v8, vs), q_pos=pvec,
                                   t_valid=pvec.max() + 1)
            new_cache = (k8, v8)
    o_proj = qdense(cfg, o.reshape(b, s, -1), p["wo"])
    if tp:
        o_proj = L.tp_exit(tp_axis, o_proj)
    x = x + o_proj
    h2 = qact(cfg, "none", qrmsnorm(cfg, x, p["ln2"]))
    if tp:
        h2 = L.tp_enter(tp_axis, h2)
    mlp = L.swiglu(cfg, h2, p["w_gate"], p["w_up"], p["w_down"], acfg.act)
    if tp:
        mlp = L.tp_exit(tp_axis, mlp)
    x = x + mlp
    return x, new_cache


class Zamba2:
    def __init__(self, acfg: ArchConfig, qcfg: QConfig, mesh=None,
                 dp_axes=("data",), tp_axis="model", tp_size: int = 1):
        self.a, self.q = acfg, qcfg
        self.mesh, self.dp, self.tp = mesh, dp_axes, tp_axis
        self.tp_size = tp_size
        if tp_size > 1:
            hm = acfg.d_inner // acfg.headdim
            bad = [f"{k}={v}" for k, v in
                   (("n_heads", acfg.n_heads), ("n_kv", acfg.n_kv),
                    ("d_ff", acfg.d_ff), ("ssd_heads", hm))
                   if v % tp_size]
            if bad:
                raise ValueError(
                    f"manual TP shards attention heads / FFN features / "
                    f"SSD heads: {', '.join(bad)} not divisible by "
                    f"tp={tp_size}")
        ae = acfg.attn_every
        self.n_groups = acfg.n_layers // ae
        self.tail = acfg.n_layers - self.n_groups * ae

    def _init_shared(self, key):
        a, q = self.a, self.q
        d, dh, h, kv, f = a.d_model, a.dh, a.n_heads, a.n_kv, a.d_ff
        ks = jax.random.split(key, 8)
        return {
            "ln1": jnp.ones((d,), jnp.float32),
            "wq": L.winit(q, ks[0], (d, h * dh), d),
            "wk": L.winit(q, ks[1], (d, kv * dh), d),
            "wv": L.winit(q, ks[2], (d, kv * dh), d),
            "wo": L.winit(q, ks[3], (h * dh, d), h * dh),
            "ln2": jnp.ones((d,), jnp.float32),
            "w_gate": L.winit(q, ks[4], (d, f), d),
            "w_up": L.winit(q, ks[5], (d, f), d),
            "w_down": L.winit(q, ks[6], (f, d), f),
        }

    def init(self, key):
        a = self.a
        ks = jax.random.split(key, 5)
        lk = jax.random.split(ks[0], a.n_layers)
        layers = jax.vmap(lambda k: S.mamba2_init(self.q, a, k))(lk)
        return {
            "embed": jax.random.normal(ks[1], (a.vocab_padded, a.d_model),
                                       jnp.float32) * 0.02,
            "layers": layers,
            "shared": self._init_shared(ks[2]),
            "final_norm": jnp.ones((a.d_model,), jnp.float32),
            "lm_head": jax.random.normal(ks[3], (a.d_model, a.vocab_padded),
                                         jnp.float32) * 0.02,
        }

    def labels(self, params):
        shared = {"ln1": "gamma", "wq": "w", "wk": "w", "wv": "w", "wo": "w",
                  "ln2": "gamma", "w_gate": "w", "w_up": "w", "w_down": "w"}
        return {"embed": "exempt", "layers": S.mamba2_labels(),
                "shared": shared, "final_norm": "gamma", "lm_head": "exempt"}

    def pspecs(self):
        dp, tp = self.dp, self.tp
        layer = {"ln": P(None, None), "in_proj": P(None, dp, tp),
                 "conv_w": P(None, None, tp), "conv_b": P(None, tp),
                 "bc_proj": P(None, dp, None), "dt_proj": P(None, dp, tp),
                 "dt_bias": P(None, tp), "A_log": P(None, tp),
                 "D_skip": P(None, tp), "ssm_norm": P(None, tp),
                 "out_proj": P(None, tp, dp)}
        shared = {"ln1": P(None), "wq": P(dp, tp), "wk": P(dp, tp),
                  "wv": P(dp, tp), "wo": P(tp, dp), "ln2": P(None),
                  "w_gate": P(dp, tp), "w_up": P(dp, tp),
                  "w_down": P(tp, dp)}
        return {"embed": P(None, tp), "layers": layer, "shared": shared,
                "final_norm": P(None), "lm_head": P(None, tp)}

    def _split_groups(self, tree):
        """Stacked (L, ...) mamba arrays -> ((G, ae, ...), (tail, ...))."""
        g, ae = self.n_groups, self.a.attn_every
        head = jax.tree.map(
            lambda t: t[: g * ae].reshape((g, ae) + t.shape[1:]), tree)
        tail = jax.tree.map(lambda t: t[g * ae:], tree)
        return head, tail

    def _backbone(self, params, x, pos, mode, cache=None):
        a, q = self.a, self.q
        head, tail = self._split_groups(params["layers"])
        shared = params["shared"]
        emit = cache == "emit"

        tpk = {"tp_size": self.tp_size, "tp_axis": self.tp}

        def mamba_scan(x, group_params, states):
            if mode == "train":
                def mbody(h, lp):
                    h = L.constrain(self.mesh, h, P(self.dp, None, None))
                    h2, st = S.mamba2_block(q, a, lp, h, "train", **tpk)
                    return h2, st
                mbody = L.maybe_remat(a, mbody)
                return L.lscan(a, mbody, x, group_params)

            def mbody(h, xs):
                lp, sc, sh = xs
                h2, ns = S.mamba2_block(q, a, lp, h, mode,
                                        {"conv": sc, "h": sh}, **tpk)
                return h2, (ns["conv"], ns["h"])
            return L.lscan(a, mbody, x,
                           (group_params, states["conv"], states["h"]))

        if mode == "train":
            def gbody(h, xs):
                gp = xs
                h, sts = mamba_scan(h, gp, None)
                h, kv = _attn_shared(q, a, shared, h, pos, "train",
                                     "emit" if emit else None, **tpk)
                return h, (sts, kv)
            gbody = L.maybe_remat(a, gbody)
            x, (g_states, g_kv) = L.lscan(a, gbody, x, head)
            t_states = None
            if self.tail:
                def tbody(h, lp):
                    h2, st = S.mamba2_block(q, a, lp, h, "train", **tpk)
                    return h2, st
                tbody = L.maybe_remat(a, tbody)
                x, t_states = L.lscan(a, tbody, x, tail)
            return x, (g_states, g_kv, t_states)

        # decode (s==1, per-lane positions) or chunk (one lane, one page)
        paged = "k_pages" in cache

        def gbody(h, xs):
            gp, st_c, st_h, ck, cv = xs
            h, (nc, nh) = mamba_scan(h, gp, {"conv": st_c, "h": st_h})
            if paged:
                lc = {"k_pages": ck, "v_pages": cv,
                      "k_scale": cache["k_scale"][0],
                      "v_scale": cache["v_scale"][0],
                      "table": cache["table"]}
            else:
                lc = {"k": ck, "v": cv, "k_scale": cache["k_scale"][0],
                      "v_scale": cache["v_scale"][0]}
            h, (nk, nv) = _attn_shared(q, a, shared, h, pos, mode, lc,
                                       **tpk)
            return h, (nc, nh, nk, nv)

        g, ae = self.n_groups, a.attn_every
        mc = cache["m_conv"][: g * ae].reshape((g, ae) +
                                               cache["m_conv"].shape[1:])
        mh = cache["m_h"][: g * ae].reshape((g, ae) + cache["m_h"].shape[1:])
        kv_xs = ((cache["k_pages"], cache["v_pages"]) if paged
                 else (cache["k"], cache["v"]))
        x, (nc, nh, nk, nv) = L.lscan(
            a, gbody, x, (head, mc, mh) + kv_xs)
        nc = nc.reshape((-1,) + nc.shape[2:])
        nh = nh.reshape((-1,) + nh.shape[2:])
        if self.tail:
            def tbody(h, xs):
                lp, sc, sh = xs
                h2, ns = S.mamba2_block(q, a, lp, h, mode,
                                        {"conv": sc, "h": sh}, **tpk)
                return h2, (ns["conv"], ns["h"])
            x, (tc, th) = L.lscan(
                a, tbody, x, (tail, cache["m_conv"][g * ae:],
                              cache["m_h"][g * ae:]))
            nc = jnp.concatenate([nc, tc], 0)
            nh = jnp.concatenate([nh, th], 0)
        if paged:
            new_cache = dict(cache, m_conv=nc, m_h=nh, k_pages=nk,
                             v_pages=nv)
        else:
            new_cache = dict(cache, m_conv=nc, m_h=nh, k=nk, v=nv)
        if mode == "decode":
            new_cache["pos"] = cache["pos"] + 1
        return x, new_cache

    def _logits(self, params, x):
        h = qrmsnorm(self.q, x, params["final_norm"])
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
        logits = L.constrain(self.mesh, logits, P(self.dp, None, self.tp))
        if self.a.vocab_padded != self.a.vocab:
            pad = jnp.arange(self.a.vocab_padded) >= self.a.vocab
            logits = jnp.where(pad, L.NEG_INF, logits)
        return logits

    def loss(self, params, batch, key=None):
        tokens, labels = batch["tokens"], batch["labels"]
        x = params["embed"][tokens]
        pos = jnp.arange(tokens.shape[1])
        x, _ = self._backbone(params, x, pos, "train")
        logits = self._logits(params, x)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = L.target_logit(logits, labels)
        loss = jnp.mean(lse - tgt)
        return loss, {"loss": loss}

    def init_cache(self, b, t):
        a = self.a
        di, n = a.d_inner, a.ssm_state
        hm = di // a.headdim
        return {
            "m_conv": jnp.zeros((a.n_layers, b, a.d_conv - 1, di),
                                jnp.float32),
            "m_h": jnp.zeros((a.n_layers, b, hm, n, a.headdim), jnp.float32),
            "k": jnp.zeros((self.n_groups, b, t, a.n_kv, a.dh), jnp.int8),
            "v": jnp.zeros((self.n_groups, b, t, a.n_kv, a.dh), jnp.int8),
            "k_scale": jnp.full((self.n_groups,), 2.0 ** -7, jnp.float32),
            "v_scale": jnp.full((self.n_groups,), 2.0 ** -7, jnp.float32),
            "pos": jnp.zeros((b,), jnp.int32),
        }

    def prefill(self, params, tokens, cache_len):
        a = self.a
        b, s = tokens.shape
        x = params["embed"][tokens]
        pos = jnp.arange(s)
        x, (g_states, g_kv, t_states) = self._backbone(
            params, x, pos, "train", cache="emit")
        cache = self.init_cache(b, cache_len)
        gc = g_states
        nc = gc["conv"].reshape((-1,) + gc["conv"].shape[2:])
        nh = gc["h"].reshape((-1,) + gc["h"].shape[2:])
        if self.tail:
            nc = jnp.concatenate([nc, t_states["conv"]], 0)
            nh = jnp.concatenate([nh, t_states["h"]], 0)
        k8, v8 = g_kv
        cache.update(m_conv=nc, m_h=nh,
                     k=cache["k"].at[:, :, :s].set(k8),
                     v=cache["v"].at[:, :, :s].set(v8),
                     pos=jnp.full((b,), s, jnp.int32))
        return cache, self._logits(params, x[:, -1:])[:, 0]

    def serve_step(self, params, cache, tokens):
        x = params["embed"][tokens][:, None, :]
        x, cache = self._backbone(params, x, cache["pos"], "decode", cache)
        return cache, self._logits(params, x)[:, 0]

    # ---------------- serving decode-state slot API ----------------
    # Hybrid lanes split across both stores: the mamba recurrent state sits
    # in dense per-lane slots, the shared-attention KV in paged pool pages
    # (one logical page spans all n_groups applications of the block).

    def decode_state_spec(self):
        # tp_axes: stacked-slot axes sharded over the model axis under
        # manual TP (m_h is (L,B,hm,N,pdim) with SSD heads sharded; the
        # conv window is replicated).
        a = self.a
        return {"kv_layers": self.n_groups, "n_kv": a.n_kv, "dh": a.dh,
                "dense_axes": {"m_conv": 1, "m_h": 1, "pos": 0},
                "tp_axes": {"m_h": 2}}

    def init_slots(self, n_lanes: int):
        a = self.a
        di, n = a.d_inner, a.ssm_state
        hm = di // a.headdim
        return {
            "m_conv": jnp.zeros((a.n_layers, n_lanes, a.d_conv - 1, di),
                                jnp.float32),
            "m_h": jnp.zeros((a.n_layers, n_lanes, hm, n, a.headdim),
                             jnp.float32),
            "pos": jnp.zeros((n_lanes,), jnp.int32),
        }

    def slot_from_cache(self, cache, b: int = 0):
        return ({"m_conv": cache["m_conv"][:, b], "m_h": cache["m_h"][:, b],
                 "pos": cache["pos"][b]},
                (cache["k"][:, b], cache["v"][:, b]))

    def paged_decode_step(self, params, slots, pool_view, tokens):
        """One fused decode step over all lanes: mamba states advance in the
        dense slots, the shared-attention KV reads/writes pool pages.
        Positions advance in the engine (dead lanes must not move)."""
        cache = dict(pool_view, m_conv=slots["m_conv"], m_h=slots["m_h"],
                     pos=slots["pos"])
        x = params["embed"][tokens][:, None, :]
        x, nc = self._backbone(params, x, slots["pos"], "decode", cache)
        logits = self._logits(params, x)[:, 0]
        return logits, {"m_conv": nc["m_conv"], "m_h": nc["m_h"],
                        "pos": slots["pos"]}, \
            {"k_pages": nc["k_pages"], "v_pages": nc["v_pages"]}

    def prefill_page(self, params, dense, pool_view, tokens, pos0):
        """Chunked prefill: one page of one lane's prompt (see
        LMTransformer.prefill_page).  Mamba states advance through the
        page via the train-style 'chunk' scan seeded from `dense`; the
        shared-attention KV page lands in the pool.  The returned dense
        values are the page-boundary state snapshot the radix cache stores
        per node — restoring it on a prefix hit reproduces the recurrent
        state bitwise (same pure function of the same token prefix)."""
        page = pool_view["k_pages"].shape[2]
        x = params["embed"][tokens][None]               # (1, page, d)
        pos = pos0 + jnp.arange(page)
        cache = dict(pool_view, m_conv=dense["m_conv"], m_h=dense["m_h"])
        x, nc = self._backbone(params, x, pos, "chunk", cache)
        logits = self._logits(params, x[:, -1:])[:, 0]
        return logits, {"m_conv": nc["m_conv"], "m_h": nc["m_h"],
                        "pos": dense["pos"]}, \
            {"k_pages": nc["k_pages"], "v_pages": nc["v_pages"]}

    def batch_pspec(self):
        return {"tokens": P(self.dp, None), "labels": P(self.dp, None)}

    def cache_pspec(self, long=False):
        dp, tp = self.dp, self.tp
        bdim = None if long else dp
        tdim = ("data", tp) if long else tp
        return {"m_conv": P(None, bdim, None, tp),
                "m_h": P(None, bdim, tp, None, None),
                "k": P(None, bdim, tdim, None, None),
                "v": P(None, bdim, tdim, None, None),
                "k_scale": P(None), "v_scale": P(None), "pos": P(None)}

    def input_specs(self, shape_name, sb=None):
        s, b, kind = LM_SHAPES[shape_name]
        if sb is not None:
            s, b = sb
        a = self.a
        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if kind == "train":
            return {"tokens": tok, "labels": tok}, "train"
        if kind == "prefill":
            return {"tokens": tok}, "prefill"
        di, n = a.d_inner, a.ssm_state
        hm = di // a.headdim
        cache = {
            "m_conv": jax.ShapeDtypeStruct(
                (a.n_layers, b, a.d_conv - 1, di), jnp.float32),
            "m_h": jax.ShapeDtypeStruct((a.n_layers, b, hm, n, a.headdim),
                                        jnp.float32),
            "k": jax.ShapeDtypeStruct((self.n_groups, b, s, a.n_kv, a.dh),
                                      jnp.int8),
            "v": jax.ShapeDtypeStruct((self.n_groups, b, s, a.n_kv, a.dh),
                                      jnp.int8),
            "k_scale": jax.ShapeDtypeStruct((self.n_groups,), jnp.float32),
            "v_scale": jax.ShapeDtypeStruct((self.n_groups,), jnp.float32),
            "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
        }
        return {"cache": cache,
                "tokens": jax.ShapeDtypeStruct((b,), jnp.int32)}, "decode"
