"""State-space models: Mamba1 (falcon-mamba-7b) and Mamba2/SSD (zamba2-7b).

TPU adaptation (DESIGN.md §3/§6): all projections are WAGEUBN int8 matmuls;
the selective-scan recurrence runs on the fp32 VPU over 16-bit-gridded
inputs (INT8 states collapse under long product chains; the paper's k_BN=16
precedent applies).  Mamba2's SSD chunk formulation is matmul-based, so its
intra-chunk score/combine matmuls DO go through qeinsum (int8 MXU) — a
beyond-paper extension recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import (qact, qdense, qeinsum, qweight, qbn_param, qrmsnorm,
                        qt_carrier)
from repro.core.qconfig import QConfig
from repro.configs.base import ArchConfig
from . import layers as L

Array = jax.Array


def causal_conv1d(cfg, x, w, b, init=None):
    """Depthwise causal conv over seq.  x: (B,S,C), w: (K,C), b: (C,).

    `init` is the K-1 inputs PRECEDING x (the carried conv window of a
    chunked prefill); None means zero history (sequence start) — identical
    math, different left padding.
    """
    k = w.shape[0]
    wq = qt_carrier(qweight(cfg, w))   # conv runs on the fp32 grid carrier
    if init is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([init, x], axis=1)
    y = lax.conv_general_dilated(
        xp, wq[:, None, :], window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return y + b


def conv_window_tail(xi, prev, kc):
    """Next conv window: last kc inputs of (carried window ++ this chunk).
    Handles chunks shorter than the window without a dynamic slice."""
    return jnp.concatenate([prev, xi], axis=1)[:, -kc:]


# ==========================================================================
# Mamba1
# ==========================================================================


def mamba1_init(cfg: QConfig, acfg: ArchConfig, key):
    d, di, n = acfg.d_model, acfg.d_inner, acfg.ssm_state
    r = max(d // 16, 1)
    ks = jax.random.split(key, 8)
    dt = jnp.exp(jax.random.uniform(ks[6], (di,), minval=math.log(1e-3),
                                    maxval=math.log(1e-1)))
    return {
        "ln": jnp.ones((d,), jnp.float32),
        "in_proj": L.winit(cfg, ks[0], (d, 2 * di), d),
        "conv_w": L.winit(cfg, ks[1], (acfg.d_conv, di), acfg.d_conv),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": L.winit(cfg, ks[2], (di, r + 2 * n), di),
        "dt_proj": L.winit(cfg, ks[3], (r, di), r),
        "dt_bias": jnp.log(jnp.expm1(dt)),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (di, 1))),
        "D_skip": jnp.ones((di,), jnp.float32),
        "out_proj": L.winit(cfg, ks[4], (di, d), di),
    }


def mamba1_labels():
    return {"ln": "gamma", "in_proj": "w", "conv_w": "w", "conv_b": "beta",
            "x_proj": "w", "dt_proj": "w", "dt_bias": "exempt",
            "A_log": "exempt", "D_skip": "exempt", "out_proj": "w"}


def _sscan_chunked(a, b, c_coef, h0, chunk, unroll=False):
    """Selective scan h_t = a_t h_{t-1} + b_t, y_t = <c_t, h_t>.

    a, b: (B,S,d,N); c_coef: (B,S,N).  Chunked associative scan.
    Returns (y: (B,S,d), h_last: (B,d,N)).
    """
    bsz, s, d, n = a.shape
    chunk = min(chunk, s)
    pad = -s % chunk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_coef = jnp.pad(c_coef, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk
    ac = a.reshape(bsz, nc, chunk, d, n).transpose(1, 0, 2, 3, 4)
    bc = b.reshape(bsz, nc, chunk, d, n).transpose(1, 0, 2, 3, 4)
    cc = c_coef.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)

    def op(l, r):
        return (r[0] * l[0], r[0] * l[1] + r[1])

    def body(h, inp):
        ai, bi, ci = inp
        acum, bcum = lax.associative_scan(op, (ai, bi), axis=1)
        h_all = acum * h[:, None] + bcum            # (B,c,d,N)
        y = jnp.einsum("bcdn,bcn->bcd", h_all, ci)
        return h_all[:, -1], y

    h_last, ys = lax.scan(body, h0, (ac, bc, cc),
                          unroll=(True if unroll else 1))
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, nc * chunk, d)
    return y[:, :s], h_last


def mamba1_block(cfg: QConfig, acfg: ArchConfig, p, x, mode, state=None,
                 tp_size: int = 1, tp_axis: str = "model"):
    """x: (B,S,D).  mode 'train' (state ignored), 'chunk' (train-style
    parallel scan seeded from `state` — the chunked-prefill page step), or
    'decode' (S==1, state carried per token).

    Manual TP (tp_size > 1, inside a shard_map body): the block splits on
    the d_inner channel axis.  ln/in_proj/conv stay REPLICATED (the conv
    mixes nothing across channels but its window state is cheapest shared);
    each rank then slices its d_inner/tp channel block and runs the scan
    locally — x_proj/out_proj are row-sharded (tp_exit rejoins), dt_proj is
    column-sharded, and dt_bias/A_log/D_skip are per-channel slices.  The
    carried `h` state is sharded on its channel axis; `conv` is replicated.
    Bit-exact vs tp=1 because every quantizer scale is global (amax_sync)
    and every weight scale is fixed (DESIGN.md §12).
    """
    bsz, s, d = x.shape
    di, n = acfg.d_inner, acfg.ssm_state
    dil = di // tp_size                              # local channel count
    tp = tp_size > 1
    r = max(d // 16, 1)
    h = qact(cfg, "none", qrmsnorm(cfg, x, p["ln"]))
    xz = qdense(cfg, h, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)

    new_state = None
    if mode == "train":
        xc = causal_conv1d(cfg, xi, p["conv_w"], p["conv_b"])
    elif mode == "chunk":
        xc = causal_conv1d(cfg, xi, p["conv_w"], p["conv_b"],
                           init=state["conv"])
    else:
        conv_s = state["conv"]                       # (B, K-1, di)
        window = jnp.concatenate([conv_s, xi], axis=1)
        wq = qt_carrier(qweight(cfg, p["conv_w"]))
        xc = jnp.einsum("kc,bkc->bc", wq, window)[:, None] + p["conv_b"]
        new_conv = window[:, 1:]
    if tp:
        off = lax.axis_index(tp_axis) * dil
        xc = lax.dynamic_slice_in_dim(L.tp_enter(tp_axis, xc), off, dil, -1)
        z = lax.dynamic_slice_in_dim(L.tp_enter(tp_axis, z), off, dil, -1)
    xc = qact(cfg, "silu", xc)

    meta = qdense(cfg, xc, p["x_proj"])
    if tp:
        meta = L.tp_exit(tp_axis, meta)              # partial row outputs
    dtr, bs, cs = jnp.split(meta, [r, r + n], axis=-1)
    dtr = qact(cfg, "none", dtr)
    if tp:
        dtr = L.tp_enter(tp_axis, dtr)               # feeds sharded dt_proj
    dt = jax.nn.softplus(qdense(cfg, dtr, p["dt_proj"]) + p["dt_bias"])
    dt = qbn_param(cfg, dt, cfg.k_bn)                # 16-bit grid (DESIGN §3)
    bs = qbn_param(cfg, bs, cfg.k_bn)
    cs = qbn_param(cfg, cs, cfg.k_bn)
    a_mat = -jnp.exp(p["A_log"])                     # (di, N)

    if mode in ("train", "chunk"):
        sdt = jnp.bfloat16 if cfg.scan_dtype == "bf16" else jnp.float32
        a = jnp.exp(dt[..., None] * a_mat).astype(sdt)   # (B,S,di,N)
        b = ((dt * xc)[..., None] * bs[:, :, None, :]).astype(sdt)
        h0 = (state["h"].astype(sdt) if mode == "chunk"
              else jnp.zeros((bsz, dil, n), sdt))
        y, h_last = _sscan_chunked(a, b, cs.astype(sdt), h0,
                                   chunk=acfg.scan_chunk,
                                   unroll=acfg.unroll_scan_chunks)
        y = y.astype(jnp.float32)
        kc = acfg.d_conv - 1
        if mode == "chunk":     # fp32 state: carry dtype of the slot store
            new_state = {"conv": conv_window_tail(xi, state["conv"], kc),
                         "h": h_last.astype(jnp.float32)}
        else:
            conv_tail = (jnp.pad(xi, ((0, 0), (kc - s, 0), (0, 0)))
                         if s < kc else xi[:, s - kc:])
            new_state = {"conv": conv_tail, "h": h_last}
    else:
        hs = state["h"]                              # (B, di, N)
        a1 = jnp.exp(dt[:, 0, :, None] * a_mat)
        b1 = (dt * xc)[:, 0, :, None] * bs[:, 0, None, :]
        hs = a1 * hs + b1
        y = jnp.einsum("bdn,bn->bd", hs, cs[:, 0])[:, None]
        new_state = {"conv": new_conv, "h": hs}

    y = y + p["D_skip"] * xc
    y = y * qact(cfg, "silu", z)
    out = qdense(cfg, qact(cfg, "none", y), p["out_proj"])
    if tp:
        out = L.tp_exit(tp_axis, out)                # partial row outputs
    return x + out, new_state


def mamba1_state_init(acfg: ArchConfig, bsz):
    di, n = acfg.d_inner, acfg.ssm_state
    return {"conv": jnp.zeros((bsz, acfg.d_conv - 1, di), jnp.float32),
            "h": jnp.zeros((bsz, di, n), jnp.float32)}


# ==========================================================================
# Mamba2 (SSD)
# ==========================================================================


def mamba2_init(cfg: QConfig, acfg: ArchConfig, key):
    d, di, n = acfg.d_model, acfg.d_inner, acfg.ssm_state
    hm = di // acfg.headdim
    ks = jax.random.split(key, 8)
    dt = jnp.exp(jax.random.uniform(ks[6], (hm,), minval=math.log(1e-3),
                                    maxval=math.log(1e-1)))
    return {
        "ln": jnp.ones((d,), jnp.float32),
        "in_proj": L.winit(cfg, ks[0], (d, 2 * di), d),
        "conv_w": L.winit(cfg, ks[1], (acfg.d_conv, di), acfg.d_conv),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "bc_proj": L.winit(cfg, ks[2], (d, 2 * n), d),
        "dt_proj": L.winit(cfg, ks[3], (d, hm), d),
        "dt_bias": jnp.log(jnp.expm1(dt)),
        "A_log": jnp.zeros((hm,), jnp.float32),
        "D_skip": jnp.ones((hm,), jnp.float32),
        "ssm_norm": jnp.ones((di,), jnp.float32),
        "out_proj": L.winit(cfg, ks[4], (di, d), di),
    }


def mamba2_labels():
    return {"ln": "gamma", "in_proj": "w", "conv_w": "w", "conv_b": "beta",
            "bc_proj": "w", "dt_proj": "w", "dt_bias": "exempt",
            "A_log": "exempt", "D_skip": "exempt", "ssm_norm": "gamma",
            "out_proj": "w"}


def _segsum_decay(alog):
    """alog: (B,c,H) per-step log decays -> cumulative sums for SSD."""
    return jnp.cumsum(alog, axis=1)


def mamba2_block(cfg: QConfig, acfg: ArchConfig, p, x, mode, state=None,
                 chunk: int | None = None, tp_size: int = 1,
                 tp_axis: str = "model"):
    """Manual TP (tp_size > 1): splits on SSD heads.  Everything that mixes
    across d_inner (in_proj/conv/bc_proj/ssm_norm/out_proj) stays
    REPLICATED; each rank slices its hm/tp contiguous head block (heads are
    contiguous pdim channel runs, so the channel slice is rank*di/tp), runs
    the recurrence locally (dt_proj column-sharded; dt_bias/A_log/D_skip
    per-head slices), and one integer-payload gather (tp_gather_lastdim)
    rejoins y before the replicated norm/gate/out tail.  Carried `h` state
    is head-sharded; `conv` is replicated."""
    bsz, s, d = x.shape
    di, n = acfg.d_inner, acfg.ssm_state
    pdim = acfg.headdim
    hm = di // pdim
    hml = hm // tp_size                                # local head count
    dil = hml * pdim                                   # local channel count
    tp = tp_size > 1

    h = qact(cfg, "none", qrmsnorm(cfg, x, p["ln"]))
    xz = qdense(cfg, h, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)
    bc = qdense(cfg, h, p["bc_proj"])
    bs, cs = jnp.split(bc, 2, axis=-1)                 # (B,S,N) each
    bs = qbn_param(cfg, bs, cfg.k_bn)
    cs = qbn_param(cfg, cs, cfg.k_bn)
    hd = L.tp_enter(tp_axis, h) if tp else h           # feeds sharded dt_proj
    dt = jax.nn.softplus(qdense(cfg, hd, p["dt_proj"]) + p["dt_bias"])
    dt = qbn_param(cfg, dt, cfg.k_bn)                  # (B,S,Hm/tp)
    a_neg = -jnp.exp(p["A_log"])                       # (Hm/tp,)

    new_state = None
    if chunk is None:
        chunk = acfg.scan_chunk
    if mode in ("train", "chunk"):
        xc = causal_conv1d(cfg, xi, p["conv_w"], p["conv_b"],
                           init=state["conv"] if mode == "chunk" else None)
        if tp:
            off = lax.axis_index(tp_axis) * dil
            xc = lax.dynamic_slice_in_dim(L.tp_enter(tp_axis, xc),
                                          off, dil, -1)
        xc = qact(cfg, "silu", xc)
        xh = qt_carrier(xc).reshape(bsz, s, hml, pdim)
        alog = dt * a_neg                              # (B,S,Hm) log decays
        chunk = min(chunk, s)
        pad = -s % chunk
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_, alog_, bs_, cs_ = (jnp.pad(t, ((0, 0), (0, pad)) +
                                            ((0, 0),) * (t.ndim - 2))
                                    for t in (dt, alog, bs, cs))
        else:
            dt_, alog_, bs_, cs_ = dt, alog, bs, cs
        nc = (s + pad) // chunk
        xhc = xh.reshape(bsz, nc, chunk, hml, pdim).transpose(1, 0, 2, 3, 4)
        dtc = dt_.reshape(bsz, nc, chunk, hml).transpose(1, 0, 2, 3)
        alc = alog_.reshape(bsz, nc, chunk, hml).transpose(1, 0, 2, 3)
        bsc = bs_.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
        csc = cs_.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)

        def body(s0, inp):
            xcb, dtb, alb, bsb, csb = inp
            cum = _segsum_decay(alb)                   # (B,c,Hm)
            # intra-chunk: quantized score matmul (beyond-paper INT8 SSD)
            scores = qeinsum(cfg, "btn,bsn->bts", cfg.e_attn, False, csb, bsb)
            ldec = jnp.exp(jnp.clip(cum[:, :, None, :] - cum[:, None, :, :],
                                    -60.0, 0.0))
            tt = jnp.arange(xcb.shape[1])
            causal = (tt[:, None] >= tt[None, :])[None, :, :, None]
            m = scores[:, :, :, None] * ldec * dtb[:, None, :, :] * causal
            m = qact(cfg, "none", m)
            y_in = qeinsum(cfg, "btsh,bshp->bthp", cfg.e_attn, False, m, xcb)
            # inter-chunk
            dec0 = jnp.exp(cum)                        # (B,c,Hm)
            y_x = jnp.einsum("btn,bhnp->bthp", csb, s0) * dec0[..., None]
            # state update
            dec_end = jnp.exp(jnp.clip(cum[:, -1:, :] - cum, -60.0, 0.0))
            wx = xcb * (dtb * dec_end)[..., None]
            s_new = (jnp.exp(cum[:, -1])[:, :, None, None] * s0
                     + jnp.einsum("bsn,bshp->bhnp", bsb, wx))
            return s_new, y_in + y_x

        s0 = (state["h"] if mode == "chunk"
              else jnp.zeros((bsz, hml, n, pdim), jnp.float32))
        s_last, ys = lax.scan(body, s0, (xhc, dtc, alc, bsc, csc),
                              unroll=(True if acfg.unroll_scan_chunks
                                      else 1))
        y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, nc * chunk, hml, pdim)
        y = y[:, :s]
        xh = xh[:, :s]
        kc = acfg.d_conv - 1
        if mode == "chunk":
            new_state = {"conv": conv_window_tail(xi, state["conv"], kc),
                         "h": s_last}
        else:
            conv_tail = (jnp.pad(xi, ((0, 0), (kc - s, 0), (0, 0)))
                         if s < kc else xi[:, s - kc:])
            new_state = {"conv": conv_tail, "h": s_last}
    else:
        conv_s = state["conv"]
        window = jnp.concatenate([conv_s, xi], axis=1)
        wq = qt_carrier(qweight(cfg, p["conv_w"]))
        xc = jnp.einsum("kc,bkc->bc", wq, window)[:, None] + p["conv_b"]
        if tp:
            off = lax.axis_index(tp_axis) * dil
            xc = lax.dynamic_slice_in_dim(L.tp_enter(tp_axis, xc),
                                          off, dil, -1)
        xc = qact(cfg, "silu", xc)
        xh = qt_carrier(xc).reshape(bsz, 1, hml, pdim)
        ss = state["h"]                                # (B,Hm,N,P)
        dt1 = dt[:, 0]                                 # (B,Hm)
        dec = jnp.exp(dt1 * a_neg)[:, :, None, None]
        upd = jnp.einsum("bn,bhp->bhnp", bs[:, 0], xh[:, 0] * dt1[..., None])
        ss = dec * ss + upd
        y = jnp.einsum("bn,bhnp->bhp", cs[:, 0], ss)[:, None]
        new_state = {"conv": window[:, 1:], "h": ss}

    y = y + p["D_skip"][:, None] * xh
    y = y.reshape(bsz, -1, dil)
    if tp:
        y = L.tp_gather_lastdim(tp_axis, y)            # rejoin head shards
    y = qrmsnorm(cfg, y, p["ssm_norm"]) * qact(cfg, "silu", z)
    out = qdense(cfg, qact(cfg, "none", y), p["out_proj"])
    return x + out, new_state


def mamba2_state_init(acfg: ArchConfig, bsz):
    di, n = acfg.d_inner, acfg.ssm_state
    hm = di // acfg.headdim
    return {"conv": jnp.zeros((bsz, acfg.d_conv - 1, di), jnp.float32),
            "h": jnp.zeros((bsz, hm, n, acfg.headdim), jnp.float32)}
