from .registry import FAMILIES, build_model

__all__ = ["FAMILIES", "build_model"]
