"""Expert-parallel Mixture-of-Experts FFN (capacity dispatch, shard_map EP).

Tokens are replicated across the tensor/expert axis (they already are in the
pjit TP scheme — activations enter layers replicated over "model"), experts
are sharded over it.  Each device builds capacity buffers for its local
experts only, runs the quantized expert matmuls, scatters contributions back
and psums across the expert axis.  Routing is computed identically on every
expert rank (deterministic), so no dispatch collective is needed; the only
communication is the output psum — the same all-reduce TP already pays.

The router is exempt from quantization (a softmax decision path, mirroring
the paper's first/last-layer exemption — DESIGN.md §6).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import SHARD_MAP_KW as _SM_KW
from repro.compat import shard_map as _shard_map

from repro.core import qact, qeinsum, qt_carrier, qweight
from repro.core.qconfig import QConfig


def init_moe_params(cfg, acfg, key):
    from .layers import winit
    e, d, f = acfg.moe_experts, acfg.d_model, acfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * 0.02,
        "wg": winit(cfg, ks[1], (e, d, f), d),
        "wu": winit(cfg, ks[2], (e, d, f), d),
        "wd": winit(cfg, ks[3], (e, f, d), f),
    }


def moe_labels():
    return {"router": "exempt", "wg": "w", "wu": "w", "wd": "w"}


def moe_pspecs(dp, tp):
    return {"router": P(None, None), "wg": P(tp, None, None),
            "wu": P(tp, None, None), "wd": P(tp, None, None)}


def _moe_local(cfg: QConfig, acfg, x, rw, wg, wu, wd, e_off,
               dropless: bool = False):
    """Per-device MoE on local tokens x:(T,D) with local experts.

    `dropless` sizes capacity to worst case (cap = T*k).  Decode uses it:
    a one-token-per-lane batch is tiny, and under the serving engine's
    padded lane batches a capacity drop would let DEAD lanes displace live
    tokens from expert slots — routing must not depend on lane padding.
    """
    t, d = x.shape
    e, k = acfg.moe_experts, acfg.moe_topk
    el = wg.shape[0]
    if dropless:
        cap = t * k
    else:
        cap = max(1, int(math.ceil(t * k / e * acfg.capacity_factor)))

    logits = x @ rw                                     # router (exempt fp32)
    vals, idx = lax.top_k(logits, k)                    # (T, k)
    gates = jax.nn.softmax(vals, axis=-1)
    e_flat = idx.reshape(-1)
    g_flat = gates.reshape(-1)
    t_flat = jnp.repeat(jnp.arange(t), k)

    oh = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(oh, axis=0) - 1,
                              e_flat[:, None], axis=1)[:, 0]
    ok = (e_flat >= e_off) & (e_flat < e_off + el) & (pos < cap)
    e_loc = jnp.where(ok, e_flat - e_off, el)           # el => dropped
    pos_c = jnp.where(ok, pos, cap)

    # Inverse dispatch map (el, cap): which token fills each capacity slot.
    # Gathering x through it builds the (el, cap, d) buffer directly —
    # never materializing the (T*k, d) token copies (memory term, §Perf).
    tid = jnp.zeros((el + 1, cap + 1), jnp.int32)
    tid = tid.at[e_loc, pos_c].set(t_flat, mode="drop")
    gbuf = jnp.zeros((el + 1, cap + 1), x.dtype)
    gbuf = gbuf.at[e_loc, pos_c].set(jnp.where(ok, g_flat, 0.0), mode="drop")
    tid, gbuf = tid[:el, :cap], gbuf[:el, :cap]
    xbuf = x[tid] * (gbuf != 0)[..., None]

    # quantized expert matmuls (SwiGLU)
    gate = qact(cfg, acfg.act,
                qeinsum(cfg, "ecd,edf->ecf", "default", True, xbuf, qweight(cfg, wg)))
    up = qact(cfg, "none",
              qeinsum(cfg, "ecd,edf->ecf", "default", True, xbuf, qweight(cfg, wu)))
    h = qact(cfg, "none", gate * up)
    ybuf = qeinsum(cfg, "ecf,efd->ecd", "default", True, h, qweight(cfg, wd))

    # combine: scatter-add weighted expert outputs back to tokens (slots
    # with gate 0 scatter zeros to token 0 — harmless)
    y = jnp.zeros((t, d), x.dtype)
    y = y.at[tid].add(ybuf * gbuf[..., None], mode="drop")
    return y


def moe_ffn(cfg: QConfig, acfg, x, p, mesh=None, dp_axes=("data",),
            tp_axis="model", tp_size: int = 1):
    """x: (B, S, D) on the activation grid -> (B, S, D).

    QTensor inputs degrade to their grid carrier here: the capacity
    dispatch (gather + gate mask) and shard_map specs operate on flat fp32;
    the expert matmuls re-enter the integer path via qeinsum/qweight.

    Three parallelism regimes:
      mesh given        — this function owns a shard_map (pjit callers).
      tp_size > 1       — manual expert parallelism INSIDE an enclosing
                          full-manual shard_map (the sharded train step):
                          expert params arrive pre-sliced over `tp_axis`,
                          routing is computed identically on every rank,
                          and the caller's tp_exit psums the partial
                          outputs.  The router is replicated, so its
                          cotangent (partial per rank: only local experts'
                          gate paths) re-enters through tp_enter.
      neither           — single-device local MoE.
    """
    x = qt_carrier(x)
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)

    dropless = s == 1                   # decode: see _moe_local docstring
    if tp_size > 1:
        from .layers import tp_enter
        el = p["wg"].shape[0]           # local expert count (pre-sliced)
        e_off = lax.axis_index(tp_axis) * el
        y = _moe_local(cfg, acfg, x2, tp_enter(tp_axis, p["router"]),
                       p["wg"], p["wu"], p["wd"], e_off, dropless=dropless)
        return y.reshape(b, s, d)       # partial; caller's tp_exit psums
    if mesh is None or tp_axis not in mesh.axis_names:
        y = _moe_local(cfg, acfg, x2, p["router"], p["wg"], p["wu"], p["wd"],
                       e_off=0, dropless=dropless)
        return y.reshape(b, s, d)

    el = acfg.moe_experts // mesh.shape[tp_axis]

    def f(x2, rw, wg, wu, wd):
        e_off = lax.axis_index(tp_axis) * el
        y = _moe_local(cfg, acfg, x2, rw, wg, wu, wd, e_off,
                       dropless=dropless)
        return lax.psum(y, tp_axis)

    fn = _shard_map(
        f, mesh=mesh,
        in_specs=(P(dp_axes, None), P(None, None), P(tp_axis, None, None),
                  P(tp_axis, None, None), P(tp_axis, None, None)),
        out_specs=P(dp_axes, None), **_SM_KW)
    y = fn(x2, p["router"], p["wg"], p["wu"], p["wd"])
    return y.reshape(b, s, d)
