"""Paper-faithful ResNet18/34/50 with WAGEUBN quantized conv + BN + Momentum.

First conv and final FC are exempt from quantization (paper §IV-A).  Every
hidden conv goes through qconv (Q_W weights, Q_E2 errors), every BN through
qbatchnorm (Eq. 12), every ReLU through qact (Q_A forward / Q_E1 backward).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import qact, qconv, qbatchnorm, qt_carrier, qweight
from repro.core.qconfig import QConfig
from repro.configs.base import ArchConfig
from . import layers as L

Array = jax.Array


def _conv_init(cfg, key, kh, kw, cin, cout):
    return L.winit(cfg, key, (kh, kw, cin, cout), kh * kw * cin)


def _bn_init(c):
    return {"gamma": jnp.ones((c,), jnp.float32),
            "beta": jnp.zeros((c,), jnp.float32)}


class ResNet:
    def __init__(self, acfg: ArchConfig, qcfg: QConfig, mesh=None,
                 dp_axes=("data",), tp_axis="model", tp_size: int = 1):
        self.a, self.q = acfg, qcfg
        self.mesh, self.dp, self.tp = mesh, dp_axes, tp_axis
        self.tp_size = tp_size
        if tp_size != 1:
            raise ValueError(
                f"{type(self).__name__} supports DP-only sharding "
                f"(manual TP shards attention heads / FFN / experts; "
                f"got tp_size={tp_size})")
        self.bottleneck = acfg.block == "bottleneck"
        self.widths = (64, 128, 256, 512)[: len(acfg.stage_sizes)]

    def _init_block(self, key, cin, cout, stride):
        ks = jax.random.split(key, 5)
        if self.bottleneck:
            mid = cout // 4
            p = {
                "conv1": _conv_init(self.q, ks[0], 1, 1, cin, mid),
                "bn1": _bn_init(mid),
                "conv2": _conv_init(self.q, ks[1], 3, 3, mid, mid),
                "bn2": _bn_init(mid),
                "conv3": _conv_init(self.q, ks[2], 1, 1, mid, cout),
                "bn3": _bn_init(cout),
            }
        else:
            p = {
                "conv1": _conv_init(self.q, ks[0], 3, 3, cin, cout),
                "bn1": _bn_init(cout),
                "conv2": _conv_init(self.q, ks[1], 3, 3, cout, cout),
                "bn2": _bn_init(cout),
            }
        if stride != 1 or cin != cout:
            p["proj"] = _conv_init(self.q, ks[3], 1, 1, cin, cout)
            p["bn_proj"] = _bn_init(cout)
        return p

    def init(self, key):
        a = self.a
        ks = jax.random.split(key, 3 + len(a.stage_sizes))
        mult = 4 if self.bottleneck else 1
        params = {
            # first layer exempt (fp32)
            "stem": jax.random.normal(ks[0], (7, 7, 3, 64)) * 0.05,
            "bn_stem": _bn_init(64),
            "stages": [],
            "fc": jax.random.normal(ks[1], (self.widths[-1] * mult,
                                            a.num_classes)) * 0.01,
            "fc_b": jnp.zeros((a.num_classes,), jnp.float32),
        }
        cin = 64
        stages = []
        for si, n in enumerate(a.stage_sizes):
            cout = self.widths[si] * mult
            blocks = []
            bks = jax.random.split(ks[2 + si], n)
            for bi in range(n):
                stride = 2 if (si > 0 and bi == 0) else 1
                blocks.append(self._init_block(bks[bi], cin, cout, stride))
                cin = cout
            stages.append(blocks)
        params["stages"] = stages
        return params

    def labels(self, params):
        def bn_lab(_):
            return {"gamma": "gamma", "beta": "beta"}
        lab = {"stem": "exempt", "bn_stem": bn_lab(None), "stages": [],
               "fc": "exempt", "fc_b": "exempt"}
        for blocks in params["stages"]:
            st = []
            for b in blocks:
                d = {}
                for k in b:
                    d[k] = bn_lab(None) if k.startswith("bn") else "w"
                st.append(d)
            lab["stages"].append(st)
        return lab

    def pspecs(self):
        return jax.tree.map(lambda _: P(), {})  # CPU-scale model

    def _block(self, p, x, stride):
        q = self.q
        idn = x
        if self.bottleneck:
            h = qact(q, "relu", qbatchnorm(q, qconv(
                q, x, qweight(q, p["conv1"]), 1, "SAME"),
                p["bn1"]["gamma"], p["bn1"]["beta"]))
            h = qact(q, "relu", qbatchnorm(q, qconv(
                q, h, qweight(q, p["conv2"]), stride, "SAME"),
                p["bn2"]["gamma"], p["bn2"]["beta"]))
            h = qbatchnorm(q, qconv(q, h, qweight(q, p["conv3"]), 1, "SAME"),
                           p["bn3"]["gamma"], p["bn3"]["beta"])
        else:
            h = qact(q, "relu", qbatchnorm(q, qconv(
                q, x, qweight(q, p["conv1"]), stride, "SAME"),
                p["bn1"]["gamma"], p["bn1"]["beta"]))
            h = qbatchnorm(q, qconv(q, h, qweight(q, p["conv2"]), 1, "SAME"),
                           p["bn2"]["gamma"], p["bn2"]["beta"])
        if "proj" in p:
            idn = qbatchnorm(q, qconv(q, x, qweight(q, p["proj"]), stride,
                                      "SAME"),
                             p["bn_proj"]["gamma"], p["bn_proj"]["beta"])
        return qact(q, "relu", h + idn)

    def forward(self, params, images):
        q = self.q
        # exempt stem (fp32 conv + BN + relu, no quantizers)
        x = jax.lax.conv_general_dilated(
            images, params["stem"], (2, 2), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        from repro.core.qconfig import FP32
        x = qbatchnorm(FP32, x, params["bn_stem"]["gamma"],
                       params["bn_stem"]["beta"])
        x = jax.nn.relu(x)
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                                  (1, 2, 2, 1), "SAME")
        x = qact(q, "none", x)
        for si, blocks in enumerate(params["stages"]):
            for bi, bp in enumerate(blocks):
                stride = 2 if (si > 0 and bi == 0) else 1
                x = self._block(bp, x, stride)
        x = jnp.mean(qt_carrier(x), axis=(1, 2))
        return x @ params["fc"] + params["fc_b"]      # exempt last layer

    def loss(self, params, batch, key=None):
        logits = self.forward(params, batch["images"])
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        loss = jnp.mean(lse - tgt)
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return loss, {"loss": loss, "acc": acc}

    def input_specs(self, shape_name=None):
        a = self.a
        return {
            "images": jax.ShapeDtypeStruct((128, a.img_size, a.img_size, 3),
                                           jnp.float32),
            "labels": jax.ShapeDtypeStruct((128,), jnp.int32),
        }, "train"


RESNET_STAGES = {
    "resnet18": ("basic", (2, 2, 2, 2)),
    "resnet34": ("basic", (3, 4, 6, 3)),
    "resnet50": ("bottleneck", (3, 4, 6, 3)),
}
