"""Pure-SSM LM (falcon-mamba-7b): stacked Mamba1 blocks, O(1) decode state."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.qconfig import QConfig
from repro.configs.base import ArchConfig, LM_SHAPES
from . import layers as L
from . import ssm as S


class SSMLM:
    def __init__(self, acfg: ArchConfig, qcfg: QConfig, mesh=None,
                 dp_axes=("data",), tp_axis="model", tp_size: int = 1):
        self.a, self.q = acfg, qcfg
        self.mesh, self.dp, self.tp = mesh, dp_axes, tp_axis
        self.tp_size = tp_size
        if tp_size > 1 and acfg.d_inner % tp_size:
            raise ValueError(
                f"manual TP shards the d_inner channel axis: "
                f"d_inner={acfg.d_inner} % tp={tp_size} != 0")

    def init(self, key):
        a = self.a
        ks = jax.random.split(key, 4)
        lk = jax.random.split(ks[0], a.n_layers)
        layers = jax.vmap(lambda k: S.mamba1_init(self.q, a, k))(lk)
        return {
            "embed": jax.random.normal(ks[1], (a.vocab_padded, a.d_model),
                                       jnp.float32) * 0.02,
            "layers": layers,
            "final_norm": jnp.ones((a.d_model,), jnp.float32),
            "lm_head": jax.random.normal(ks[2], (a.d_model, a.vocab_padded),
                                         jnp.float32) * 0.02,
        }

    def labels(self, params):
        return {"embed": "exempt", "layers": S.mamba1_labels(),
                "final_norm": "gamma", "lm_head": "exempt"}

    def pspecs(self):
        dp, tp = self.dp, self.tp
        layer = {"ln": P(None, None), "in_proj": P(None, dp, tp),
                 "conv_w": P(None, None, tp), "conv_b": P(None, tp),
                 "x_proj": P(None, tp, None), "dt_proj": P(None, None, tp),
                 "dt_bias": P(None, tp), "A_log": P(None, tp, None),
                 "D_skip": P(None, tp), "out_proj": P(None, tp, dp)}
        return {"embed": P(None, tp), "layers": layer,
                "final_norm": P(None), "lm_head": P(None, tp)}

    def _backbone(self, params, x, mode, state=None):
        if mode == "train":
            def body(h, lp):
                h = L.constrain(self.mesh, h, P(self.dp, None, None))
                h2, st = S.mamba1_block(self.q, self.a, lp, h, "train",
                                        tp_size=self.tp_size,
                                        tp_axis=self.tp)
                return h2, st
            body = L.maybe_remat(self.a, body)
            x, states = L.lscan(self.a, body, x, params["layers"])
            return x, states

        def body(h, xs):
            lp, st_c, st_h = xs
            h2, ns = S.mamba1_block(self.q, self.a, lp, h, "decode",
                                    {"conv": st_c, "h": st_h},
                                    tp_size=self.tp_size, tp_axis=self.tp)
            return h2, (ns["conv"], ns["h"])
        x, (nc, nh) = L.lscan(self.a, body, x,
                              (params["layers"], state["conv"], state["h"]))
        return x, {"conv": nc, "h": nh, "pos": state["pos"] + 1}

    def _logits(self, params, x):
        from repro.core import qrmsnorm
        h = qrmsnorm(self.q, x, params["final_norm"])
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
        logits = L.constrain(self.mesh, logits, P(self.dp, None, self.tp))
        if self.a.vocab_padded != self.a.vocab:
            pad = jnp.arange(self.a.vocab_padded) >= self.a.vocab
            logits = jnp.where(pad, L.NEG_INF, logits)
        return logits

    def loss(self, params, batch, key=None):
        tokens, labels = batch["tokens"], batch["labels"]
        x = params["embed"][tokens]
        x, _ = self._backbone(params, x, "train")
        logits = self._logits(params, x)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = L.target_logit(logits, labels)
        loss = jnp.mean(lse - tgt)
        return loss, {"loss": loss}

    def init_state(self, bsz):
        a = self.a
        st = jax.vmap(lambda _: S.mamba1_state_init(a, bsz))(
            jnp.arange(a.n_layers))
        return {"conv": st["conv"], "h": st["h"],
                "pos": jnp.zeros((bsz,), jnp.int32)}

    def serve_step(self, params, state, tokens):
        x = params["embed"][tokens][:, None, :]
        x, state = self._backbone(params, x, "decode", state)
        return state, self._logits(params, x)[:, 0]

    # ---------------- serving decode-state slot API ----------------
    # SSM decode state is O(1) per lane, so there is no paged KV: the whole
    # state sits in dense per-lane slots behind the same engine interface.

    def decode_state_spec(self):
        # tp_axes: axis of each stacked dense slot sharded over the model
        # axis under manual TP (the mamba1 channel split: h is (L,B,di,N)
        # with di sharded; the conv window is replicated).
        return {"kv_layers": 0, "n_kv": 0, "dh": 0,
                "dense_axes": {"conv": 1, "h": 1, "pos": 0},
                "tp_axes": {"h": 2}}

    def init_slots(self, n_lanes: int):
        return self.init_state(n_lanes)

    def slot_from_cache(self, state, b: int = 0):
        return ({"conv": state["conv"][:, b], "h": state["h"][:, b],
                 "pos": state["pos"][b]}, None)

    def paged_decode_step(self, params, slots, pool_view, tokens):
        """One fused decode step over all lanes (pool_view unused: the SSM
        recurrent state IS the cache).  Positions advance in the engine."""
        del pool_view
        state = {"conv": slots["conv"], "h": slots["h"], "pos": slots["pos"]}
        state, logits = self.serve_step(params, state, tokens)
        return logits, {"conv": state["conv"], "h": state["h"],
                        "pos": slots["pos"]}, {}

    def prefill_page(self, params, dense, pool_view, tokens, pos0):
        """Chunked prefill: one page of one lane's prompt advances the
        per-layer mamba states (no KV pages — pool_view unused).  tokens:
        (page,) for a single lane; pos0 ignored (SSM state is positionless).
        """
        del pool_view, pos0
        x = params["embed"][tokens][None]               # (1, page, d)

        def body(h, xs):
            lp, st_c, st_h = xs
            h2, ns = S.mamba1_block(self.q, self.a, lp, h, "chunk",
                                    {"conv": st_c, "h": st_h},
                                    tp_size=self.tp_size, tp_axis=self.tp)
            return h2, (ns["conv"], ns["h"])
        x, (nc, nh) = L.lscan(self.a, body, x,
                              (params["layers"], dense["conv"], dense["h"]))
        logits = self._logits(params, x[:, -1:])[:, 0]
        return logits, {"conv": nc, "h": nh, "pos": dense["pos"]}, {}

    def batch_pspec(self):
        return {"tokens": P(self.dp, None), "labels": P(self.dp, None)}

    def cache_pspec(self, long=False):
        dp, tp = self.dp, self.tp
        bdim = None if long else dp   # long_500k has batch 1
        return {"conv": P(None, bdim, None, tp),
                "h": P(None, bdim, tp, None), "pos": P(None)}

    def input_specs(self, shape_name, sb=None):
        s, b, kind = LM_SHAPES[shape_name]
        if sb is not None:
            s, b = sb
        a = self.a
        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if kind == "train":
            return {"tokens": tok, "labels": tok}, "train"
        if kind == "prefill":
            return {"tokens": tok}, "prefill"
        di, n = a.d_inner, a.ssm_state
        state = {
            "conv": jax.ShapeDtypeStruct((a.n_layers, b, a.d_conv - 1, di),
                                         jnp.float32),
            "h": jax.ShapeDtypeStruct((a.n_layers, b, di, n), jnp.float32),
            "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
        }
        return {"cache": state,
                "tokens": jax.ShapeDtypeStruct((b,), jnp.int32)}, "decode"

    def prefill(self, params, tokens, cache_len=None):
        """Parallel (chunked-scan) prefill; emits per-layer SSM states."""
        bsz, s = tokens.shape
        x = params["embed"][tokens]
        x, states = self._backbone(params, x, "train")
        state = {"conv": states["conv"], "h": states["h"],
                 "pos": jnp.full((bsz,), s, jnp.int32)}
        return state, self._logits(params, x[:, -1:])[:, 0]
