"""Encoder-decoder backbone (seamless-m4t-large-v2).

Per assignment instructions the modality frontend is a STUB: input_specs()
provides precomputed audio-frame embeddings (B, S, D).  The frontend is
therefore the exempt "first layer" (paper rule).  The conformer encoder is
realized as its transformer backbone (DESIGN.md §6); decoder layers add
cross-attention over the (int8-cached) encoder memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import qact, qdense, qlayernorm
from repro.core.qconfig import QConfig
from repro.configs.base import ArchConfig, LM_SHAPES
from . import layers as L

Array = jax.Array


def _attn(cfg, acfg, p, x, kv_src, *, causal, q_pos, k_pos, cache=None,
          prefix=""):
    """Generic attention (self when kv_src is x, cross otherwise)."""
    b, s, _ = x.shape
    h = qact(cfg, "none", qlayernorm(cfg, x, p[prefix + "ln_g"],
                                     p[prefix + "ln_b"]))
    qh = qdense(cfg, h, p[prefix + "wq"]).reshape(b, s, acfg.n_heads, acfg.dh)
    if cache is not None and "kf" in cache:          # precomputed cross K/V
        kh, vh = cache["kf"], cache["vf"]
    else:
        src = kv_src if kv_src is not None else h
        t = src.shape[1]
        kh = qdense(cfg, src, p[prefix + "wk"]).reshape(b, t, acfg.n_kv,
                                                        acfg.dh)
        vh = qdense(cfg, src, p[prefix + "wv"]).reshape(b, t, acfg.n_kv,
                                                        acfg.dh)
        kh, vh = qact(cfg, "none", kh), qact(cfg, "none", vh)
    qh = qact(cfg, "none", qh)
    new_cache = None
    if cache is not None and "k8" in cache:          # decode self-attn
        pvec = q_pos
        bidx = jnp.arange(b)
        k8 = cache["k8"].at[bidx, pvec].set(
            L.kv_quantize(kh[:, 0], cache["k_scale"]))
        v8 = cache["v8"].at[bidx, pvec].set(
            L.kv_quantize(vh[:, 0], cache["v_scale"]))
        # the int8 cache IS the matmul operand: no dequantize round trip
        o = L.decode_attention(cfg, qh, L.kv_qtensor(k8, cache["k_scale"]),
                               L.kv_qtensor(v8, cache["v_scale"]),
                               q_pos=pvec, t_valid=pvec.max() + 1)
        new_cache = (k8, v8)
    elif s == 1:                                      # decode cross-attn
        o = L.decode_attention(cfg, qh, kh, vh, q_pos=k_pos[-1:] * 0 +
                               kh.shape[1] - 1, t_valid=kh.shape[1])
    else:
        o = L.chunked_attention(cfg, qh, kh, vh, causal=causal, q_pos=q_pos,
                                k_pos=k_pos, q_chunk=acfg.q_chunk,
                                kv_chunk=acfg.kv_chunk)
    return x + qdense(cfg, o.reshape(b, s, -1), p[prefix + "wo"]), new_cache


def _mlp_block(cfg, acfg, p, x):
    h = qact(cfg, "none", qlayernorm(cfg, x, p["mlp_ln_g"], p["mlp_ln_b"]))
    return x + L.mlp(cfg, h, p["w_up"], p["w_down"], acfg.act)


class EncDec:
    def __init__(self, acfg: ArchConfig, qcfg: QConfig, mesh=None,
                 dp_axes=("data",), tp_axis="model", tp_size: int = 1):
        self.a, self.q = acfg, qcfg
        self.mesh, self.dp, self.tp = mesh, dp_axes, tp_axis
        self.tp_size = tp_size
        if tp_size != 1:
            raise ValueError(
                f"{type(self).__name__} supports DP-only sharding "
                f"(manual TP shards attention heads / FFN / experts; "
                f"got tp_size={tp_size})")

    # ---------------- params ----------------

    def _init_attn(self, key, prefix=""):
        a, q = self.a, self.q
        d, dh, h, kv = a.d_model, a.dh, a.n_heads, a.n_kv
        ks = jax.random.split(key, 4)
        return {
            prefix + "ln_g": jnp.ones((d,), jnp.float32),
            prefix + "ln_b": jnp.zeros((d,), jnp.float32),
            prefix + "wq": L.winit(q, ks[0], (d, h * dh), d),
            prefix + "wk": L.winit(q, ks[1], (d, kv * dh), d),
            prefix + "wv": L.winit(q, ks[2], (d, kv * dh), d),
            prefix + "wo": L.winit(q, ks[3], (h * dh, d), h * dh),
        }

    def _init_mlp(self, key):
        a, q = self.a, self.q
        ks = jax.random.split(key, 2)
        return {
            "mlp_ln_g": jnp.ones((a.d_model,), jnp.float32),
            "mlp_ln_b": jnp.zeros((a.d_model,), jnp.float32),
            "w_up": L.winit(q, ks[0], (a.d_model, a.d_ff), a.d_model),
            "w_down": L.winit(q, ks[1], (a.d_ff, a.d_model), a.d_ff),
        }

    def _init_enc_layer(self, key):
        k1, k2 = jax.random.split(key)
        return {**self._init_attn(k1), **self._init_mlp(k2)}

    def _init_dec_layer(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {**self._init_attn(k1), **self._init_attn(k2, "x_"),
                **self._init_mlp(k3)}

    def init(self, key):
        a = self.a
        ks = jax.random.split(key, 5)
        enc = jax.vmap(self._init_enc_layer)(
            jax.random.split(ks[0], a.enc_layers))
        dec = jax.vmap(self._init_dec_layer)(
            jax.random.split(ks[1], a.dec_layers))
        return {
            "enc": enc, "dec": dec,
            "embed": jax.random.normal(ks[2], (a.vocab_padded, a.d_model),
                                       jnp.float32) * 0.02,
            "final_ln_g": jnp.ones((a.d_model,), jnp.float32),
            "final_ln_b": jnp.zeros((a.d_model,), jnp.float32),
            "lm_head": jax.random.normal(ks[3], (a.d_model, a.vocab_padded),
                                         jnp.float32) * 0.02,
        }

    def labels(self, params):
        def attn_lab(prefix=""):
            return {prefix + "ln_g": "gamma", prefix + "ln_b": "beta",
                    prefix + "wq": "w", prefix + "wk": "w",
                    prefix + "wv": "w", prefix + "wo": "w"}
        mlp_lab = {"mlp_ln_g": "gamma", "mlp_ln_b": "beta",
                   "w_up": "w", "w_down": "w"}
        return {"enc": {**attn_lab(), **mlp_lab},
                "dec": {**attn_lab(), **attn_lab("x_"), **mlp_lab},
                "embed": "exempt", "final_ln_g": "gamma",
                "final_ln_b": "beta", "lm_head": "exempt"}

    def pspecs(self):
        dp, tp = self.dp, self.tp
        def attn_spec(prefix=""):
            return {prefix + "ln_g": P(None, None),
                    prefix + "ln_b": P(None, None),
                    prefix + "wq": P(None, dp, tp),
                    prefix + "wk": P(None, dp, tp),
                    prefix + "wv": P(None, dp, tp),
                    prefix + "wo": P(None, tp, dp)}
        mlp_spec = {"mlp_ln_g": P(None, None), "mlp_ln_b": P(None, None),
                    "w_up": P(None, dp, tp), "w_down": P(None, tp, dp)}
        return {"enc": {**attn_spec(), **mlp_spec},
                "dec": {**attn_spec(), **attn_spec("x_"), **mlp_spec},
                "embed": P(None, tp), "final_ln_g": P(None),
                "final_ln_b": P(None), "lm_head": P(None, tp)}

    # ---------------- forward ----------------

    def encode(self, params, frames):
        a = self.a
        pos = jnp.arange(frames.shape[1])

        def body(h, lp):
            h = L.constrain(self.mesh, h, P(self.dp, None, None))
            h, _ = _attn(self.q, a, lp, h, None, causal=False, q_pos=pos,
                         k_pos=pos)
            h = _mlp_block(self.q, a, lp, h)
            return h, None
        body = L.maybe_remat(self.a, body)
        x, _ = L.lscan(a, body, frames, params["enc"])
        return x

    def _decode_train(self, params, enc_out, tokens):
        a = self.a
        y = params["embed"][tokens]
        tpos = jnp.arange(tokens.shape[1])
        spos = jnp.arange(enc_out.shape[1])
        enc_q = qact(self.q, "none", enc_out)

        def body(h, lp):
            h = L.constrain(self.mesh, h, P(self.dp, None, None))
            h, _ = _attn(self.q, a, lp, h, None, causal=True, q_pos=tpos,
                         k_pos=tpos)
            h, _ = _attn(self.q, a, lp, h, enc_q, causal=False, q_pos=tpos,
                         k_pos=spos, prefix="x_")
            h = _mlp_block(self.q, a, lp, h)
            return h, None
        body = L.maybe_remat(self.a, body)
        y, _ = L.lscan(a, body, y, params["dec"])
        return y

    def _logits(self, params, x):
        h = qlayernorm(self.q, x, params["final_ln_g"], params["final_ln_b"])
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
        logits = L.constrain(self.mesh, logits, P(self.dp, None, self.tp))
        if self.a.vocab_padded != self.a.vocab:
            pad = jnp.arange(self.a.vocab_padded) >= self.a.vocab
            logits = jnp.where(pad, L.NEG_INF, logits)
        return logits

    def loss(self, params, batch, key=None):
        enc_out = self.encode(params, batch["frames"])
        y = self._decode_train(params, enc_out, batch["tokens"])
        logits = self._logits(params, y)
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = L.target_logit(logits, labels)
        loss = jnp.mean(lse - tgt)
        return loss, {"loss": loss}

    # ---------------- serving ----------------

    def init_cache(self, b, t_self, t_src):
        a = self.a
        return {
            "k8": jnp.zeros((a.dec_layers, b, t_self, a.n_kv, a.dh),
                            jnp.int8),
            "v8": jnp.zeros((a.dec_layers, b, t_self, a.n_kv, a.dh),
                            jnp.int8),
            "k_scale": jnp.full((a.dec_layers,), 2.0 ** -7, jnp.float32),
            "v_scale": jnp.full((a.dec_layers,), 2.0 ** -7, jnp.float32),
            "xk": jnp.zeros((a.dec_layers, b, t_src, a.n_kv, a.dh),
                            jnp.int8),
            "xv": jnp.zeros((a.dec_layers, b, t_src, a.n_kv, a.dh),
                            jnp.int8),
            "x_scale": jnp.full((a.dec_layers,), 2.0 ** -7, jnp.float32),
            "pos": jnp.zeros((b,), jnp.int32),
        }

    def prefill(self, params, frames, t_self):
        """Encode source; precompute per-layer cross K/V into int8 cache."""
        a = self.a
        enc_out = self.encode(params, frames)
        enc_q = qact(self.q, "none", enc_out)
        b, t_src, _ = frames.shape
        cache = self.init_cache(b, t_self, t_src)

        def layer_kv(lp):
            kh = qdense(self.q, enc_q, lp["x_wk"]).reshape(
                b, t_src, a.n_kv, a.dh)
            vh = qdense(self.q, enc_q, lp["x_wv"]).reshape(
                b, t_src, a.n_kv, a.dh)
            return (L.kv_quantize(qact(self.q, "none", kh), 2.0 ** -7),
                    L.kv_quantize(qact(self.q, "none", vh), 2.0 ** -7))
        xk, xv = jax.vmap(layer_kv)(params["dec"])
        cache.update(xk=xk, xv=xv)
        return cache

    def serve_step(self, params, cache, tokens):
        a = self.a
        y = params["embed"][tokens][:, None, :]
        pvec = cache["pos"]

        def body(h, xs):
            lp, ck, cv, cxk, cxv = xs
            h, (nk, nv) = _attn(
                self.q, a, lp, h, None, causal=True, q_pos=pvec, k_pos=pvec,
                cache={"k8": ck, "v8": cv, "k_scale": cache["k_scale"][0],
                       "v_scale": cache["v_scale"][0]})
            # cross K/V stay int8 QTensors end-to-end (no dequantize pass)
            kf = L.kv_qtensor(cxk, cache["x_scale"][0])
            vf = L.kv_qtensor(cxv, cache["x_scale"][0])
            h, _ = _attn(self.q, a, lp, h, None, causal=False, q_pos=pvec,
                         k_pos=jnp.arange(kf.shape[1]),
                         cache={"kf": kf, "vf": vf}, prefix="x_")
            h = _mlp_block(self.q, a, lp, h)
            return h, (nk, nv)
        y, (nk, nv) = L.lscan(a, body, y, (params["dec"], cache["k8"],
                                           cache["v8"], cache["xk"],
                                           cache["xv"]))
        cache = dict(cache, k8=nk, v8=nv, pos=cache["pos"] + 1)
        return cache, self._logits(params, y)[:, 0]

    # ---------------- dry-run plumbing ----------------

    def batch_pspec(self):
        dp = self.dp
        return {"frames": P(dp, None, None), "tokens": P(dp, None),
                "labels": P(dp, None)}

    def cache_pspec(self, long=False):
        dp, tp = self.dp, self.tp
        kv = P(None, dp, tp, None, None)
        return {"k8": kv, "v8": kv, "k_scale": P(None), "v_scale": P(None),
                "xk": kv, "xv": kv, "x_scale": P(None), "pos": P(None)}

    def input_specs(self, shape_name, sb=None):
        s, b, kind = LM_SHAPES[shape_name]
        if sb is not None:
            s, b = sb
        a = self.a
        st = s // a.tgt_ratio
        frames = jax.ShapeDtypeStruct((b, s, a.d_model), jnp.float32)
        tok = jax.ShapeDtypeStruct((b, st), jnp.int32)
        if kind == "train":
            return {"frames": frames, "tokens": tok, "labels": tok}, "train"
        if kind == "prefill":
            return {"frames": frames}, "prefill"
        cache = {
            "k8": jax.ShapeDtypeStruct((a.dec_layers, b, s, a.n_kv, a.dh),
                                       jnp.int8),
            "v8": jax.ShapeDtypeStruct((a.dec_layers, b, s, a.n_kv, a.dh),
                                       jnp.int8),
            "k_scale": jax.ShapeDtypeStruct((a.dec_layers,), jnp.float32),
            "v_scale": jax.ShapeDtypeStruct((a.dec_layers,), jnp.float32),
            "xk": jax.ShapeDtypeStruct((a.dec_layers, b, s, a.n_kv, a.dh),
                                       jnp.int8),
            "xv": jax.ShapeDtypeStruct((a.dec_layers, b, s, a.n_kv, a.dh),
                                       jnp.int8),
            "x_scale": jax.ShapeDtypeStruct((a.dec_layers,), jnp.float32),
            "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
        }
        return {"cache": cache,
                "tokens": jax.ShapeDtypeStruct((b,), jnp.int32)}, "decode"
