"""Decoder-only LM (dense / GQA / MoE): chameleon-34b, granite-moe,
moonshot, granite-3-8b, phi4-mini, minitron, granite-34b.

Scan-over-layers with per-layer remat keeps the HLO O(1) in depth.  The
embedding and lm_head are exempt from quantization (the paper's first/last
layer rule); every hidden matmul, norm, and activation goes through the
WAGEUBN ops.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import qact, qdense, qweight
from repro.core.qconfig import QConfig
from repro.configs.base import ArchConfig, LM_SHAPES
from . import layers as L
from . import moe as MOE

Array = jax.Array


class LMTransformer:
    def __init__(self, acfg: ArchConfig, qcfg: QConfig, mesh=None,
                 dp_axes=("data",), tp_axis="model", tp_size: int = 1):
        self.a, self.q = acfg, qcfg
        self.mesh, self.dp, self.tp = mesh, dp_axes, tp_axis
        # Manual tensor parallelism (shard_map bodies, DESIGN.md §9): with
        # tp_size > 1 this instance computes on its LOCAL head/FFN/expert
        # shard — params must arrive pre-sliced (launch/shard.py specs) and
        # the Megatron enter/exit psums activate.  tp_size=1 is the plain
        # replicated model (identical to the legacy constructor).
        self.tp_size = tp_size
        if tp_size > 1:
            divisible = (acfg.n_heads % tp_size == 0
                         and acfg.n_kv % tp_size == 0
                         and acfg.d_ff % tp_size == 0
                         and (not acfg.moe_experts
                              or acfg.moe_experts % tp_size == 0))
            if not divisible:
                raise ValueError(
                    f"tp_size={tp_size} must divide n_heads={acfg.n_heads}, "
                    f"n_kv={acfg.n_kv}, d_ff={acfg.d_ff}"
                    + (f", moe_experts={acfg.moe_experts}"
                       if acfg.moe_experts else ""))

    @property
    def _hl(self):
        """Local (per-TP-rank) query-head count."""
        return self.a.n_heads // self.tp_size

    @property
    def _kvl(self):
        """Local (per-TP-rank) KV-head count."""
        return self.a.n_kv // self.tp_size

    def _tp_in(self, x):
        """Megatron `f`: identity fwd / psum bwd at column-shard entries."""
        return L.tp_enter(self.tp, x) if self.tp_size > 1 else x

    def _tp_out(self, y):
        """Megatron `g`: psum fwd / identity bwd after row-shard outputs."""
        return L.tp_exit(self.tp, y) if self.tp_size > 1 else y

    # ---------------- params ----------------

    def _init_layer(self, key):
        a, q = self.a, self.q
        d, dh, h, kv, f = a.d_model, a.dh, a.n_heads, a.n_kv, a.d_ff
        ks = jax.random.split(key, 8)
        p = {
            "ln1": jnp.ones((d,), jnp.float32),
            "wq": L.winit(q, ks[0], (d, h * dh), d),
            "wk": L.winit(q, ks[1], (d, kv * dh), d),
            "wv": L.winit(q, ks[2], (d, kv * dh), d),
            "wo": L.winit(q, ks[3], (h * dh, d), h * dh),
            "ln2": jnp.ones((d,), jnp.float32),
        }
        if a.moe_experts:
            p["moe"] = MOE.init_moe_params(q, a, ks[4])
        else:
            p["w_gate"] = L.winit(q, ks[4], (d, f), d)
            p["w_up"] = L.winit(q, ks[5], (d, f), d)
            p["w_down"] = L.winit(q, ks[6], (f, d), f)
        return p

    def init(self, key):
        a = self.a
        ks = jax.random.split(key, 4)
        layer_keys = jax.random.split(ks[0], a.n_layers)
        layers = jax.vmap(self._init_layer)(layer_keys)
        return {
            "embed": jax.random.normal(ks[1], (a.vocab_padded, a.d_model),
                                       jnp.float32) * 0.02,
            "layers": layers,
            "final_norm": jnp.ones((a.d_model,), jnp.float32),
            "lm_head": jax.random.normal(ks[2], (a.d_model, a.vocab_padded),
                                         jnp.float32) * 0.02,
        }

    def labels(self, params):
        layer = {"ln1": "gamma", "wq": "w", "wk": "w", "wv": "w", "wo": "w",
                 "ln2": "gamma"}
        if self.a.moe_experts:
            layer["moe"] = MOE.moe_labels()
        else:
            layer.update(w_gate="w", w_up="w", w_down="w")
        return {"embed": "exempt", "layers": layer, "final_norm": "gamma",
                "lm_head": "exempt"}

    def pspecs(self):
        dp, tp = self.dp, self.tp
        layer = {"ln1": P(None, None), "wq": P(None, dp, tp),
                 "wk": P(None, dp, None), "wv": P(None, dp, None),
                 "wo": P(None, tp, dp), "ln2": P(None, None)}
        if self.a.n_kv % 16 == 0:           # kv heads shardable over tp=16
            layer["wk"] = P(None, dp, tp)
            layer["wv"] = P(None, dp, tp)
        if self.a.moe_experts:
            layer["moe"] = {k: P(*((None,) + tuple(s)))
                            for k, s in MOE.moe_pspecs(dp, tp).items()}
        else:
            layer.update(w_gate=P(None, dp, tp), w_up=P(None, dp, tp),
                         w_down=P(None, tp, dp))
        return {"embed": P(None, tp), "layers": layer,
                "final_norm": P(None), "lm_head": P(None, tp)}

    # ---------------- forward ----------------

    def _attn(self, p, x, pos, mode, cache=None):
        a, q = self.a, self.q
        hl, kvl = self._hl, self._kvl
        b, s, d = x.shape
        h = qact(q, "none", L.norm(q, a.norm, x, p["ln1"]))
        h = self._tp_in(h)          # wq/wk/wv are head(column)-sharded
        qh = qdense(q, h, p["wq"]).reshape(b, s, hl, a.dh)
        kh = qdense(q, h, p["wk"]).reshape(b, s, kvl, a.dh)
        vh = qdense(q, h, p["wv"]).reshape(b, s, kvl, a.dh)
        if mode == "train":
            pos1 = pos  # (S,)
            qh = L.rope(qh, pos1, a.rope_theta)
            kh = L.rope(kh, pos1, a.rope_theta)
            qh, kh, vh = (qact(q, "none", t) for t in (qh, kh, vh))
            o = L.chunked_attention(q, qh, kh, vh, causal=True,
                                    q_pos=pos1, k_pos=pos1,
                                    q_chunk=a.q_chunk, kv_chunk=a.kv_chunk)
            new_cache = None
            if cache == "emit":
                ks = L.kv_quantize(kh, 2.0 ** -7)
                vs = L.kv_quantize(vh, 2.0 ** -7)
                new_cache = (ks, vs)
        elif mode == "chunk":
            # chunked prefill: ONE lane (b==1), s == page_size tokens whose
            # positions pos (S,) fill exactly one pool page.  The page is
            # the quantization unit — every amax spans this page alone —
            # so the written KV is a pure function of the token prefix
            # (the radix cache's bitwise-hit contract, DESIGN.md §10).
            qh = L.rope(qh, pos, a.rope_theta)
            kh = L.rope(kh, pos, a.rope_theta)
            qh, kh, vh = (qact(q, "none", t) for t in (qh, kh, vh))
            ks, vs = cache["k_scale"], cache["v_scale"]
            kp, vp = cache["k_pages"], cache["v_pages"]
            table = cache["table"]
            pid = table[0, pos[0] // kp.shape[1]]
            kp = L.page_write(kp, pid, L.kv_quantize(kh[0], ks))
            vp = L.page_write(vp, pid, L.kv_quantize(vh[0], vs))
            o = L.paged_prefill_attention(q, qh, kp, vp, table, ks, vs,
                                          q_pos=pos)
            new_cache = (kp, vp)
        else:  # decode: s == 1, pos: (B,), cache: dict slices for this layer
            pvec = pos  # (B,)
            qh = _rope_batched(qh, pvec, a.rope_theta)
            kh = _rope_batched(kh, pvec, a.rope_theta)
            qh, kh, vh = (qact(q, "none", t) for t in (qh, kh, vh))
            ks, vs = cache["k_scale"], cache["v_scale"]
            if "k_pages" in cache:  # paged serving cache (one layer's pages)
                # native + fuse_kernels streams these pages through the
                # fused paged-attention kernel inside paged_decode_attention
                # (no gathered KV in HBM); sim mode takes the gather route
                kp, vp = cache["k_pages"], cache["v_pages"]
                table = cache["table"]
                kp = L.page_scatter_token(kp, table, pvec,
                                          L.kv_quantize(kh[:, 0], ks))
                vp = L.page_scatter_token(vp, table, pvec,
                                          L.kv_quantize(vh[:, 0], vs))
                o = L.paged_decode_attention(q, qh, kp, vp, table, ks, vs,
                                             q_pos=pvec,
                                             t_valid=pvec.max() + 1)
                new_cache = (kp, vp)
            else:
                k8, v8 = cache["k"], cache["v"]    # (B,T,KV,dh) int8
                bidx = jnp.arange(b)
                k8 = k8.at[bidx, pvec].set(L.kv_quantize(kh[:, 0], ks))
                v8 = v8.at[bidx, pvec].set(L.kv_quantize(vh[:, 0], vs))
                # the int8 cache IS the matmul operand: no dequantize trip
                o = L.decode_attention(q, qh, L.kv_qtensor(k8, ks),
                                       L.kv_qtensor(v8, vs), q_pos=pvec,
                                       t_valid=pvec.max() + 1)
                new_cache = (k8, v8)
        o = o.reshape(b, s, hl * a.dh)
        return x + self._tp_out(qdense(q, o, p["wo"])), new_cache

    def _ffn(self, p, x):
        a, q = self.a, self.q
        h = qact(q, "none", L.norm(q, a.norm, x, p["ln2"]))
        h = self._tp_in(h)          # gate/up (or experts) are column-sharded
        if a.moe_experts:
            y = MOE.moe_ffn(q, a, h, p["moe"], self.mesh, self.dp, self.tp,
                            tp_size=self.tp_size)
        else:
            y = L.swiglu(q, h, p["w_gate"], p["w_up"], p["w_down"], a.act)
        return x + self._tp_out(y)

    def _block(self, p, x, pos, mode, cache=None):
        from jax.sharding import PartitionSpec as PS
        x = L.constrain(self.mesh, x, PS(self.dp, None, None))
        x, new_cache = self._attn(p, x, pos, mode, cache)
        x = self._ffn(p, x)
        return x, new_cache

    def _backbone(self, params, x, pos, mode, cache=None):
        """Scan over layers.  cache: None | 'emit' | dict of stacked arrays."""
        a = self.a

        if cache is None or cache == "emit":
            def body(h, lp):
                h2, c = self._block(lp, h, pos, mode, cache)
                return h2, c
            body = L.maybe_remat(self.a, body)
            x, caches = L.lscan(self.a, body, x, params["layers"])
            return x, caches

        if "k_pages" in cache:   # paged decode/chunk: per-layer page pools
            def body(h, xs):
                lp, kp, vp = xs
                layer_cache = {"k_pages": kp, "v_pages": vp,
                               "k_scale": cache["k_scale"][0],
                               "v_scale": cache["v_scale"][0],
                               "table": cache["table"]}
                h2, (nkp, nvp) = self._block(lp, h, pos, mode, layer_cache)
                return h2, (nkp, nvp)
            x, (nk, nv) = L.lscan(self.a, body, x,
                                  (params["layers"], cache["k_pages"],
                                   cache["v_pages"]))
            out = dict(cache, k_pages=nk, v_pages=nv)
            if mode == "decode":
                out["pos"] = cache["pos"] + 1
            return x, out

        def body(h, xs):
            lp, ck, cv = xs
            layer_cache = {"k": ck, "v": cv, "k_scale": cache["k_scale"][0],
                           "v_scale": cache["v_scale"][0]}
            h2, (nk, nv) = self._block(lp, h, pos, mode, layer_cache)
            return h2, (nk, nv)
        x, (nk, nv) = L.lscan(self.a, body, x,
                              (params["layers"], cache["k"], cache["v"]))
        return x, {"k": nk, "v": nv, "k_scale": cache["k_scale"],
                   "v_scale": cache["v_scale"], "pos": cache["pos"] + 1}

    def _logits(self, params, x):
        h = L.norm(self.q, self.a.norm, x, params["final_norm"])
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
        logits = L.constrain(self.mesh, logits, P(self.dp, None, self.tp))
        if self.a.vocab_padded != self.a.vocab:
            pad = jnp.arange(self.a.vocab_padded) >= self.a.vocab
            logits = jnp.where(pad, L.NEG_INF, logits)
        return logits

    # ---------------- public API ----------------

    def loss(self, params, batch, key=None):
        a = self.a
        tokens, labels = batch["tokens"], batch["labels"]
        x = params["embed"][tokens]                      # exempt first layer
        pos = jnp.arange(tokens.shape[1])
        x, _ = self._backbone(params, x, pos, "train")
        logits = self._logits(params, x)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = L.target_logit(logits, labels)
        loss = jnp.mean(lse - tgt)
        return loss, {"loss": loss}

    def init_cache(self, b, t):
        a = self.a
        return L.kv_cache_init(a.n_layers, b, t, a.n_kv, a.dh)

    def prefill(self, params, tokens, cache_len):
        """Run the prompt, return (cache, last-token logits)."""
        a = self.a
        b, s = tokens.shape
        x = params["embed"][tokens]
        pos = jnp.arange(s)
        x, caches = self._backbone(params, x, pos, "train", cache="emit")
        k8, v8 = caches
        cache = self.init_cache(b, cache_len)
        cache["k"] = cache["k"].at[:, :, :s].set(k8)
        cache["v"] = cache["v"].at[:, :, :s].set(v8)
        cache["pos"] = jnp.full((b,), s, jnp.int32)
        logits = self._logits(params, x[:, -1:])
        return cache, logits[:, 0]

    def serve_step(self, params, cache, tokens):
        """tokens: (B,) int32 — one decode step. Returns (cache, logits)."""
        x = params["embed"][tokens][:, None, :]          # (B,1,D)
        pos = cache["pos"]
        x, cache = self._backbone(params, x, pos, "decode", cache)
        logits = self._logits(params, x)
        return cache, logits[:, 0]

    # ---------------- serving decode-state slot API ----------------
    # Uniform interface the continuous-batching engine drives: attention KV
    # lives in the engine's paged pool, recurrent state (none here) in dense
    # per-lane slots.  See serving/engine.py and DESIGN.md §7.

    def decode_state_spec(self):
        a = self.a
        return {"kv_layers": a.n_layers, "n_kv": a.n_kv, "dh": a.dh,
                "dense_axes": {"pos": 0}, "tp_axes": {}}

    def init_slots(self, n_lanes: int):
        return {"pos": jnp.zeros((n_lanes,), jnp.int32)}

    def slot_from_cache(self, cache, b: int = 0):
        """Sequence `b` of a prefill cache -> (dense slot values, (k, v)
        paged payloads of shape (L, T, KV, dh) int8)."""
        return ({"pos": cache["pos"][b]},
                (cache["k"][:, b], cache["v"][:, b]))

    def paged_decode_step(self, params, slots, pool_view, tokens):
        """One fused decode step over all lanes against the paged pool.

        pool_view: {"k_pages"/"v_pages": (L, P, page, KV, dh) int8,
        "k_scale"/"v_scale": (L,), "table": (B, NB)}.  Returns
        (logits, new_slots, new pool payloads).  Lane positions advance in
        the engine (dead lanes must not move), so `slots` pass through.
        """
        cache = dict(pool_view, pos=slots["pos"])
        x = params["embed"][tokens][:, None, :]
        x, nc = self._backbone(params, x, slots["pos"], "decode", cache)
        logits = self._logits(params, x)[:, 0]
        return logits, slots, {"k_pages": nc["k_pages"],
                               "v_pages": nc["v_pages"]}

    def prefill_page(self, params, dense, pool_view, tokens, pos0):
        """Chunked prefill: run ONE page of one lane's prompt.

        tokens: (page,) int32; pos0: the page's first absolute position
        (a multiple of page_size); pool_view as in `paged_decode_step`
        with a single-lane (1, NB) table.  Writes the page's KV into the
        pool and attends to every earlier position through the table.
        Returns (last-token logits (1, Vp), dense slot values, new pool
        payloads).  No recurrent state here, so `dense` passes through.
        """
        page = pool_view["k_pages"].shape[2]
        x = params["embed"][tokens][None]               # (1, page, d)
        pos = pos0 + jnp.arange(page)
        cache = dict(pool_view)
        x, nc = self._backbone(params, x, pos, "chunk", cache)
        logits = self._logits(params, x[:, -1:])[:, 0]
        return logits, dense, {"k_pages": nc["k_pages"],
                               "v_pages": nc["v_pages"]}

    # ---------------- dry-run plumbing ----------------

    def batch_pspec(self):
        return {"tokens": P(self.dp, None), "labels": P(self.dp, None)}

    def cache_pspec(self, long=False):
        dp, tp = self.dp, self.tp
        if long:   # batch=1: shard the KV sequence over (data, model)
            kvspec = P(None, None, ("data", tp), None, None)
        else:      # batch over dp, KV sequence over model
            kvspec = P(None, dp, tp, None, None)
        return {"k": kvspec, "v": kvspec, "k_scale": P(None),
                "v_scale": P(None), "pos": P(None)}

    def input_specs(self, shape_name, sb=None):
        s, b, kind = LM_SHAPES[shape_name]
        if sb is not None:
            s, b = sb
        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if kind == "train":
            return {"tokens": tok, "labels": tok}, "train"
        if kind == "prefill":
            return {"tokens": tok}, "prefill"
        # decode: cache of seq_len + one token
        a = self.a
        cache = {
            "k": jax.ShapeDtypeStruct((a.n_layers, b, s, a.n_kv, a.dh),
                                      jnp.int8),
            "v": jax.ShapeDtypeStruct((a.n_layers, b, s, a.n_kv, a.dh),
                                      jnp.int8),
            "k_scale": jax.ShapeDtypeStruct((a.n_layers,), jnp.float32),
            "v_scale": jax.ShapeDtypeStruct((a.n_layers,), jnp.float32),
            "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
        }
        return {"cache": cache,
                "tokens": jax.ShapeDtypeStruct((b,), jnp.int32)}, "decode"


def _rope_batched(x, pos, theta):
    """x: (B, 1, H, dh); pos: (B,)."""
    def one(xi, pi):
        return L.rope(xi, pi[None], theta)
    return jax.vmap(one)(x, pos)
