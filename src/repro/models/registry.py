"""Model registry: family string -> model class."""
from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.core.qconfig import QConfig

from .transformer import LMTransformer
from .ssm_lm import SSMLM
from .hybrid import Zamba2
from .encdec import EncDec
from .resnet import ResNet

FAMILIES = {
    "lm": LMTransformer,       # dense decoder-only
    "vlm": LMTransformer,      # chameleon: early-fusion VQ tokens = vocab ids
    "moe": LMTransformer,      # MoE FFN selected via acfg.moe_experts
    "ssm": SSMLM,
    "hybrid": Zamba2,
    "encdec": EncDec,
    "resnet": ResNet,
}


def build_model(acfg: ArchConfig, qcfg: QConfig, mesh=None,
                dp_axes=("data",), tp_axis="model", tp_size: int = 1):
    """tp_size > 1 builds the model for MANUAL tensor parallelism inside a
    full-manual shard_map (launch/train.make_sharded_train_step): params
    arrive pre-sliced over `tp_axis` per launch/shard.py's specs.  Families
    without a manual-TP implementation raise."""
    cls = FAMILIES[acfg.family]
    return cls(acfg, qcfg, mesh=mesh, dp_axes=dp_axes, tp_axis=tp_axis,
               tp_size=tp_size)
