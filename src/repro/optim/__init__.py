from .momentum import (MomentumState, apply_leaf_update, dr_bits_schedule,
                       fixed_point_lr, init_momentum, momentum_update,
                       parse_boundaries, quantize_grad_leaf)

__all__ = ["MomentumState", "apply_leaf_update", "dr_bits_schedule",
           "fixed_point_lr", "init_momentum", "momentum_update",
           "parse_boundaries", "quantize_grad_leaf"]
