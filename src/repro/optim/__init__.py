from .momentum import (MomentumState, fixed_point_lr, dr_bits_schedule,
                       init_momentum, momentum_update)

__all__ = ["MomentumState", "fixed_point_lr", "dr_bits_schedule",
           "init_momentum", "momentum_update"]
