"""Quantized Momentum optimizer + fixed-point updates (paper Eq. 19-24).

Per training step i and layer l:
    g_q    = CQ(g_W)            (weights, Eq. 5/18 — stochastic rounding)
           = Q(g, 15)           (gamma/beta, Eq. 18)
    Acc_i  = Mom * Acc_{i-1,q} + g_q          (Eq. 20)
    Acc_iq = Q(Acc_i, k_Acc)
    dW     = lr * Acc_i                        (Eq. 23, lr on the k_lr grid)
    W     <- clip(Q(W - dW, k_WU), +-(1 - 2^-(k_WU-1)))

Bit-width closure (Eq. 22/24) is asserted by QConfig.validate().

Leaves are classified by a `labels` pytree of strings:
    "w"      — matmul/conv weights: CQ gradient quantization
    "gamma" / "beta" — norm parameters: direct 15-bit gradient quantization
    "exempt" — first/last layers & any fp32-kept leaf: vanilla momentum
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import qfuncs as qf
from repro.core.qconfig import QConfig
from repro.core.qtensor import get_quantizer


class MomentumState(NamedTuple):
    acc: Any           # pytree like params
    step: jax.Array    # int32 scalar


def fixed_point_lr(lr: float, cfg: QConfig) -> float:
    """Learning rate on the k_lr-bit grid (e.g. 0.05 -> 26*2^-9)."""
    if not cfg.quantize:
        return lr
    s = 2.0 ** (cfg.k_lr - 1)
    return max(round(lr * s), 1.0) / s


def dr_bits_schedule(step: int | jax.Array, boundaries=(), base_bits: int = 8):
    """dr = 2^(k-1) shrinks at step boundaries (paper §III-C: k 8 -> 7 ...).

    `base_bits` is cfg.k_gw in the train drivers; with boundaries=() the
    schedule is constant at the base (drivers plumb --dr-boundaries — see
    parse_boundaries — and rebuild/re-select the step fn at each boundary,
    since dr_bits is a static trace constant).

    Static python int when `step` is concrete; for traced steps the caller
    should pass the schedule value in as a static per-epoch constant.
    """
    bits = base_bits
    for b in boundaries:
        if step >= b:
            bits -= 1
    return max(bits, 2)


def parse_boundaries(spec: str) -> tuple[int, ...]:
    """--dr-boundaries CLI format: '200,400' -> (200, 400), '' -> ()."""
    return tuple(int(s) for s in str(spec).split(",") if s.strip())


def _grad_quantizer(cfg: QConfig, dr_bits: int):
    """Resolve cfg.g through the registry, honoring its static params.

    The per-step dr schedule and the legacy stochastic_g knob are injected
    only where the registered quantizer declares those fields AND the spec
    did not pin them explicitly — an explicit QuantSpec param is
    authoritative (e.g. params=(("stochastic", False),) opts out of both
    stochastic rounding and the schedule default)."""
    import dataclasses
    params = dict(cfg.g.params)
    fields = {f.name for f in
              dataclasses.fields(type(get_quantizer(cfg.g.kind, cfg.g.k,
                                                    cfg.g.params)))}
    if "dr_bits" in fields:
        params.setdefault("dr_bits", dr_bits)
    if "stochastic" in fields:
        params.setdefault("stochastic", cfg.stochastic_g)
    return get_quantizer(cfg.g.kind, cfg.g.k, tuple(sorted(params.items())))


def init_momentum(params: Any) -> MomentumState:
    acc = jax.tree.map(jnp.zeros_like, params)
    return MomentumState(acc=acc, step=jnp.zeros((), jnp.int32))


def _mom_coeff(cfg: QConfig, mom: float) -> float:
    if not cfg.quantize:
        return mom
    s = 2.0 ** (cfg.k_mom - 1)
    return round(mom * s) / s          # e.g. 0.75 = 3 * 2^-2 (3-bit)


def _plain_path(cfg: QConfig, lab) -> bool:
    """Vanilla-momentum leaves: fp32 config, exempt leaves, or Table II runs
    with both the G and U quantizers off."""
    return (not cfg.quantize or lab == "exempt"
            or not (cfg.quant_g or cfg.quant_u))


def quantize_grad_leaf(cfg: QConfig, g, lab, key, dr_bits: int | None = None):
    """Per-leaf gradient quantization (Eq. 18): CQ for "w" leaves, direct
    15-bit for gamma/beta, identity for plain-path leaves.

    Split from `apply_leaf_update` so ZeRO-sharded optimizers can quantize
    the FULL leaf (CQ's amax scale and stochastic-rounding bits are
    leaf-global — a chunk-local quantization would make the update depend
    on the chunking) and then update only their chunk of (p, gq, acc).
    """
    if _plain_path(cfg, lab) or not cfg.quant_g:
        return g
    if dr_bits is None:        # unscheduled callers: cfg.k_gw IS the dr width
        dr_bits = cfg.k_gw
    if lab == "w":
        # registry-resolved gradient quantizer (cfg.g names kind, k_gc and
        # static params); the dr schedule and rounding mode are per-step
        # parameters injected only when the registered quantizer declares
        # those fields (i.e. CQ-family kinds)
        return _grad_quantizer(cfg, dr_bits)(g, key=key)
    if lab in ("gamma", "beta"):
        k = cfg.k_ggamma if lab == "gamma" else cfg.k_gbeta
        return get_quantizer("direct", k)(g)
    raise ValueError(f"unknown label {lab!r}")


def apply_leaf_update(cfg: QConfig, p, gq, a, lab, lr, mom: float = 0.75):
    """Elementwise Momentum + fixed-point update (Eq. 19-24) given the
    already-quantized gradient `gq`.  Returns (new_p, new_acc).

    Every operation is elementwise, so this applies bit-identically to any
    aligned chunking of (p, gq, a) — the property the ZeRO-1 sharded update
    in launch/train.py relies on (tests/test_sharded_train.py).
    """
    if _plain_path(cfg, lab) or not cfg.quant_u:
        # plain momentum (raw mom coefficient; Table II FP32-update runs)
        acc = mom * a + gq
        return p - lr * acc, acc
    momq = _mom_coeff(cfg, mom)
    acc_full = momq * qf.q_direct(a, cfg.k_acc) + gq      # Eq. 20
    acc = qf.q_direct(acc_full, cfg.k_acc)
    dw = lr * acc_full                                    # Eq. 23
    q = qf.q_direct(p - dw, cfg.k_wu)                     # k_WU grid
    lim = 1.0 - 2.0 ** (1 - cfg.k_wu)
    return jnp.clip(q, -lim, lim), acc


def momentum_update(cfg: QConfig, params: Any, grads: Any, state: MomentumState,
                    labels: Any, key: jax.Array, lr: float | jax.Array,
                    mom: float = 0.75, dr_bits: int | None = None):
    """One optimizer step.  Returns (new_params, new_state).

    `lr` must already be on the k_lr grid (see fixed_point_lr); `dr_bits` is
    the (static) CQ range schedule value for this step — None takes
    cfg.k_gw, the schedule base.
    """
    leaves, treedef = jax.tree.flatten(params)
    glist = treedef.flatten_up_to(grads)
    alist = treedef.flatten_up_to(state.acc)
    llist = treedef.flatten_up_to(labels)

    new_p, new_a = [], []
    for i, (p, g, a, lab) in enumerate(zip(leaves, glist, alist, llist)):
        gq = quantize_grad_leaf(cfg, g, lab, jax.random.fold_in(key, i),
                                dr_bits)
        q, acc = apply_leaf_update(cfg, p, gq, a, lab, lr, mom)
        new_p.append(q)
        new_a.append(acc)

    return (jax.tree.unflatten(treedef, new_p),
            MomentumState(acc=jax.tree.unflatten(treedef, new_a),
                          step=state.step + 1))
