"""Quantization configuration for the WAGEUBN framework.

Bit-width notation follows the paper (Yang et al. 2019, §III-B/§IV-A):
  k_W, k_A, k_GW, k_E1, k_E2  — weights / activations / weight-grad (dr bits) /
                                error at layer boundary / error before matmul
  k_GC                        — constant scale bits of CQ (Eq. 7)
  k_BN, k_mu, k_sigma, k_gamma, k_beta — BN / norm operand widths (Eq. 13)
  k_Ggamma, k_Gbeta           — gamma/beta gradient widths (Eq. 18)
  k_Mom, k_Acc, k_lr, k_WU    — Momentum optimizer + update widths (Eq. 19-24)

Paper presets (§IV-A): full 8-bit ("FULL8") and the 16-bit E2 variant
("E2_16").  "FP32" turns every quantizer into the identity — the vanilla
baseline the paper compares against.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class QConfig:
    # Numeric mode: "fp32" (vanilla), "sim" (grid values carried in fp32),
    # "native" (int8/int16 payloads + pow2 scales, integer dot_generals).
    mode: str = "sim"

    # --- forward-path widths ---
    k_w: int = 8
    k_a: int = 8
    k_bn: int = 16
    k_mu: int = 16
    k_sigma: int = 16
    k_gamma: int = 8
    k_beta: int = 8

    # --- error-path widths (backward) ---
    k_e1: int = 8            # Q_E1 = shift-quantization at layer boundaries
    k_e2: int = 8            # Q_E2 before weight matmuls (flag or 16-bit)
    e2_kind: str = "flag8"   # "flag8" (Eq. 17) | "sq16" (Eq. 16) | "sq8"
    e_attn_kind: str = "sq8" # error quant for activation-activation matmuls

    # --- gradient / optimizer widths ---
    k_gw: int = 8            # dr bits of CQ (shrinks during training)
    k_gc: int = 15           # constant scale bits of CQ
    k_ggamma: int = 15
    k_gbeta: int = 15
    k_mom: int = 3
    k_acc: int = 13
    k_lr: int = 10
    k_wu: int = 24
    stochastic_g: bool = True  # stochastic rounding inside CQ (paper Eq. 7)

    # Norm backward: full autodiff-through-stats (True) or the paper's
    # elementwise 1/sigma approximation (False).
    norm_full_bwd: bool = True

    # ---- beyond-paper performance knobs (EXPERIMENTS.md §Perf) ----
    # fixed 2^(1-k_W) scale for weight operands in qeinsum (skips the amax
    # pass; valid because Q_W saturates to (-1,1)) -> int8 FSDP gathers
    fixed_w_scale: bool = False
    # carrier dtype at TP matmul boundaries ("f32" | "bf16"): bf16 holds the
    # 8-bit activation grid exactly and halves all-reduce bytes
    tp_comm_dtype: str = "f32"
    # carrier dtype for the SSM scan intermediates ("f32" | "bf16")
    scan_dtype: str = "f32"

    # Per-path switches (paper Table II single-path sensitivity runs).
    quant_w: bool = True
    quant_a: bool = True
    quant_bn: bool = True
    quant_g: bool = True
    quant_e1: bool = True
    quant_e2: bool = True
    quant_u: bool = True

    @property
    def quantize(self) -> bool:
        return self.mode != "fp32"

    @property
    def native(self) -> bool:
        return self.mode == "native"

    def replace(self, **kw) -> "QConfig":
        return dataclasses.replace(self, **kw)

    def validate(self) -> None:
        # Paper Eq. 22: k_Ggamma = k_Gbeta = k_GC = k_Mom + k_Acc - 1
        assert self.k_ggamma == self.k_gbeta == self.k_gc == (
            self.k_mom + self.k_acc - 1
        ), "bit-width closure Eq.(22) violated"
        # Paper Eq. 24: k_WU = k_GC + k_lr - 1
        assert self.k_wu == self.k_gc + self.k_lr - 1, (
            "bit-width closure Eq.(24) violated"
        )
        assert self.e2_kind in ("flag8", "sq16", "sq8")
        assert self.mode in ("fp32", "sim", "native")


FULL8 = QConfig()                                   # paper full 8-bit version
E2_16 = QConfig(e2_kind="sq16", k_e2=16)            # paper 16-bit E2 version
FP32 = QConfig(mode="fp32")                         # vanilla baseline

PRESETS = {"full8": FULL8, "e2_16": E2_16, "fp32": FP32}


def preset(name: str, mode: str | None = None) -> QConfig:
    cfg = PRESETS[name]
    if mode is not None:
        cfg = cfg.replace(mode=mode)
    cfg.validate()
    return cfg
