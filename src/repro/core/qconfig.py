"""Quantization configuration for the WAGEUBN framework.

Bit-width notation follows the paper (Yang et al. 2019, §III-B/§IV-A):
  k_W, k_A, k_GW, k_E1, k_E2  — weights / activations / weight-grad (dr bits) /
                                error at layer boundary / error before matmul
  k_GC                        — constant scale bits of CQ (Eq. 7)
  k_BN, k_mu, k_sigma, k_gamma, k_beta — BN / norm operand widths (Eq. 13)
  k_Ggamma, k_Gbeta           — gamma/beta gradient widths (Eq. 18)
  k_Mom, k_Acc, k_lr, k_WU    — Momentum optimizer + update widths (Eq. 19-24)

Per-path quantizers are structured `QuantSpec`s resolved through the
quantizer registry (DESIGN.md §2): `w`/`a`/`e1`/`e2`/`e_attn`/`g`.  The old
string fields `e2_kind`/`e_attn_kind` are kept as DEPRECATED aliases — when
passed they are resolved via the registry alias table and the matching spec
is rebuilt; reading them returns the canonical legacy name of the spec.

Width semantics (INTENTIONAL change vs the legacy string dispatcher): an
explicit width field now re-widths the configured spec — QConfig(k_e2=16)
means flag@16, where the legacy dispatcher silently ignored k_e2 for
width-pinned kinds like "flag8".  Pass a width-suffixed alias (e2_kind=
"flag8") to pin the width regardless of k_e2.

Paper presets (§IV-A): full 8-bit ("FULL8") and the 16-bit E2 variant
("E2_16").  "FP32" turns every quantizer into the identity — the vanilla
baseline the paper compares against.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

from .qtensor import QuantSpec, legacy_kind, spec_from_alias

# legacy single-width fields <-> structured spec fields
_WIDTH_TO_SPEC = {"k_w": "w", "k_a": "a", "k_e1": "e1", "k_e2": "e2",
                  "k_gc": "g"}


@dataclass(frozen=True)
class QConfig:
    # Numeric mode: "fp32" (vanilla), "sim" (grid values carried in fp32),
    # "native" (QTensor int8/int16 payloads + pow2 scales, integer dots).
    mode: str = "sim"

    # --- forward-path widths ---
    k_w: int = 8
    k_a: int = 8
    k_bn: int = 16
    k_mu: int = 16
    k_sigma: int = 16
    k_gamma: int = 8
    k_beta: int = 8

    # --- error-path widths (backward) ---
    k_e1: int = 8            # Q_E1 = shift-quantization at layer boundaries
    k_e2: int = 8            # Q_E2 before weight matmuls (flag or 16-bit)

    # --- structured per-path quantizer specs (registry-resolved) ---
    w: QuantSpec = field(default=QuantSpec("clip", 8))       # Q_W  (Eq. 10)
    a: QuantSpec = field(default=QuantSpec("scaled", 8))     # Q_A  (Eq. 14)
    e1: QuantSpec = field(default=QuantSpec("sq", 8))        # Q_E1 (Eq. 15)
    e2: QuantSpec = field(default=QuantSpec("flag", 8))      # Q_E2 (Eq. 17)
    e_attn: QuantSpec = field(default=QuantSpec("sq", 8))    # act-act matmuls
    g: QuantSpec = field(default=QuantSpec("cq", 15))        # CQ   (Eq. 7)

    # DEPRECATED string aliases (resolve through the registry alias table);
    # after __post_init__ they always hold the canonical legacy names.
    e2_kind: str | None = None
    e_attn_kind: str | None = None

    # --- gradient / optimizer widths ---
    # dr bits of CQ: the BASE of the shrink schedule (paper §III-C, k 8->7
    # ->...).  optim/momentum resolves the per-step value as
    # dr_bits_schedule(step, boundaries, base_bits=k_gw) — train drivers
    # plumb the boundaries via --dr-boundaries.
    k_gw: int = 8
    k_gc: int = 15           # constant scale bits of CQ
    k_ggamma: int = 15
    k_gbeta: int = 15
    k_mom: int = 3
    k_acc: int = 13
    k_lr: int = 10
    k_wu: int = 24
    stochastic_g: bool = True  # stochastic rounding inside CQ (paper Eq. 7)

    # Norm backward: full autodiff-through-stats (True) or the paper's
    # elementwise 1/sigma approximation (False).
    norm_full_bwd: bool = True

    # ---- beyond-paper performance knobs (EXPERIMENTS.md §Perf) ----
    # DEPRECATED: native weight payloads now always use the fixed 2^(1-k_W)
    # scale of the "clip" quantizer when they arrive as QTensors (lossless
    # for Q_W-saturated weights).  This flag only still affects raw fp32
    # operands marked b_weight that reach qeinsum un-quantized.
    fixed_w_scale: bool = False
    # carrier dtype at TP matmul boundaries ("f32" | "bf16"): bf16 holds the
    # 8-bit activation grid exactly and halves all-reduce bytes
    tp_comm_dtype: str = "f32"
    # carrier dtype for the SSM scan intermediates ("f32" | "bf16")
    scan_dtype: str = "f32"
    # native-mode fused kernels (DESIGN.md §7/§8): route the backward error
    # dots through the fused-prologue dgrad/wgrad ops, norms through the
    # fused UBN op, the attention forward through the tiled flash kernel,
    # and paged serving decode through the streaming paged-attention kernel
    # (the gathered KV never exists in HBM).  Bit-exact either way
    # (train_bench/serve_bench flip this to measure the fusion win); sim
    # mode ignores it.
    fuse_kernels: bool = True

    # Per-path switches (paper Table II single-path sensitivity runs).
    quant_w: bool = True
    quant_a: bool = True
    quant_bn: bool = True
    quant_g: bool = True
    quant_e1: bool = True
    quant_e2: bool = True
    quant_u: bool = True

    def __post_init__(self):
        set_ = lambda n, v: object.__setattr__(self, n, v)
        # Deprecated string aliases win ONLY when they carry new information
        # (differ from the spec's own canonical name).  A canonical string
        # merely carried through dataclasses.replace must NOT rebuild the
        # spec — that would erase non-alias widths and custom params.
        e2_str = self.e2_kind
        if e2_str is not None and e2_str != legacy_kind(self.e2):
            set_("e2", spec_from_alias(e2_str, self.k_e2))
        if (self.e_attn_kind is not None
                and self.e_attn_kind != legacy_kind(self.e_attn)):
            set_("e_attn", spec_from_alias(self.e_attn_kind, self.e_attn.k))
        # Reconcile legacy width fields with specs: an explicitly configured
        # spec wins (its k is authoritative); an untouched default spec
        # inherits the width field (legacy constructors like QConfig(k_a=4)).
        # Whenever a string kind was present at all ("flag8" explicit or
        # carried), the spec it names is authoritative — width-pinned aliases
        # must never be re-widthed by a stale k_e2 (legacy quant_error
        # ignored k_e2 for them too); replace() passes e2_kind=None when a
        # bare k_e2 change should re-width the current spec.
        for kf, sf in _WIDTH_TO_SPEC.items():
            if sf == "e2" and e2_str is not None:
                set_("k_e2", self.e2.k)
                continue
            spec, kval = getattr(self, sf), getattr(self, kf)
            if spec.k != kval:
                if spec == _DEFAULT_SPECS[sf]:
                    set_(sf, spec.replace(k=kval))
                else:
                    set_(kf, spec.k)
        # canonicalize the deprecated strings LAST, from the final specs —
        # a stale alias must never describe a pre-reconciliation spec
        set_("e2_kind", legacy_kind(self.e2))
        set_("e_attn_kind", legacy_kind(self.e_attn))

    @property
    def quantize(self) -> bool:
        return self.mode != "fp32"

    @property
    def native(self) -> bool:
        return self.mode == "native"

    def replace(self, **kw) -> "QConfig":
        # replacing a spec clears its deprecated string alias (which would
        # otherwise win in __post_init__); replacing the string clears the
        # spec-derived canonical form implicitly.
        if "e2" in kw and "e2_kind" not in kw:
            kw["e2_kind"] = None
        if "e_attn" in kw and "e_attn_kind" not in kw:
            kw["e_attn_kind"] = None
        # replacing a legacy width field re-widths the current spec (the
        # spec is otherwise authoritative for k in __post_init__)
        for kf, sf in _WIDTH_TO_SPEC.items():
            if kf in kw and sf not in kw:
                kw[sf] = getattr(self, sf).replace(k=kw[kf])
                if sf == "e2" and "e2_kind" not in kw:
                    kw["e2_kind"] = None   # the re-widthed spec must win
        return dataclasses.replace(self, **kw)

    def validate(self) -> None:
        # Paper Eq. 22: k_Ggamma = k_Gbeta = k_GC = k_Mom + k_Acc - 1
        assert self.k_ggamma == self.k_gbeta == self.k_gc == (
            self.k_mom + self.k_acc - 1
        ), "bit-width closure Eq.(22) violated"
        # Paper Eq. 24: k_WU = k_GC + k_lr - 1
        assert self.k_wu == self.k_gc + self.k_lr - 1, (
            "bit-width closure Eq.(24) violated"
        )
        # every per-path spec must resolve through the registry
        for spec in (self.w, self.a, self.e1, self.e2, self.e_attn, self.g):
            spec.make()
        assert self.mode in ("fp32", "sim", "native")


# single source of truth for "untouched default spec" detection: the
# dataclass field defaults themselves
_DEFAULT_SPECS = {sf: QConfig.__dataclass_fields__[sf].default
                  for sf in _WIDTH_TO_SPEC.values()}

FULL8 = QConfig()                                   # paper full 8-bit version
E2_16 = QConfig(e2_kind="sq16", k_e2=16)            # paper 16-bit E2 version
FP32 = QConfig(mode="fp32")                         # vanilla baseline

# Bit-width-lane spec points (DESIGN.md §14): per-path widths re-width the
# registry specs through __post_init__, so each lane is the same quantizer
# kind at a different k — and rides every fused-kernel / sharding contract.
W4A8 = QConfig(k_w=4)      # DoReFa-style 4-bit weights: clip@4, fixed 2^-3
                           # grid, int8 storage with a 4-bit clip
A4 = QConfig(k_a=4)        # 4-bit activations: scaled@4 (amax pow2 scale)
G16 = QConfig(k_gw=16)     # wide CQ range: dr = 2^15 on int16 payloads —
                           # the base the --dr-boundaries schedule shrinks

PRESETS = {"full8": FULL8, "e2_16": E2_16, "fp32": FP32,
           "w4a8": W4A8, "a4": A4, "g16": G16}


def preset(name: str, mode: str | None = None) -> QConfig:
    cfg = PRESETS[name]
    if mode is not None:
        cfg = cfg.replace(mode=mode)
    cfg.validate()
    return cfg
