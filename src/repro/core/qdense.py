"""Quantized compute ops with WAGEUBN backward semantics, QTensor-native.

The paper's dataflow (Fig. 5 / Algorithms 1-2) is realized with three
custom-vjp ops:

  qeinsum  — every matmul.  Operands may be fp32 grid carriers OR QTensors
             (DESIGN.md §2): a QTensor operand is consumed as-is — its int
             payload feeds the integer dot directly, with NO re-decomposition
             (no amax pass) in either the forward or the backward.  Raw fp32
             operands are decomposed exactly once at entry.  Backward: the
             incoming cotangent is quantized with Q_E2 (paper e3) through the
             quantizer registry, then BOTH the input-error dot (e4 = W^T e3)
             and the weight-gradient dot (g_W = e3 x0^T) run on integer
             operands — exactly Algorithm 2.  2-D int8 dots route through
             the Pallas qmatmul kernel (kernels/ops.qmatmul_op).
  qact     — activation + Q_A.  In native mode the output IS a QTensor
             (payload decomposed once, differentiable via its carrier).
             Backward applies Q_E1 (shift quantization) to the cotangent at
             the layer boundary (paper e0), then the activation derivative
             (paper e1) — exactly Algorithm 2.
  qconv    — ResNet convolutions, same error semantics via jax.vjp on the
             saturating conv evaluated at quantized operands.

Weight quantization Q_W (Eq. 10) is applied by callers through `qweight`
(STE, so the gradient reaches the int32 master copy unchanged, Eq. 1);
in native mode it returns a QTensor with the FIXED 2^(1-k_W) scale — no
amax pass ever happens on weights.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops
from repro.kernels.ops import qmatmul_op

from . import qfuncs as qf
from .qconfig import QConfig
from .qtensor import (QTensor, get_quantizer, payload_dtype, qt_carrier,
                      qtensor_cotangent, quantize_ste, resolve_quantizer)

Array = jax.Array


# --------------------------------------------------------------------------
# weight / activation / prob quantizers (forward-path, STE)
# --------------------------------------------------------------------------


def qweight(cfg: QConfig, w: Array):
    """Q_W (Eq. 10) through cfg.w's registered quantizer, STE.

    native mode -> QTensor (fixed-scale int8 payload, decomposed once);
    sim mode    -> fp32 grid carrier (legacy semantics, bit-identical).
    """
    if not cfg.quantize or not cfg.quant_w:
        return w
    quantizer = cfg.w.make()
    if cfg.native:
        return quantize_ste(quantizer, w)
    return qf.ste(quantizer, w)


def qbn_param(cfg: QConfig, p: Array, k: int) -> Array:
    """Q for norm operands (gamma/beta/mu/sigma, Eq. 13), STE."""
    if not cfg.quantize:
        return p
    return qf.ste(get_quantizer("direct", k), p)


def qprobs(cfg: QConfig, p: Array) -> Array:
    """Attention probabilities onto the k_A grid (in [0,1] so Q is exact-range)."""
    if not cfg.quantize:
        return p
    return qf.ste(get_quantizer("direct", cfg.k_a), p)


_ACT = {
    "relu": (jax.nn.relu, lambda x: (x > 0).astype(jnp.float32)),
    "silu": (jax.nn.silu,
             lambda x: jax.nn.sigmoid(x)
             * (1.0 + x * (1.0 - jax.nn.sigmoid(x)))),
    "gelu": (jax.nn.gelu,
             lambda x: jax.grad(lambda t: jax.nn.gelu(t).sum())(x)),
    "none": (lambda x: x, lambda x: jnp.ones_like(x)),
}


def qact(cfg: QConfig, act: str, x):
    """activation + Q_A.  Native mode returns a QTensor (the int8 payload is
    what downstream matmuls consume); sim/fp32 return fp32 carriers."""
    return _qact(cfg, act, qt_carrier(x))


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _qact(cfg: QConfig, act: str, x: Array):
    fn, _ = _ACT[act]
    y = fn(x)
    if cfg.quantize and cfg.quant_a:
        quantizer = cfg.a.make()
        if cfg.native:
            return quantizer.quantize(y).with_carrier()
        return quantizer(y)
    return y


def _qact_fwd(cfg, act, x):
    return _qact(cfg, act, x), x


def _qact_bwd(cfg, act, x, ct):
    _, dfn = _ACT[act]
    g = ct.carrier if isinstance(ct, QTensor) else ct
    if cfg.quantize and cfg.quant_e1:
        g = cfg.e1.make()(g)          # Q_E1: e0 = SQ(e4^{l+1})   (Eq. 15)
    return (g * dfn(x),)              # e1 = e0 * dACT            (Alg. 2)


_qact.defvjp(_qact_fwd, _qact_bwd)


# --------------------------------------------------------------------------
# quantized einsum
# --------------------------------------------------------------------------


def _bwd_specs(spec: str):
    ins, out = spec.split("->")
    a_s, b_s = ins.split(",")
    for idx in a_s:
        assert idx in out or idx in b_s, f"unsupported einsum {spec}"
    for idx in b_s:
        assert idx in out or idx in a_s, f"unsupported einsum {spec}"
    return f"{out},{b_s}->{a_s}", f"{a_s},{out}->{b_s}"


def _int_contract(spec, a8, b8):
    """Integer contraction; canonical 2-D forms route through the Pallas
    qmatmul kernel (MXU int8 path), everything else through XLA einsum."""
    if a8.dtype == jnp.int8 and b8.dtype == jnp.int8:
        if spec == "mk,kn->mn":
            return qmatmul_op(a8, b8)
        if spec == "mn,kn->mk":          # da = g @ b^T
            return qmatmul_op(a8, b8.T)
        if spec == "mk,mn->kn":          # db = a^T @ g
            return qmatmul_op(a8.T, b8)
    return jnp.einsum(spec, a8, b8, preferred_element_type=jnp.int32)


def _qt_contract(spec, qa: QTensor, qb: QTensor):
    """Sum of integer dots over the operands' plane products, rescaled."""
    y = None
    for a_data, a_scale in qa.planes():
        for b_data, b_scale in qb.planes():
            t = _int_contract(spec, a_data, b_data).astype(jnp.float32) \
                * (a_scale * b_scale)
            y = t if y is None else y + t
    return y


def _fwd_quantize(cfg: QConfig, x, weight_side: bool) -> QTensor:
    """Native operand entry: QTensors pass through untouched (ZERO redundant
    decomposition); raw carriers are decomposed exactly once."""
    if isinstance(x, QTensor):
        return x.drop_carrier()
    if weight_side and cfg.fixed_w_scale:
        return get_quantizer("clip", cfg.k_w).quantize(x)
    return get_quantizer("grid", cfg.k_w if weight_side else cfg.k_a).quantize(x)


def _error_quantizer(cfg: QConfig, e_kind):
    """Registry lookup for Q_E2: QuantSpec | legacy string | "default"."""
    if cfg.quant_e2:
        quantizer = resolve_quantizer(
            cfg.e2 if e_kind == "default" else e_kind, cfg.k_e2)
        if quantizer.name != "none":
            return quantizer
    # identity ("none" via switch, argument, or spec): no quantization; the
    # native payload falls back to the lossless-on-grid 16-bit decomposition
    # (legacy dec_int16) — NEVER k_e2-wide, which would silently quantize a
    # path explicitly configured as unquantized
    return get_quantizer("none")


def _carrier(cfg, y):
    if cfg.tp_comm_dtype == "bf16":
        return y.astype(jnp.bfloat16).astype(jnp.float32)
    return y


def _tag(x) -> str:
    if isinstance(x, QTensor):
        return "qt" if x.carrier is not None else "qt_frozen"
    return "arr"


def _save(x):
    return x.drop_carrier() if isinstance(x, QTensor) else x


def _wrap_ct(tag: str, saved, d):
    """Cotangent matching the original operand's pytree structure: plain
    array for arrays, QTensor-shaped (gradient on the carrier leaf, float0
    payloads) for QTensors; frozen QTensors (no carrier) get no gradient."""
    if tag == "arr":
        return d
    assert isinstance(saved, QTensor), tag   # _save keeps QTensors QTensors
    ct = qtensor_cotangent(saved, None)
    if tag == "qt":
        ct = dataclasses.replace(ct, carrier=d)
    return ct


def qeinsum(cfg: QConfig, spec: str, e_kind, b_weight: bool, a, b) -> Array:
    """y = einsum(spec, a, b) with WAGEUBN forward/backward quantization.

    `a`/`b`: fp32 grid carriers (via qact/qweight in sim mode) or QTensors
    (native mode) — QTensor payloads feed the integer dots directly.
    `e_kind` selects Q_E2: a QuantSpec, a registered/legacy name ("flag8" |
    "sq16" | "sq8" | "none"), or "default" (cfg.e2).  `b_weight` marks b as
    a saturated Q_W weight (fixed-scale int8 decomposition for raw arrays).
    QTensors without a carrier (e.g. the int8 KV cache) are consumed but
    receive no gradient — they are non-differentiable by construction.
    """
    return _qeinsum(cfg, spec, e_kind, b_weight, _tag(a), _tag(b), a, b)


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4, 5))
def _qeinsum(cfg, spec, e_kind, b_weight, a_tag, b_tag, a, b):
    if not cfg.quantize:
        return jnp.einsum(spec, qt_carrier(a), qt_carrier(b))
    if cfg.native:
        qa = _fwd_quantize(cfg, a, False)
        qb = _fwd_quantize(cfg, b, b_weight)
        return _carrier(cfg, _qt_contract(spec, qa, qb))
    return _carrier(cfg, jnp.einsum(spec, qt_carrier(a), qt_carrier(b)))


def _qeinsum_fwd(cfg, spec, e_kind, b_weight, a_tag, b_tag, a, b):
    if not cfg.quantize:
        return jnp.einsum(spec, qt_carrier(a), qt_carrier(b)), \
            (_save(a), _save(b))
    if cfg.native:
        qa = _fwd_quantize(cfg, a, False)
        qb = _fwd_quantize(cfg, b, b_weight)
        y = _carrier(cfg, _qt_contract(spec, qa, qb))
        # int payload residuals: the paper's 4x activation-memory saving
        return y, (qa, qb)
    return _carrier(cfg, jnp.einsum(spec, qt_carrier(a), qt_carrier(b))), \
        (_save(a), _save(b))


def _fusable_operand(q) -> bool:
    return (isinstance(q, QTensor) and q.lo is None
            and q.data.dtype == jnp.int8 and q.data.ndim == 2)


def _fused_bwd(cfg, spec, quantizer, g, a_s, b_s, want_a, want_b):
    """Fused-prologue backward route (DESIGN.md §8), or None to fall back.

    For the canonical 2-D spec with single-plane int8 residuals, Q_E2 is
    fused into the dgrad/wgrad matmul prologues: only the quantizer's scale
    reduction (at most ONE amax, shared by both dots) runs here — the error
    payload is emitted inside the kernels and never materialized.  Output
    is bit-identical to quantizer.quantize + _qt_contract.
    """
    if not (cfg.fuse_kernels and spec == "mk,kn->mn"
            and not isinstance(g, QTensor) and g.ndim == 2):
        return None
    if (want_a and not _fusable_operand(b_s)) or \
            (want_b and not _fusable_operand(a_s)):
        return None
    plan = quantizer.fused_plan(g)
    if plan is None:
        return None
    mode, steps, k = plan
    inv = jnp.float32(1.0) / steps[0]          # pow2: exact reciprocal
    s2 = steps[1] if len(steps) > 1 else jnp.float32(0.0)
    da = db = None
    if want_a:    # e4 = W^T e3, Q_E2 in the kernel prologue (Alg. 2)
        scal = jnp.stack([inv, steps[0] * b_s.scale, s2 * b_s.scale])
        da = ops.dgrad_op(g, b_s.data, scal, mode=mode, k=k)
    if want_b:    # g_W = e3 x0^T, same fused prologue (Alg. 2)
        scal = jnp.stack([inv, steps[0] * a_s.scale, s2 * a_s.scale])
        db = ops.wgrad_op(a_s.data, g, scal, mode=mode, k=k)
    return da, db


def _qeinsum_bwd(cfg, spec, e_kind, b_weight, a_tag, b_tag, res, g):
    da_spec, db_spec = _bwd_specs(spec)
    a_s, b_s = res
    want_a = a_tag != "qt_frozen"
    want_b = b_tag != "qt_frozen"

    if not cfg.quantize:
        da = jnp.einsum(da_spec, g, qt_carrier(b_s)) if want_a else None
        db = jnp.einsum(db_spec, qt_carrier(a_s), g) if want_b else None
        return _wrap_ct(a_tag, a_s, da), _wrap_ct(b_tag, b_s, db)

    quantizer = _error_quantizer(cfg, e_kind)
    if cfg.native:
        fused = _fused_bwd(cfg, spec, quantizer, g, a_s, b_s, want_a, want_b)
        if fused is not None:
            da, db = fused
            return _wrap_ct(a_tag, a_s, da), _wrap_ct(b_tag, b_s, db)
        gq = quantizer.quantize(g)     # e3 = Q_E2(e2), decomposed once
        da = db = None
        if want_a:
            # e4 = W^T e3 on integer operands (Alg. 2)
            da = _qt_contract(da_spec, gq, b_s)
        if want_b:
            # g_W = e3 x0^T on integer operands (Alg. 2)
            db = _qt_contract(db_spec, a_s, gq)
        return _wrap_ct(a_tag, a_s, da), _wrap_ct(b_tag, b_s, db)

    eq = quantizer(g)
    da = jnp.einsum(da_spec, eq, qt_carrier(b_s)) if want_a else None
    db = jnp.einsum(db_spec, qt_carrier(a_s), eq) if want_b else None
    return _wrap_ct(a_tag, a_s, da), _wrap_ct(b_tag, b_s, db)


_qeinsum.defvjp(_qeinsum_fwd, _qeinsum_bwd)


def qdense(cfg: QConfig, x, w: Array, e_kind="default") -> Array:
    """x @ Q_W(w): the Conv step of Alg. 1 for matmul architectures.

    x: (..., K) on the activation grid (Array or QTensor); w: (K, N) master
    weights.  The 2-D contraction routes through the Pallas int8 kernel.
    """
    wq = qweight(cfg, w)
    xm = x.reshape((-1, x.shape[-1]))
    y = qeinsum(cfg, "mk,kn->mn", e_kind, True, xm, wq)
    return y.reshape(x.shape[:-1] + (w.shape[-1],))


def qdense_requant(cfg: QConfig, x, w: Array, step, k: int = 8) -> QTensor:
    """Forward-only qdense emitting the payload on a FIXED pow2 `step`.

    The serving-side entry to the fused requantize epilogue (DESIGN.md §8):
    in native mode with single-plane int8 operands the Pallas matmul's
    epilogue performs int32 accumulate -> pow2 rescale -> round -> clip and
    writes the int8 payload directly — no fp32 carrier, no separate
    quantize pass.  Other modes fall back to qdense + requantize, which is
    bit-identical (every rescale is an exact pow2 scaling).

    x: (..., K) activation (Array or QTensor); w: (K, N) master weights;
    `step` must be a known power of two (e.g. the KV pool's 2^-7).
    Returns a carrier-less QTensor (non-differentiable by construction).
    """
    step = jnp.asarray(step, jnp.float32)
    lim = 2.0 ** (k - 1) - 1.0
    out_shape = x.shape[:-1] + (w.shape[-1],)
    if cfg.quantize and cfg.native and cfg.fuse_kernels and k <= 8:
        wq = qweight(cfg, w)
        xm = x.reshape((-1, x.shape[-1]))
        qa = _fwd_quantize(cfg, xm, False)
        qb = _fwd_quantize(cfg, wq, True)
        if _fusable_operand(qa) and _fusable_operand(qb):
            inv = qa.scale * qb.scale / step     # all pow2: exact
            data = ops.qmatmul_op(qa.data, qb.data, inv, lim=lim)
            return QTensor(data.reshape(out_shape), step, k)
    y = lax.stop_gradient(qt_carrier(qdense(cfg, x, w)))
    data = jnp.clip(jnp.round(y / step), -lim, lim).astype(payload_dtype(k))
    return QTensor(data, step, k)


# --------------------------------------------------------------------------
# quantized convolution (ResNet reproduction)
# --------------------------------------------------------------------------


def _conv(x, w, stride, padding):
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def qconv(cfg: QConfig, x, wq, stride: int, padding: str) -> Array:
    """Quantized conv: operands on grid; backward errors through Q_E2.

    Conv arithmetic runs on exact grid values in fp32 (integer-identical;
    see DESIGN.md §3 — XLA's int8 conv path is TPU-only, so the carrier is
    fp32 while the *semantics* are fixed-point).  QTensor operands
    contribute their differentiable carriers.
    """
    return _qconv(cfg, qt_carrier(x), qt_carrier(wq), stride, padding)


@partial(jax.custom_vjp, nondiff_argnums=(0, 3, 4))
def _qconv(cfg: QConfig, x: Array, wq: Array, stride: int,
           padding: str) -> Array:
    return _conv(x, wq, stride, padding)


def _qconv_fwd(cfg, x, wq, stride, padding):
    y, vjp = jax.vjp(lambda t, v: _conv(t, v, stride, padding), x, wq)
    return y, vjp


def _qconv_bwd(cfg, stride, padding, vjp, g):
    if cfg.quantize and cfg.quant_e2:
        quantizer = cfg.e2.make()
        plan = (quantizer.fused_plan(g)
                if cfg.native and cfg.fuse_kernels else None)
        if plan is not None and plan[0] == "affine" and plan[2] <= 8 \
                and quantizer.name != "none":
            # single-plane int8 formats decompose through the fused
            # quantize kernel dispatch (quantize_op), so e3 materializes
            # once as its int8 payload; the conv vjp consumes the grid
            # value (== the legacy fp32 formula bit-exactly, per the
            # registry invariant).  Multi-plane (flag) and wide formats
            # keep the one-pass legacy formula — decomposing them here
            # would add passes, not remove them.
            g = quantizer.quantize(g).dequantize()
        else:
            g = quantizer(g)           # e3 = Q_E2(...)
    return vjp(g)


_qconv.defvjp(_qconv_fwd, _qconv_bwd)
