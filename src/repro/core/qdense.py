"""Quantized compute ops with WAGEUBN backward semantics.

The paper's dataflow (Fig. 5 / Algorithms 1-2) is realized with three
custom-vjp ops:

  qeinsum  — every matmul.  Forward: int8 x int8 -> int32 (native) or exact
             grid fp32 (sim).  Backward: the incoming cotangent is quantized
             with Q_E2 (paper e3), then BOTH the input-error dot (e4 = W^T e3)
             and the weight-gradient dot (g_W = e3 x0^T) run on integer
             operands — exactly Algorithm 2.
  qact     — activation + Q_A.  Backward applies Q_E1 (shift quantization)
             to the cotangent at the layer boundary (paper e0), then the
             activation derivative (paper e1) — exactly Algorithm 2.
  qconv    — ResNet convolutions, same error semantics via jax.vjp on the
             saturating conv evaluated at quantized operands.

Weight quantization Q_W (Eq. 10) is applied by callers through `qweight`
(STE, so the gradient reaches the int32 master copy unchanged, Eq. 1).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from . import qfuncs as qf
from .qconfig import QConfig

Array = jax.Array


# --------------------------------------------------------------------------
# weight / activation / prob quantizers (forward-path, STE)
# --------------------------------------------------------------------------


def qweight(cfg: QConfig, w: Array) -> Array:
    """Q_W (Eq. 10): k_W-bit direct quantization with saturation, STE."""
    if not cfg.quantize or not cfg.quant_w:
        return w
    return qf.ste(lambda t: qf.q_clip(t, cfg.k_w), w)


def qbn_param(cfg: QConfig, p: Array, k: int) -> Array:
    """Q for norm operands (gamma/beta/mu/sigma, Eq. 13), STE."""
    if not cfg.quantize:
        return p
    return qf.ste(lambda t: qf.q_direct(t, k), p)


def qprobs(cfg: QConfig, p: Array) -> Array:
    """Attention probabilities onto the k_A grid (in [0,1] so Q is exact-range)."""
    if not cfg.quantize:
        return p
    return qf.ste(lambda t: qf.q_direct(t, cfg.k_a), p)


_ACT = {
    "relu": (jax.nn.relu, lambda x: (x > 0).astype(jnp.float32)),
    "silu": (jax.nn.silu,
             lambda x: jax.nn.sigmoid(x)
             * (1.0 + x * (1.0 - jax.nn.sigmoid(x)))),
    "gelu": (jax.nn.gelu,
             lambda x: jax.grad(lambda t: jax.nn.gelu(t).sum())(x)),
    "none": (lambda x: x, lambda x: jnp.ones_like(x)),
}


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def qact(cfg: QConfig, act: str, x: Array) -> Array:
    fn, _ = _ACT[act]
    y = fn(x)
    if cfg.quantize and cfg.quant_a:
        y = qf.q_scaled(y, cfg.k_a)
    return y


def _qact_fwd(cfg, act, x):
    return qact(cfg, act, x), x


def _qact_bwd(cfg, act, x, g):
    _, dfn = _ACT[act]
    if cfg.quantize and cfg.quant_e1:
        g = qf.sq(g, cfg.k_e1)          # Q_E1: e0 = SQ(e4^{l+1})   (Eq. 15)
    return (g * dfn(x),)                # e1 = e0 * dACT            (Alg. 2)


qact.defvjp(_qact_fwd, _qact_bwd)


# --------------------------------------------------------------------------
# quantized einsum
# --------------------------------------------------------------------------


def _bwd_specs(spec: str):
    ins, out = spec.split("->")
    a_s, b_s = ins.split(",")
    for idx in a_s:
        assert idx in out or idx in b_s, f"unsupported einsum {spec}"
    for idx in b_s:
        assert idx in out or idx in a_s, f"unsupported einsum {spec}"
    return f"{out},{b_s}->{a_s}", f"{a_s},{out}->{b_s}"


def _int_einsum(spec, a, b):
    return jnp.einsum(spec, a, b, preferred_element_type=jnp.int32)


def _dec_b(cfg, b, b_weight):
    if b_weight and cfg.fixed_w_scale:
        return qf.dec_int8_fixed(b, cfg.k_w)
    return qf.dec_int8(b, cfg.k_w)


def _carrier(cfg, y):
    if cfg.tp_comm_dtype == "bf16":
        return y.astype(jnp.bfloat16).astype(jnp.float32)
    return y


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def qeinsum(cfg: QConfig, spec: str, e_kind: str, b_weight: bool,
            a: Array, b: Array) -> Array:
    """y = einsum(spec, a, b) with WAGEUBN forward/backward quantization.

    `a` and `b` must already be on their forward grids (via qact/qweight);
    `e_kind` selects Q_E2 ("flag8" | "sq16" | "sq8" | "none"); `b_weight`
    marks b as a saturated Q_W weight (enables fixed-scale int8, §Perf).
    """
    if not cfg.quantize:
        return jnp.einsum(spec, a, b)
    if cfg.native:
        a8, sa = qf.dec_int8(a, cfg.k_a)
        b8, sb = _dec_b(cfg, b, b_weight)
        y = _int_einsum(spec, a8, b8).astype(jnp.float32) * (sa * sb)
        return _carrier(cfg, y)
    return _carrier(cfg, jnp.einsum(spec, a, b))


def _qeinsum_fwd(cfg, spec, e_kind, b_weight, a, b):
    if not cfg.quantize:
        return jnp.einsum(spec, a, b), (a, b)
    if cfg.native:
        a8, sa = qf.dec_int8(a, cfg.k_a)
        b8, sb = _dec_b(cfg, b, b_weight)
        y = _int_einsum(spec, a8, b8).astype(jnp.float32) * (sa * sb)
        # int8 residuals: the paper's 4x activation-memory saving
        return _carrier(cfg, y), (a8, sa, b8, sb)
    return _carrier(cfg, jnp.einsum(spec, a, b)), (a, b)


def _qeinsum_bwd(cfg, spec, e_kind, b_weight, res, g):
    da_spec, db_spec = _bwd_specs(spec)
    if not cfg.quantize:
        a, b = res
        return jnp.einsum(da_spec, g, b), jnp.einsum(db_spec, a, g)

    kind = e_kind if e_kind != "default" else cfg.e2_kind
    if not cfg.quant_e2:
        kind = "none"
    if cfg.native:
        a8, sa, b8, sb = res
        planes = (qf.dec_error(g, kind, cfg.k_e2) if kind != "none"
                  else [qf.dec_int16(g, 16)])
        da = jnp.zeros((), jnp.float32)
        db = jnp.zeros((), jnp.float32)
        for e_data, se in planes:
            # e4 = W^T e3 and g_W = e3 x0^T on integer operands (Alg. 2)
            da = da + _int_einsum(da_spec, e_data, b8).astype(jnp.float32) \
                * (se * sb)
            db = db + _int_einsum(db_spec, a8, e_data).astype(jnp.float32) \
                * (sa * se)
        return da, db

    a, b = res
    eq = qf.quant_error(g, kind, cfg.k_e2) if kind != "none" else g
    return jnp.einsum(da_spec, eq, b), jnp.einsum(db_spec, a, eq)


qeinsum.defvjp(_qeinsum_fwd, _qeinsum_bwd)


def qdense(cfg: QConfig, x: Array, w: Array,
           e_kind: str = "default") -> Array:
    """x @ Q_W(w): the Conv step of Alg. 1 for matmul architectures.

    x: (..., K) on the activation grid;  w: (K, N) master weights.
    """
    wq = qweight(cfg, w)
    xm = x.reshape((-1, x.shape[-1]))
    y = qeinsum(cfg, "mk,kn->mn", e_kind, True, xm, wq)
    return y.reshape(x.shape[:-1] + (w.shape[-1],))


# --------------------------------------------------------------------------
# quantized convolution (ResNet reproduction)
# --------------------------------------------------------------------------


def _conv(x, w, stride, padding):
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


@partial(jax.custom_vjp, nondiff_argnums=(0, 3, 4))
def qconv(cfg: QConfig, x: Array, wq: Array, stride: int,
          padding: str) -> Array:
    """Quantized conv: operands on grid; backward errors through Q_E2.

    Conv arithmetic runs on exact grid values in fp32 (integer-identical;
    see DESIGN.md §3 — XLA's int8 conv path is TPU-only, so the carrier is
    fp32 while the *semantics* are fixed-point).
    """
    return _conv(x, wq, stride, padding)


def _qconv_fwd(cfg, x, wq, stride, padding):
    y, vjp = jax.vjp(lambda t, v: _conv(t, v, stride, padding), x, wq)
    return y, vjp


def _qconv_bwd(cfg, stride, padding, vjp, g):
    if cfg.quantize and cfg.quant_e2:
        g = qf.quant_error(g, cfg.e2_kind, cfg.k_e2)   # e3 = Q_E2(...)
    return vjp(g)


qconv.defvjp(_qconv_fwd, _qconv_bwd)
