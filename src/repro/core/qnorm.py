"""Quantized normalization layers (paper Eq. 11-13, adapted per DESIGN.md §3).

The paper quantizes BN's operands: mu -> k_mu, sigma -> k_sigma, the
normalized activation x_hat -> k_BN, gamma/beta -> k_gamma/k_beta.  All
quantizers use STE, so standard autodiff through these functions *is* the
paper's quantized backward evaluated on grid values (e1 = e0*gamma_q,
g_gamma = e1*x_hat, g_beta = e1, and the stat terms of e3's pre-image).
Q_E2 on the outgoing error is applied by the adjacent qeinsum/qconv.

RMSNorm / LayerNorm ports keep the identical bit-width recipe — RMSNorm is
BN with per-token statistics, no mean and no running stats (the paper itself
drops running stats "considering the computational cost", §IV-D).

Fused UBN (DESIGN.md §8): in native mode the whole forward chain —
statistics, normalize, and all five direct quantizations — runs as ONE
kernel pass through `kernels/ops.ubn_norm_op` instead of five XLA passes
re-materializing the activation between stages.  The fused forward is
bit-identical to the unfused composition (every direct quantizer has a
fixed pow2 step, so no amax appears anywhere), and the backward is the vjp
of the unfused body — the STE semantics are unchanged.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ops

from . import qfuncs as qf
from .qconfig import QConfig
from .qtensor import get_quantizer, qt_carrier

Array = jax.Array

EPS_Q = 2.0 ** -8  # epsilon_q: small fixed-point value (Eq. 12)


def _qs(cfg: QConfig, t: Array, k: int) -> Array:
    """Direct-quantize with STE when quantization is on (registry-resolved;
    the "direct" quantizer's grid output is bit-identical to qf.q_direct)."""
    if not cfg.quantize or not cfg.quant_bn:
        return t
    return qf.ste(get_quantizer("direct", k), t)


def _maybe_stop(cfg: QConfig, t: Array) -> Array:
    return t if cfg.norm_full_bwd else jax.lax.stop_gradient(t)


def _fuse(cfg: QConfig) -> bool:
    return (cfg.native and cfg.quant_bn
            and getattr(cfg, "fuse_kernels", True))


# --------------------------------------------------------------------------
# unfused bodies (sim mode, and the vjp ground truth for the fused route)
# --------------------------------------------------------------------------


def _qbatchnorm_unfused(cfg: QConfig, x: Array, gamma: Array,
                        beta: Array) -> Array:
    axes = tuple(range(x.ndim - 1))
    mu = _maybe_stop(cfg, jnp.mean(x, axes))
    var = _maybe_stop(cfg, jnp.mean(jnp.square(x), axes) - jnp.square(mu))
    sigma = jnp.sqrt(jnp.maximum(var, 0.0))
    mu_q = _qs(cfg, mu, cfg.k_mu)
    sigma_q = _qs(cfg, sigma, cfg.k_sigma)
    xhat = (x - mu_q) / (sigma_q + EPS_Q)
    xhat = _qs(cfg, xhat, cfg.k_bn)                        # Q_BN
    gamma_q = _qs(cfg, gamma, cfg.k_gamma)
    beta_q = _qs(cfg, beta, cfg.k_beta)
    return gamma_q * xhat + beta_q


def _qrmsnorm_unfused(cfg: QConfig, x: Array, gamma: Array) -> Array:
    ms = _maybe_stop(cfg, jnp.mean(jnp.square(x), axis=-1, keepdims=True))
    sigma = jnp.sqrt(ms)
    sigma_q = _qs(cfg, sigma, cfg.k_sigma)
    xhat = x / (sigma_q + EPS_Q)
    xhat = _qs(cfg, xhat, cfg.k_bn)
    gamma_q = _qs(cfg, gamma, cfg.k_gamma)
    return gamma_q * xhat


def _qlayernorm_unfused(cfg: QConfig, x: Array, gamma: Array,
                        beta: Array) -> Array:
    mu = _maybe_stop(cfg, jnp.mean(x, axis=-1, keepdims=True))
    var = _maybe_stop(
        cfg, jnp.mean(jnp.square(x), axis=-1, keepdims=True) - jnp.square(mu))
    sigma = jnp.sqrt(jnp.maximum(var, 0.0))
    mu_q = _qs(cfg, mu, cfg.k_mu)
    sigma_q = _qs(cfg, sigma, cfg.k_sigma)
    xhat = (x - mu_q) / (sigma_q + EPS_Q)
    xhat = _qs(cfg, xhat, cfg.k_bn)
    gamma_q = _qs(cfg, gamma, cfg.k_gamma)
    beta_q = _qs(cfg, beta, cfg.k_beta)
    return gamma_q * xhat + beta_q


_UNFUSED = {"batch": _qbatchnorm_unfused, "layer": _qlayernorm_unfused}


# --------------------------------------------------------------------------
# fused UBN route (native mode): one kernel pass, unfused vjp
# --------------------------------------------------------------------------


def _ubn_widths(cfg: QConfig) -> dict:
    return dict(k_mu=cfg.k_mu, k_sigma=cfg.k_sigma, k_bn=cfg.k_bn,
                k_gamma=cfg.k_gamma, k_beta=cfg.k_beta, eps=EPS_Q)


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _fused_norm(kind: str, cfg: QConfig, x: Array, gamma: Array,
                beta: Array) -> Array:
    x2 = x.reshape((-1, x.shape[-1]))
    y = ops.ubn_norm_op(x2, gamma, beta, kind=kind, **_ubn_widths(cfg))
    return y.reshape(x.shape)


def _fused_norm_fwd(kind, cfg, x, gamma, beta):
    return _fused_norm(kind, cfg, x, gamma, beta), (x, gamma, beta)


def _fused_norm_bwd(kind, cfg, res, g):
    # the fused forward is bit-identical to the unfused body, so its vjp IS
    # the fused op's gradient (STE through every direct quantizer)
    x, gamma, beta = res
    _, vjp = jax.vjp(lambda *a: _UNFUSED[kind](cfg, *a), x, gamma, beta)
    return vjp(g)


_fused_norm.defvjp(_fused_norm_fwd, _fused_norm_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fused_rmsnorm(cfg: QConfig, x: Array, gamma: Array) -> Array:
    x2 = x.reshape((-1, x.shape[-1]))
    y = ops.ubn_norm_op(x2, gamma, None, kind="rms", **_ubn_widths(cfg))
    return y.reshape(x.shape)


def _fused_rmsnorm_fwd(cfg, x, gamma):
    return _fused_rmsnorm(cfg, x, gamma), (x, gamma)


def _fused_rmsnorm_bwd(cfg, res, g):
    x, gamma = res
    _, vjp = jax.vjp(lambda *a: _qrmsnorm_unfused(cfg, *a), x, gamma)
    return vjp(g)


_fused_rmsnorm.defvjp(_fused_rmsnorm_fwd, _fused_rmsnorm_bwd)


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------


def qbatchnorm(cfg: QConfig, x, gamma: Array, beta: Array) -> Array:
    """Quantized BN over all axes but the last (channel), paper Eq. 12."""
    x = qt_carrier(x)
    if _fuse(cfg):
        return _fused_norm("batch", cfg, x, gamma, beta)
    return _qbatchnorm_unfused(cfg, x, gamma, beta)


def qrmsnorm(cfg: QConfig, x, gamma: Array) -> Array:
    """Quantized RMSNorm: the BN recipe with per-token stats, no mean."""
    x = qt_carrier(x)
    if _fuse(cfg):
        return _fused_rmsnorm(cfg, x, gamma)
    return _qrmsnorm_unfused(cfg, x, gamma)


def qlayernorm(cfg: QConfig, x, gamma: Array, beta: Array) -> Array:
    """Quantized LayerNorm (per-token mean + var), same widths as BN."""
    x = qt_carrier(x)
    if _fuse(cfg):
        return _fused_norm("layer", cfg, x, gamma, beta)
    return _qlayernorm_unfused(cfg, x, gamma, beta)
