"""Quantized normalization layers (paper Eq. 11-13, adapted per DESIGN.md §3).

The paper quantizes BN's operands: mu -> k_mu, sigma -> k_sigma, the
normalized activation x_hat -> k_BN, gamma/beta -> k_gamma/k_beta.  All
quantizers use STE, so standard autodiff through these functions *is* the
paper's quantized backward evaluated on grid values (e1 = e0*gamma_q,
g_gamma = e1*x_hat, g_beta = e1, and the stat terms of e3's pre-image).
Q_E2 on the outgoing error is applied by the adjacent qeinsum/qconv.

RMSNorm / LayerNorm ports keep the identical bit-width recipe — RMSNorm is
BN with per-token statistics, no mean and no running stats (the paper itself
drops running stats "considering the computational cost", §IV-D).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import qfuncs as qf
from .qconfig import QConfig
from .qtensor import get_quantizer

Array = jax.Array

EPS_Q = 2.0 ** -8  # epsilon_q: small fixed-point value (Eq. 12)


def _qs(cfg: QConfig, t: Array, k: int) -> Array:
    """Direct-quantize with STE when quantization is on (registry-resolved;
    the "direct" quantizer's grid output is bit-identical to qf.q_direct)."""
    if not cfg.quantize or not cfg.quant_bn:
        return t
    return qf.ste(get_quantizer("direct", k), t)


def _maybe_stop(cfg: QConfig, t: Array) -> Array:
    return t if cfg.norm_full_bwd else jax.lax.stop_gradient(t)


def qbatchnorm(cfg: QConfig, x: Array, gamma: Array, beta: Array) -> Array:
    """Quantized BN over all axes but the last (channel), paper Eq. 12."""
    axes = tuple(range(x.ndim - 1))
    mu = _maybe_stop(cfg, jnp.mean(x, axes))
    var = _maybe_stop(cfg, jnp.mean(jnp.square(x), axes) - jnp.square(mu))
    sigma = jnp.sqrt(jnp.maximum(var, 0.0))
    mu_q = _qs(cfg, mu, cfg.k_mu)
    sigma_q = _qs(cfg, sigma, cfg.k_sigma)
    xhat = (x - mu_q) / (sigma_q + EPS_Q)
    xhat = _qs(cfg, xhat, cfg.k_bn)                        # Q_BN
    gamma_q = _qs(cfg, gamma, cfg.k_gamma)
    beta_q = _qs(cfg, beta, cfg.k_beta)
    return gamma_q * xhat + beta_q


def qrmsnorm(cfg: QConfig, x: Array, gamma: Array) -> Array:
    """Quantized RMSNorm: the BN recipe with per-token stats, no mean."""
    ms = _maybe_stop(cfg, jnp.mean(jnp.square(x), axis=-1, keepdims=True))
    sigma = jnp.sqrt(ms)
    sigma_q = _qs(cfg, sigma, cfg.k_sigma)
    xhat = x / (sigma_q + EPS_Q)
    xhat = _qs(cfg, xhat, cfg.k_bn)
    gamma_q = _qs(cfg, gamma, cfg.k_gamma)
    return gamma_q * xhat


def qlayernorm(cfg: QConfig, x: Array, gamma: Array, beta: Array) -> Array:
    """Quantized LayerNorm (per-token mean + var), same widths as BN."""
    mu = _maybe_stop(cfg, jnp.mean(x, axis=-1, keepdims=True))
    var = _maybe_stop(
        cfg, jnp.mean(jnp.square(x), axis=-1, keepdims=True) - jnp.square(mu))
    sigma = jnp.sqrt(jnp.maximum(var, 0.0))
    mu_q = _qs(cfg, mu, cfg.k_mu)
    sigma_q = _qs(cfg, sigma, cfg.k_sigma)
    xhat = (x - mu_q) / (sigma_q + EPS_Q)
    xhat = _qs(cfg, xhat, cfg.k_bn)
    gamma_q = _qs(cfg, gamma, cfg.k_gamma)
    beta_q = _qs(cfg, beta, cfg.k_beta)
    return gamma_q * xhat + beta_q
