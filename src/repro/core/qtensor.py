"""First-class quantized tensors + the pluggable quantizer registry.

This module is the WAGEUBN data model (DESIGN.md §2): every low-bit path
(W/A/G/E/U/BN) carries an integer payload with a power-of-two scale, and a
`QTensor` makes that payload the object that flows through the program
instead of being re-derived from fp32 grid carriers at every matmul.

  QTensor    — pytree of (integer data, pow2 scale[, low plane][, carrier]).
               `data * scale (+ lo * lo_scale)` is the represented value;
               `carrier`, when present, is the same value as a differentiable
               fp32 leaf so autodiff routes around the integer payload.
  Quantizer  — protocol: `__call__` (grid fp32 output, the legacy/sim
               semantics), `quantize` (-> QTensor, decompose exactly once),
               `dequantize`, `planes` (multi-plane formats like flag8).
  registry   — `register_quantizer` / `get_quantizer` / `resolve_quantizer`;
               legacy string kinds ("flag8", "sq16", "dec_int8", ...) resolve
               through `ALIASES`, so old call sites keep working while new
               quantizers plug in without touching core dispatch.
  QuantSpec  — hashable (kind, k, params) triple used by QConfig's structured
               per-path quantizer fields.

Invariant validated by tests/test_qtensor.py: for every registered quantizer
`dequantize(quantize(x)) == __call__(x)` bit-exactly on in-range inputs, and
`__call__` delegates to the legacy qfuncs formula verbatim.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import quantize_op

from . import qfuncs as qf

Array = jax.Array

_FIELDS = ("data", "scale", "lo", "lo_scale", "carrier")


def payload_dtype(k: int):
    if k <= 8:
        return jnp.int8
    if k <= 16:
        return jnp.int16
    return jnp.int32


def _float0_like(x):
    return np.zeros(np.shape(x), jax.dtypes.float0)


@jax.tree_util.register_pytree_with_keys_class
@dataclass(frozen=True)
class QTensor:
    """Integer payload + power-of-two scale, registered as a jax pytree.

    value = data * scale (+ lo * lo_scale for two-plane formats).  `k` is the
    logical bit-width (static aux data, preserved through jit/grad/scan).
    `carrier` is an optional differentiable fp32 view of the same value:
    QTensors produced inside autodiff (qact / quantize_ste) carry one so
    cotangents have somewhere to flow; raw payloads (KV cache, wire formats)
    leave it None and are non-differentiable by construction.
    """

    data: Array
    scale: Array
    k: int = 8
    lo: Array | None = None
    lo_scale: Array | None = None
    carrier: Array | None = None

    # ---- pytree protocol -------------------------------------------------

    def tree_flatten_with_keys(self):
        children = [(jax.tree_util.GetAttrKey(n), getattr(self, n))
                    for n in _FIELDS]
        return children, self.k

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux, *children[2:])

    # ---- value semantics -------------------------------------------------

    def dequantize(self) -> Array:
        y = self.data.astype(jnp.float32) * self.scale
        if self.lo is not None:
            y = y + self.lo.astype(jnp.float32) * self.lo_scale
        return y

    def to_array(self) -> Array:
        """Differentiable fp32 view when available, else dequantize."""
        if self.carrier is not None:
            return self.carrier
        return self.dequantize()

    def __jax_array__(self) -> Array:
        return self.to_array()

    def planes(self):
        """((data, scale), ...) integer planes for native matmuls."""
        if self.lo is None:
            return ((self.data, self.scale),)
        return ((self.data, self.scale), (self.lo, self.lo_scale))

    def with_carrier(self) -> "QTensor":
        return dataclasses.replace(self, carrier=self.dequantize())

    def drop_carrier(self) -> "QTensor":
        """Payload-only view: what backward residuals store (4x memory win)."""
        if self.carrier is None:
            return self
        return dataclasses.replace(self, carrier=None)

    def requantize(self, step, k: int | None = None) -> Array:
        """Re-express the payload on a new pow2 step WITHOUT an amax pass.

        Returns the raw integer payload saturated to the TARGET width `k`
        (default: this tensor's own width) — a rounding shift plus clip,
        never a data-dependent rescan.  Pass k=8 when writing into an int8
        store (e.g. the KV cache) so wider payloads saturate instead of
        wrapping on the dtype cast.
        """
        k = self.k if k is None else k
        v = self.data.astype(jnp.float32) * (self.scale / step)
        if self.lo is not None:
            v = v + self.lo.astype(jnp.float32) * (self.lo_scale / step)
        lim = 2.0 ** (k - 1) - 1.0
        return jnp.clip(jnp.round(v), -lim, lim).astype(payload_dtype(k))

    # ---- array-like surface ---------------------------------------------

    @property
    def shape(self):
        return self.data.shape

    @property
    def ndim(self):
        return self.data.ndim

    @property
    def size(self):
        return self.data.size

    @property
    def dtype(self):
        # logical dtype of the represented value (what dequantize returns)
        return jnp.dtype(jnp.float32)

    def _map_payload(self, fn) -> "QTensor":
        """Apply a shape-only op to every payload plane (scale unchanged)."""
        return dataclasses.replace(
            self, data=fn(self.data),
            lo=None if self.lo is None else fn(self.lo),
            carrier=None if self.carrier is None else fn(self.carrier))

    def reshape(self, *shape) -> "QTensor":
        if len(shape) == 1 and isinstance(shape[0], (tuple, list)):
            shape = tuple(shape[0])
        return self._map_payload(lambda t: t.reshape(shape))

    def transpose(self, *axes) -> "QTensor":
        if len(axes) == 1 and isinstance(axes[0], (tuple, list)):
            axes = tuple(axes[0])
        return self._map_payload(lambda t: t.transpose(axes or None))

    def swapaxes(self, a, b) -> "QTensor":
        return self._map_payload(lambda t: jnp.swapaxes(t, a, b))

    def __getitem__(self, idx) -> "QTensor":
        return self._map_payload(lambda t: t[idx])

    # arithmetic degrades to the fp32 view (differentiable via carrier)
    def __mul__(self, o):
        return self.to_array() * _arr(o)

    __rmul__ = __mul__

    def __add__(self, o):
        return self.to_array() + _arr(o)

    __radd__ = __add__

    def __sub__(self, o):
        return self.to_array() - _arr(o)

    def __rsub__(self, o):
        return _arr(o) - self.to_array()

    def __truediv__(self, o):
        return self.to_array() / _arr(o)

    def __rtruediv__(self, o):
        return _arr(o) / self.to_array()

    def __neg__(self):
        return -self.to_array()


def _arr(x) -> Array:
    """fp32 view of Array | QTensor (differentiable when carrier present)."""
    return x.to_array() if isinstance(x, QTensor) else x


# re-exported under a readable name for model code
qt_carrier = _arr


def qtensor_cotangent(like: QTensor, d_carrier) -> QTensor:
    """Cotangent pytree matching `like`'s structure.

    Integer payload leaves take float0 (non-differentiable), the scale takes
    a zero, and the fp32 gradient lands on the carrier leaf (None if `like`
    has no carrier — such QTensors are non-differentiable inputs).
    """
    return QTensor(
        _float0_like(like.data), jnp.zeros_like(like.scale), like.k,
        None if like.lo is None else _float0_like(like.lo),
        None if like.lo_scale is None else jnp.zeros_like(like.lo_scale),
        None if like.carrier is None else d_carrier)


# ==========================================================================
# Quantizer protocol + implementations
# ==========================================================================


def _decompose(x: Array, step, k: int) -> QTensor:
    """Shared payload decomposition: clip(round(x/step)) saturated to the
    signed k-bit range.  int8-width payloads route through the fused Pallas
    quantize kernel (kernels/ops.quantize_op — TPU kernel, jnp oracle on
    CPU); wider payloads lower through XLA.  `step` must be a power of two,
    so the reciprocal multiply is exact."""
    lim = 2.0 ** (k - 1) - 1.0
    step = jnp.asarray(step, jnp.float32)
    if k <= 8:
        x2 = x.reshape(1, -1) if x.ndim != 2 else x
        data = quantize_op(x2, jnp.float32(1.0) / step,
                           lim=lim).reshape(x.shape)
    else:
        data = jnp.clip(jnp.round(x / step), -lim,
                        lim).astype(payload_dtype(k))
    return QTensor(data, step, k)


@dataclass(frozen=True)
class Quantizer:
    """Base quantizer: `__call__` = legacy grid-carrier output (sim mode);
    `quantize` = native decomposition into a QTensor (exactly once);
    `dequantize(quantize(x)) == __call__(x)` bit-exactly on in-range inputs.

    Frozen dataclass => hashable => usable as a static custom_vjp argument.
    """

    k: int = 8

    name = "base"

    def __call__(self, x: Array, *, key: Array | None = None) -> Array:
        return self.dequantize(self.quantize(x, key=key))

    def quantize(self, x: Array, *, key: Array | None = None) -> QTensor:
        raise NotImplementedError

    def dequantize(self, qt: QTensor) -> Array:
        return qt.dequantize()

    def planes(self, qt: QTensor):
        return qt.planes()

    def fused_plan(self, x: Array):
        """Scalar recipe for fusing this quantizer into a matmul prologue.

        Returns (mode, plane_steps, k) — mode "affine" (one plane,
        payload = clip(round(x / plane_steps[0]), ±(2^(k-1)-1))) or "flag"
        (two planes at steps (Sc, Sc*2^(1-k))) — or None when the format
        cannot be fused (e.g. stochastic rounding needs a PRNG plane).
        Only the scale reduction (at most one amax) runs here; payload
        emission happens inside the fused kernel.  The planes must be
        bit-identical to `quantize(x).planes()`.
        """
        return None


@dataclass(frozen=True)
class IdentityQuantizer(Quantizer):
    """No forward quantization; native payloads use a lossless-on-grid 16-bit
    decomposition (the legacy `dec_int16` fallback for e_kind == "none")."""

    k: int = 16

    name = "none"

    def __call__(self, x, *, key=None):
        return x

    def quantize(self, x, *, key=None):
        s = jnp.maximum(qf.pow2_ceil(qf.amax(x)), 2.0 ** -24)
        return _decompose(x, s * 2.0 ** (1 - self.k), self.k)

    def fused_plan(self, x):
        s = jnp.maximum(qf.pow2_ceil(qf.amax(x)), 2.0 ** -24)
        return ("affine", (s * 2.0 ** (1 - self.k),), self.k)


@dataclass(frozen=True)
class GridQuantizer(IdentityQuantizer):
    """Decompose a tensor already on a fixed-point grid (paper "grid
    carriers", DESIGN.md §3): pow2_ceil(amax) scale, floor 2^-24.  This is
    the legacy `dec_int8`/`dec_int16` pair; lossless whenever x came from
    q_scaled / q_clip / sq at width <= k."""

    k: int = 8

    name = "grid"

    def __call__(self, x, *, key=None):
        return self.dequantize(self.quantize(x))


@dataclass(frozen=True)
class DirectQuantizer(Quantizer):
    """Q(x,k) = round(x * 2^(k-1)) / 2^(k-1)  (paper Eq. 6).  The payload
    decomposition clips to the signed k-bit range, so quantize/dequantize is
    exact only for |x| <= 1 - 2^(1-k) (the grid's representable range)."""

    name = "direct"

    def __call__(self, x, *, key=None):
        return qf.q_direct(x, self.k)

    def quantize(self, x, *, key=None):
        return _decompose(x, 2.0 ** (1 - self.k), self.k)

    def fused_plan(self, x):
        # fixed grid step: no amax at all
        return ("affine", (jnp.float32(2.0 ** (1 - self.k)),), self.k)


@dataclass(frozen=True)
class ClipQuantizer(Quantizer):
    """Q_W (paper Eq. 10): direct quantization saturating to (-1, 1).  The
    payload scale is the FIXED 2^(1-k) grid step — no amax pass, no scalar
    collective; the int8 copy is what FSDP gathers (legacy dec_int8_fixed)."""

    name = "clip"

    def __call__(self, x, *, key=None):
        return qf.q_clip(x, self.k)

    def quantize(self, x, *, key=None):
        return _decompose(x, 2.0 ** (1 - self.k), self.k)

    def fused_plan(self, x):
        return ("affine", (jnp.float32(2.0 ** (1 - self.k)),), self.k)


@dataclass(frozen=True)
class ScaledQuantizer(Quantizer):
    """Q_A (paper Eq. 14 + WAGE layer-wise pow2 scaling): amax pow2_ceil
    scale >= 1 extends coverage beyond (-1, 1); payload is int8-packable by
    construction (|n| <= 2^(k-1) - 1)."""

    name = "scaled"

    def __call__(self, x, *, key=None):
        return qf.q_scaled(x, self.k)

    def quantize(self, x, *, key=None):
        s = jnp.maximum(qf.pow2_ceil(qf.amax(x)), 1.0)
        return _decompose(x, s * 2.0 ** (1 - self.k), self.k)

    def fused_plan(self, x):
        s = jnp.maximum(qf.pow2_ceil(qf.amax(x)), 1.0)
        return ("affine", (s * 2.0 ** (1 - self.k),), self.k)


@dataclass(frozen=True)
class ShiftQuantizer(Quantizer):
    """SQ (paper Eq. 8): layer-wise pow2 scale R(x) = 2^round(log2 amax)."""

    name = "sq"

    def __call__(self, x, *, key=None):
        return qf.sq(x, self.k)

    def quantize(self, x, *, key=None):
        r = qf.pow2_round(qf.amax(x))
        return _decompose(x, r * 2.0 ** (1 - self.k), self.k)

    def fused_plan(self, x):
        r = qf.pow2_round(qf.amax(x))
        return ("affine", (r * 2.0 ** (1 - self.k),), self.k)


@dataclass(frozen=True)
class FlagQuantizer(Quantizer):
    """Flag-bit error quantization (paper Eq. 17 / Fig. 4): one int8 mantissa
    under two pow2 regimes.  `quantize` emits TWO disjoint-support int8
    planes (hi: multiples of Sc; lo: multiples of Sc*2^(1-k)) — the TPU
    realization of the 9-bit flag format where storage and both backward
    dots stay int8.  Sum of dequantized planes == flag_qe2(x) bit-exactly
    (the regime split keys off the rounded payload, so boundary values land
    where the legacy scalar formula puts them)."""

    name = "flag"

    def __call__(self, x, *, key=None):
        return qf.flag_qe2(x, self.k)

    def quantize(self, x, *, key=None):
        k = self.k
        r = qf.pow2_round(qf.amax(x))
        sc = r / 2.0 ** (k - 1)
        n = x / sc
        lim = 2.0 ** (k - 1) - 1.0
        nlo = jnp.round(n * 2.0 ** (k - 1))
        # |nlo| >= 2^(k-1) collapses to the hi regime (same value there)
        isbig = (jnp.abs(n) >= 1.0) | (jnp.abs(nlo) >= 2.0 ** (k - 1))
        hi = jnp.where(isbig, jnp.clip(jnp.round(n), -lim, lim), 0.0)
        lo = jnp.where(isbig, 0.0, jnp.clip(nlo, -lim, lim))
        dt = payload_dtype(k)
        return QTensor(hi.astype(dt), sc, k,
                       lo=lo.astype(dt), lo_scale=sc * 2.0 ** (1 - k))

    def fused_plan(self, x):
        r = qf.pow2_round(qf.amax(x))
        sc = r / 2.0 ** (self.k - 1)
        return ("flag", (sc, sc * 2.0 ** (1 - self.k)), self.k)


@dataclass(frozen=True)
class ConstantQuantizer(Quantizer):
    """CQ (paper Eq. 7) for weight gradients: range-normalized, constant
    pow2 scale 2^(1-k_gc), stochastic rounding, shrinking dr schedule."""

    k: int = 15          # k_gc: constant scale bits
    dr_bits: int = 8     # dr = 2^(dr_bits-1), shrinks during training
    stochastic: bool = True

    name = "cq"

    def __call__(self, x, *, key=None):
        return qf.cq(x, key, self.dr_bits, self.k, stochastic=self.stochastic)

    def quantize(self, x, *, key=None):
        r = qf.pow2_round(qf.amax(x))
        dr = float(2 ** (self.dr_bits - 1))
        y = dr * (x / r)
        if self.stochastic:
            assert key is not None, "stochastic CQ needs a PRNG key"
            y = qf.stochastic_round(y, key)
        else:
            y = jnp.round(y)
        data = jnp.clip(y, -dr + 1.0,
                        dr - 1.0).astype(payload_dtype(self.dr_bits))
        return QTensor(data, jnp.float32(2.0 ** (1 - self.k)), self.k)


# ==========================================================================
# registry
# ==========================================================================


_REGISTRY: dict[str, type] = {}

# legacy string kinds -> (registered name, fixed k or None)
ALIASES: dict[str, tuple[str, int | None]] = {
    "flag8": ("flag", 8),
    "sq8": ("sq", 8),
    "sq16": ("sq", 16),
    "q_direct": ("direct", None),
    "q_clip": ("clip", None),
    "q_scaled": ("scaled", None),
    "dec_int8": ("grid", 8),
    "dec_int16": ("grid", 16),
    "dec_int8_fixed": ("clip", 8),
    "identity": ("none", None),
}


def register_quantizer(name: str, cls: type) -> type:
    """Register a Quantizer class under `name`.  New quantizer kinds plug in
    here without touching core dispatch; returns cls for decorator use.
    Overriding an existing name takes effect immediately (the instance
    cache is invalidated)."""
    _REGISTRY[name] = cls
    get_quantizer.cache_clear()
    return cls


def registered_quantizers() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


@lru_cache(maxsize=None)
def get_quantizer(kind: str, k: int | None = None,
                  params: tuple = ()) -> Quantizer:
    """Instantiate (and cache) a quantizer by registry name or legacy alias.

    `params` is a tuple of (key, value) pairs so the lookup stays hashable.
    """
    if kind in ALIASES:
        name, fixed_k = ALIASES[kind]
        return get_quantizer(name, fixed_k if fixed_k is not None else k,
                             params)
    if kind not in _REGISTRY:
        raise ValueError(
            f"unknown quantizer {kind!r}; registered: {registered_quantizers()}")
    cls = _REGISTRY[kind]
    kw = dict(params)
    if k is not None:
        kw["k"] = k
    return cls(**kw)


for _cls in (IdentityQuantizer, GridQuantizer, DirectQuantizer,
             ClipQuantizer, ScaledQuantizer, ShiftQuantizer, FlagQuantizer,
             ConstantQuantizer):
    register_quantizer(_cls.name, _cls)


# ==========================================================================
# QuantSpec — QConfig's structured per-path quantizer description
# ==========================================================================


@dataclass(frozen=True)
class QuantSpec:
    """Hashable (kind, k, params) triple naming a registered quantizer."""

    kind: str
    k: int = 8
    params: tuple = ()

    def make(self) -> Quantizer:
        return get_quantizer(self.kind, self.k, self.params)

    def replace(self, **kw) -> "QuantSpec":
        return dataclasses.replace(self, **kw)


def spec_from_alias(kind: str, default_k: int = 8) -> QuantSpec:
    """Legacy string kind -> QuantSpec ("sq16" -> sq@16, "flag8" -> flag@8).

    Width-suffixed aliases pin their k (matching the legacy quant_error
    dispatch, which hardcoded them); bare kinds take `default_k`.
    """
    if kind in ALIASES:
        name, fixed_k = ALIASES[kind]
        return QuantSpec(name, fixed_k if fixed_k is not None else default_k)
    if kind not in _REGISTRY:
        raise ValueError(
            f"unknown quantizer {kind!r}; registered: {registered_quantizers()}")
    return QuantSpec(kind, default_k)


def legacy_kind(spec: QuantSpec) -> str:
    """Canonical legacy string for a spec (for the deprecated alias fields)."""
    for alias, (name, fixed_k) in ALIASES.items():
        if name == spec.kind and fixed_k == spec.k:
            return alias
    return spec.kind


def resolve_quantizer(spec, default_k: int = 8) -> Quantizer:
    """QuantSpec | legacy string | Quantizer -> Quantizer instance."""
    if isinstance(spec, Quantizer):
        return spec
    if isinstance(spec, QuantSpec):
        return spec.make()
    return spec_from_alias(spec, default_k).make()


# ==========================================================================
# quantize with straight-through estimator (paper Eq. 1), QTensor-valued
# ==========================================================================


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def quantize_ste(quantizer: Quantizer, x: Array) -> QTensor:
    """QTensor = quantizer.quantize(x), identity cotangent to x.

    The returned QTensor has a carrier, so it composes with both payload
    consumers (qeinsum native) and fp32 consumers (elementwise math).
    """
    return quantizer.quantize(x).with_carrier()


def _quantize_ste_fwd(quantizer, x):
    return quantize_ste(quantizer, x), None


def _quantize_ste_bwd(quantizer, _, ct):
    return (ct.carrier,)


quantize_ste.defvjp(_quantize_ste_fwd, _quantize_ste_bwd)
