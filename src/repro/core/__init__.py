"""WAGEUBN core: QTensor + quantizer registry, quantized ops, quantized norms."""
from .qconfig import FULL8, E2_16, FP32, PRESETS, QConfig, preset
from .qtensor import (ALIASES, QTensor, QuantSpec, Quantizer, get_quantizer,
                      qt_carrier, quantize_ste, register_quantizer,
                      registered_quantizers, resolve_quantizer)
from . import qfuncs
from .qdense import (qact, qconv, qdense, qdense_requant, qeinsum, qprobs,
                     qweight, qbn_param)
from .qnorm import qbatchnorm, qlayernorm, qrmsnorm

__all__ = [
    "FULL8", "E2_16", "FP32", "PRESETS", "QConfig", "preset", "qfuncs",
    "ALIASES", "QTensor", "QuantSpec", "Quantizer", "get_quantizer",
    "qt_carrier", "quantize_ste", "register_quantizer",
    "registered_quantizers", "resolve_quantizer",
    "qact", "qconv", "qdense", "qdense_requant", "qeinsum", "qprobs",
    "qweight", "qbn_param", "qbatchnorm", "qlayernorm", "qrmsnorm",
]
