"""WAGEUBN core: quantization functions, quantized ops, quantized norms."""
from .qconfig import FULL8, E2_16, FP32, PRESETS, QConfig, preset
from . import qfuncs
from .qdense import qact, qconv, qdense, qeinsum, qprobs, qweight, qbn_param
from .qnorm import qbatchnorm, qlayernorm, qrmsnorm

__all__ = [
    "FULL8", "E2_16", "FP32", "PRESETS", "QConfig", "preset", "qfuncs",
    "qact", "qconv", "qdense", "qeinsum", "qprobs", "qweight", "qbn_param",
    "qbatchnorm", "qlayernorm", "qrmsnorm",
]
