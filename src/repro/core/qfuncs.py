"""WAGEUBN quantization functions (paper §III-C) + fixed-point helpers.

All "grid" tensors are fp32 arrays whose values lie *exactly* on a fixed-point
grid: x = n * step with step a power of two and |n| < 2^(k-1).  Every paper
width k <= 24 fits exactly in fp32's 24-bit mantissa, so fp32 VPU arithmetic
on grid values is bit-identical to integer arithmetic (see DESIGN.md §3).

Three quantizers (paper Eq. 6/7/8/17):
  q_direct  — round onto the 2^-(k-1) grid                       (W, A, BN)
  cq        — stochastic-rounded, range-normalized, constant-scaled (G)
  sq        — shift quantization with layer-wise pow2 scale R(x)    (E)
  flag_qe2  — 8-bit + flag-bit format, two pow2 regimes             (e3)
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

Array = jax.Array

# --------------------------------------------------------------------------
# basic fixed-point helpers
# --------------------------------------------------------------------------


def d(k: int) -> float:
    """Minimum interval of a k-bit fixed-point grid (paper Eq. 8)."""
    return 2.0 ** (1 - k)


# Trace-time amax synchronization for manual tensor parallelism: inside a
# shard_map body every amax-derived scale must be GLOBAL (the tp=1 value),
# or per-rank quantization grids would diverge and sharded outputs would
# stop being exact slices of the single-device computation.  The sync is a
# scalar pmax — a float collective, but a SCALAR one, which the sharded
# wire contract explicitly permits (DESIGN.md §9/§12).
_AMAX_SYNC_AXIS: str | None = None


@contextlib.contextmanager
def amax_sync(axis: str | None):
    """Within this context, amax() pmaxes its result over `axis`.

    Applied at TRACE time: wrap the shard_map body so every quantizer scale
    computed inside agrees across model ranks.  pmax over ranks that hold
    identical replicated values (or over a size-1 axis at tp=1) is the
    identity, so the contract costs nothing when nothing is sharded.
    """
    global _AMAX_SYNC_AXIS
    from repro.kernels import ref as _kref   # core -> kernels only
    prev = _AMAX_SYNC_AXIS
    _AMAX_SYNC_AXIS = axis
    # the fused oracles run their own in-body GridQuantizer decompositions
    # (kernels/ref.py); their amax must obey the same global-scale contract
    prev_k = _kref.set_amax_sync_axis(axis)
    try:
        yield
    finally:
        _AMAX_SYNC_AXIS = prev
        _kref.set_amax_sync_axis(prev_k)


def amax(x: Array) -> Array:
    m = jnp.max(jnp.abs(x))
    if _AMAX_SYNC_AXIS is not None:
        m = jax.lax.pmax(m, _AMAX_SYNC_AXIS)
    return m


def pow2_round(m: Array) -> Array:
    """R(x) = 2^round(log2 m) for m = max|x| (paper Eq. 7); R(0) := 1."""
    safe = jnp.where(m > 0, m, 1.0)
    return jnp.where(m > 0, jnp.exp2(jnp.round(jnp.log2(safe))), 1.0)


def pow2_ceil(m: Array) -> Array:
    """Smallest power of two >= m; 1 for m <= 0."""
    safe = jnp.where(m > 0, m, 1.0)
    return jnp.where(m > 0, jnp.exp2(jnp.ceil(jnp.log2(safe))), 1.0)


def q_direct(x: Array, k: int) -> Array:
    """Direct quantization Q(x,k) = round(x*2^(k-1)) / 2^(k-1)  (Eq. 6)."""
    s = 2.0 ** (k - 1)
    return jnp.round(x * s) / s


def q_clip(x: Array, k: int) -> Array:
    """Direct quantization + saturation to (-1, 1): used for W (Eq. 10)."""
    lim = 1.0 - d(k)
    return jnp.clip(q_direct(x, k), -lim, lim)


def sq(x: Array, k: int) -> Array:
    """Shift quantization SQ(x,k) = R * clip(Q(x/R, k), +-(1-d))  (Eq. 8)."""
    r = pow2_round(amax(x))
    lim = 1.0 - d(k)
    return r * jnp.clip(q_direct(x / r, k), -lim, lim)


def q_scaled(x: Array, k: int) -> Array:
    """Scaled direct quantization for activations in the int8-native carrier.

    Identical to the paper's Q_A (Eq. 14) whenever max|x| < 1; for larger
    dynamic range a power-of-two amax factor extends coverage (this is
    exactly WAGE's layer-wise scaling, see DESIGN.md §3).  Guarantees the
    result is s * n * 2^-(k-1) with |n| <= 2^(k-1)-1 (int8-packable @ k=8).
    """
    s = jnp.maximum(pow2_ceil(amax(x)), 1.0)
    lim = 1.0 - d(k)
    return s * jnp.clip(q_direct(x / s, k), -lim, lim)


def stochastic_round(x: Array, key: Array) -> Array:
    """Sr(x) (Eq. 7): round to floor/ceil with probability by proximity."""
    f = jnp.floor(x)
    p = x - f
    u = jax.random.uniform(key, x.shape, dtype=x.dtype)
    return f + (u < p).astype(x.dtype)


def cq(x: Array, key: Array | None, dr_bits: int, k_gc: int,
       stochastic: bool = True) -> Array:
    """Constant quantization CQ (Eq. 7) for weight gradients G.

    dr = 2^(dr_bits-1) shrinks during training (learning-rate-like schedule);
    the output lives on the 2^-(k_gc-1) grid with range +-(dr-1)*2^-(k_gc-1).
    """
    r = pow2_round(amax(x))
    n = x / r
    dr = float(2 ** (dr_bits - 1))
    y = dr * n
    if stochastic:
        assert key is not None, "stochastic CQ needs a PRNG key"
        y = stochastic_round(y, key)
    else:
        y = jnp.round(y)
    y = jnp.clip(y, -dr + 1.0, dr - 1.0)
    return y / 2.0 ** (k_gc - 1)


def flag_qe2(x: Array, k: int = 8) -> Array:
    """Flag-bit error quantization (Eq. 17 / Fig. 4).

    Sc = R(x)/2^(k-1).  Two regimes sharing an int8 mantissa:
      |x| >= Sc : multiples of Sc       (flag=1)   n in +-(2^(k-1)-1)
      |x| <  Sc : multiples of Sc/2^(k-1) (flag=0)
    Note: Eq. 17 writes clip bounds +-(2^k - 1) but Fig. 4's bit layout
    (sign + 7 data bits) implies +-(2^(k-1)-1); we follow Fig. 4 so the
    mantissa is a true int8 (the MXU datapath the paper argues for).
    """
    r = pow2_round(amax(x))
    sc = r / 2.0 ** (k - 1)
    n = x / sc
    lim = 2.0 ** (k - 1) - 1.0
    big = sc * jnp.clip(jnp.round(n), -lim, lim)
    small = sc * q_direct(n, k)  # multiples of sc * 2^-(k-1)
    return jnp.where(jnp.abs(n) >= 1.0, big, small)


def quant_error(x: Array, kind: str, k_e: int) -> Array:
    """DEPRECATED shim: error-quantizer dispatch now lives in the quantizer
    registry (qtensor.py); legacy string kinds resolve via ALIASES."""
    from .qtensor import resolve_quantizer
    return resolve_quantizer(kind, k_e)(x)


# --------------------------------------------------------------------------
# straight-through estimator (paper Eq. 1)
# --------------------------------------------------------------------------


def ste(fn, x: Array) -> Array:
    """y = fn(x) in the forward pass; identity cotangent in the backward."""

    @jax.custom_vjp
    def f(t):
        return fn(t)

    f.defvjp(lambda t: (fn(t), None), lambda _, g: (g,))
    return f(x)


# --------------------------------------------------------------------------
# int payload decomposition (native mode)
# --------------------------------------------------------------------------


def dec_int8(x: Array, k: int = 8):
    """DEPRECATED shim for the "grid" quantizer: decompose a grid tensor
    into (int8 data, fp32 scalar scale).  value = data * scale, scale a
    power of two.  Exact (lossless) whenever x came from q_scaled/q_clip/sq
    at width <= k; otherwise it quantizes."""
    from .qtensor import get_quantizer
    qt = get_quantizer("grid", k).quantize(x)
    return qt.data, qt.scale


def dec_int8_fixed(x: Array, k: int = 8):
    """DEPRECATED shim for the "clip" quantizer's payload: int8 decomposition
    with the FIXED step 2^(1-k) — exact for tensors already saturated to
    (-1, 1) by q_clip (i.e. Q_W weights).  No amax pass, no scalar
    collective; the int8 copy is what FSDP gathers."""
    from .qtensor import get_quantizer
    qt = get_quantizer("clip", k).quantize(x)
    return qt.data, qt.scale


def dec_int16(x: Array, k: int = 16):
    """DEPRECATED shim: dec_int8 for 16-bit payloads (e.g. sq16 errors)."""
    from .qtensor import get_quantizer
    qt = get_quantizer("grid", k).quantize(x)
    return qt.data, qt.scale


def dec_error(x: Array, kind: str, k_e: int):
    """DEPRECATED shim: decompose an error tensor into integer planes.

    Registry-backed (see qtensor.Quantizer.planes).  Returns a list of
    (data, scale) planes:
      sq8   -> [(int8, R*2^-7)]
      sq16  -> [(int16, R*2^-15)]
      flag8 -> [(int8 hi, Sc), (int8 lo, Sc*2^-7)]  (disjoint support; this is
               the TPU realization of the paper's 9-bit flag format: storage
               and both backward dots stay int8)
    """
    from .qtensor import resolve_quantizer
    q = resolve_quantizer(kind, k_e)
    return list(q.planes(q.quantize(x)))
