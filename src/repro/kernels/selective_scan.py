"""Pallas TPU kernel: Mamba1 selective scan with VMEM-resident state.

The recurrence h_t = a_t * h_{t-1} + b_t is sequential in t, so the grid is
(batch, channel-blocks, seq-blocks) with the SEQ dimension innermost and
"arbitrary" (sequential); the (bd, N) state lives in VMEM scratch and
persists across seq-grid steps — HBM traffic is exactly one read of a/b/c
and one write of y (the jnp fallback materializes (B,S,D,N) intermediates).

This is the TPU-native answer to the paper-adjacent CUDA selective-scan
kernel: no warp shuffles — VMEM residency + sequential grid instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from ._compat import CompilerParams as _CompilerParams
from ._compat import pltpu


def _ssm_kernel(a_ref, b_ref, c_ref, o_ref, h_ref, *, bs):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def step(t, h):
        h = a_ref[0, t] * h + b_ref[0, t]                  # (bd, N)
        o_ref[0, t, :] = jnp.sum(h * c_ref[0, t][None, :], axis=-1)
        return h

    h_ref[...] = lax.fori_loop(0, bs, step, h_ref[...])


@functools.partial(jax.jit, static_argnames=("bd", "bs", "interpret"))
def selective_scan(a: jax.Array, b: jax.Array, c: jax.Array, *,
                   bd: int = 256, bs: int = 128,
                   interpret: bool = True) -> jax.Array:
    """a, b: (B, S, D, N) f32; c: (B, S, N) f32 -> y: (B, S, D) f32."""
    bsz, s, d, n = a.shape
    bd, bs = min(bd, d), min(bs, s)
    pd, ps = (-d) % bd, (-s) % bs
    if pd or ps:
        a = jnp.pad(a, ((0, 0), (0, ps), (0, pd), (0, 0)),
                    constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, ps), (0, pd), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, ps), (0, 0)))
    dd, ss = d + pd, s + ps

    grid = (bsz, dd // bd, ss // bs)
    kwargs = {}
    if not interpret and _CompilerParams is not None:
        kwargs["compiler_params"] = _CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    scratch = (pltpu.VMEM((bd, n), jnp.float32) if pltpu is not None
               else pl.MemorySpace.ANY)  # pragma: no cover
    out = pl.pallas_call(
        functools.partial(_ssm_kernel, bs=bs),
        grid=grid,
        in_specs=[pl.BlockSpec((1, bs, bd, n), lambda i, j, k: (i, k, j, 0)),
                  pl.BlockSpec((1, bs, bd, n), lambda i, j, k: (i, k, j, 0)),
                  pl.BlockSpec((1, bs, n), lambda i, j, k: (i, k, 0))],
        out_specs=pl.BlockSpec((1, bs, bd), lambda i, j, k: (i, k, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, ss, dd), jnp.float32),
        scratch_shapes=[scratch],
        interpret=interpret,
        **kwargs,
    )(a, b, c)
    return out[:, :s, :d]
