"""Shared Pallas-TPU import surface for the kernel modules.

`pltpu` is None when the TPU extras are unavailable; `CompilerParams`
resolves the class across jax versions (renamed from TPUCompilerParams),
or None when Pallas-TPU is absent entirely.
"""
from __future__ import annotations

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

CompilerParams = (getattr(pltpu, "CompilerParams", None)
                  or getattr(pltpu, "TPUCompilerParams", None)
                  if pltpu is not None else None)
