"""Pallas TPU kernel: blocked int8 x int8 -> int32 matmul (the WAGEUBN MAC).

MXU-native tiling: (bm, bk) x (bk, bn) int8 blocks feed the systolic array;
the int32 accumulator lives in VMEM scratch and persists across the K grid
dimension (sequential innermost).  Block shapes default to 128-aligned —
the MXU operates on 128x128 tiles; int8 packs 2 values/lane so bk=256 keeps
the lanes full on real hardware.

`qmatmul` optionally fuses a REQUANTIZE EPILOGUE (DESIGN.md §8): at the
final K step the int32 accumulator is rescaled by a power-of-two scalar,
rounded, clipped, and emitted as an int8 payload directly — the consumer
gets a QTensor payload on a known grid without an fp32 carrier ever being
materialized in HBM or a separate quantize pass running over it.

Validated in interpret mode against ref.qmatmul_ref / qmatmul_requant_ref
(this container is CPU-only; TPU is the compilation target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from ._compat import CompilerParams as _CompilerParams
from ._compat import pltpu


def _qmm_kernel(a_ref, b_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def _qmm_requant_kernel(a_ref, b_ref, s_ref, o_ref, acc_ref, *, lim):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        # fused epilogue: int32 accumulate -> pow2 rescale -> round -> clip,
        # emitting the int8 payload without an fp32 carrier round trip
        v = jnp.round(acc_ref[...].astype(jnp.float32) * s_ref[0, 0])
        o_ref[...] = jnp.clip(v, -lim, lim).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("lim", "bm", "bn", "bk",
                                             "interpret"))
def qmatmul(a8: jax.Array, b8: jax.Array, requant_inv: jax.Array | None = None,
            *, lim: float = 127.0, bm: int = 128, bn: int = 128,
            bk: int = 256, interpret: bool = True) -> jax.Array:
    """Blocked integer matmul, optionally with a fused requantize epilogue.

    Args:
      a8: (M, K) int8 payload.
      b8: (K, N) int8 payload.
      requant_inv: optional scalar f32 — the combined pow2 rescale
        `a_scale * b_scale / out_step`.  When given, the epilogue emits
        `clip(round(acc * requant_inv), +-lim)` as int8.
      lim: epilogue clip bound (only used with requant_inv).

    Returns:
      (M, N) int32 accumulator, or (M, N) int8 payload when requant_inv
      is given.
    """
    m, k = a8.shape
    k2, n = b8.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        a8 = jnp.pad(a8, ((0, pm), (0, pk)))
    if pk or pn:
        b8 = jnp.pad(b8, ((0, pk), (0, pn)))
    mm, nn, kk = m + pm, n + pn, k + pk

    grid = (mm // bm, nn // bn, kk // bk)
    kwargs = {}
    if not interpret and _CompilerParams is not None:
        kwargs["compiler_params"] = _CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    scratch = (pltpu.VMEM((bm, bn), jnp.int32) if pltpu is not None
               else pl.MemorySpace.ANY)  # pragma: no cover
    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
                pl.BlockSpec((bk, bn), lambda i, j, l: (l, j))]
    if requant_inv is None:
        kernel, out_dtype, operands = _qmm_kernel, jnp.int32, (a8, b8)
    else:
        kernel = functools.partial(_qmm_requant_kernel, lim=lim)
        out_dtype = jnp.int8
        in_specs.append(pl.BlockSpec((1, 1), lambda i, j, l: (0, 0)))
        operands = (a8, b8, jnp.asarray(requant_inv,
                                        jnp.float32).reshape(1, 1))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), out_dtype),
        scratch_shapes=[scratch],
        interpret=interpret,
        **kwargs,
    )(*operands)
    return out[:m, :n]
