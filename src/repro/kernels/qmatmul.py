"""Pallas TPU kernel: blocked int8 x int8 -> int32 matmul (the WAGEUBN MAC).

MXU-native tiling: (bm, bk) x (bk, bn) int8 blocks feed the systolic array;
the int32 accumulator lives in VMEM scratch and persists across the K grid
dimension (sequential innermost).  Block shapes default to 128-aligned —
the MXU operates on 128x128 tiles; int8 packs 2 values/lane so bk=256 keeps
the lanes full on real hardware.

Validated in interpret mode against ref.qmatmul_ref (this container is
CPU-only; TPU is the compilation target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _qmm_kernel(a_ref, b_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def qmatmul(a8: jax.Array, b8: jax.Array, *, bm: int = 128, bn: int = 128,
            bk: int = 256, interpret: bool = True) -> jax.Array:
    """a8: (M, K) int8; b8: (K, N) int8 -> (M, N) int32."""
    m, k = a8.shape
    k2, n = b8.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        a8 = jnp.pad(a8, ((0, pm), (0, pk)))
    if pk or pn:
        b8 = jnp.pad(b8, ((0, pk), (0, pn)))
    mm, nn, kk = m + pm, n + pn, k + pk

    grid = (mm // bm, nn // bn, kk // bk)
    kwargs = {}
    if not interpret and pltpu is not None:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    scratch = (pltpu.VMEM((bm, bn), jnp.int32) if pltpu is not None
               else pl.MemorySpace.ANY)  # pragma: no cover
    out = pl.pallas_call(
        _qmm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
                  pl.BlockSpec((bk, bn), lambda i, j, l: (l, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), jnp.int32),
        scratch_shapes=[scratch],
        interpret=interpret,
        **kwargs,
    )(a8, b8)
    return out[:m, :n]
