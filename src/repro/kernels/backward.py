"""Pallas TPU kernels: fused-prologue backward matmuls (paper Alg. 2).

WAGEUBN's backward runs both gradient dots on integer operands after the
incoming error is quantized with Q_E2 (paper e3 = Q_E2(e2)).  These kernels
fuse that quantization into the matmul PROLOGUE: each fp32 error block is
quantized to its integer payload plane(s) in VMEM registers and fed straight
to the MXU — the int8/int16 error tensor is never materialized in HBM and no
standalone quantize pass runs between Q_E2 and the matmuls.

  bwd_dgrad — da = dequant( Qe(g) ·_int b8ᵀ ): einsum('mn,kn->mk'), the
              input-error dot e4 = W^T e3 of Alg. 2 (b8 holds W's payload).
  bwd_wgrad — db = dequant( a8ᵀ ·_int Qe(g) ): einsum('mk,mn->kn'), the
              weight-gradient dot g_W = e3 x0^T of Alg. 2 (a8 holds x0).

Prologue modes (static):
  "affine" — payload = clip(round(g * inv), ±lim), one plane (SQ / grid /
             direct formats; int8 for k<=8, int16 above).
  "flag"   — the two-plane flag format (paper Eq. 17): hi multiples of Sc,
             lo multiples of Sc*2^(1-k), disjoint support, both int8.

Scalars arrive as one (1, 3) f32 plane [inv, s1, s2]: `inv` is the exact
pow2 reciprocal of the payload step, `s1`/`s2` the per-plane epilogue output
scales (plane_step * other_operand_scale — pow2 products, exact in fp32).
The quantized g block is recomputed per output tile (VPU work overlapped
with the MXU) instead of being staged through HBM.

Bit-exact vs ref.dgrad_ref / ref.wgrad_ref, which themselves reproduce the
unfused `Quantizer.quantize` + integer-einsum path (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from ._compat import CompilerParams as _CompilerParams
from ._compat import pltpu


def _payload_dtype(k: int):
    return jnp.int8 if k <= 8 else jnp.int16


def _quantize_block(g, inv, *, mode: str, k: int):
    """fp32 block -> integer payload plane(s), entirely in registers."""
    lim = 2.0 ** (k - 1) - 1.0
    dt = _payload_dtype(k)
    if mode == "affine":
        q = jnp.clip(jnp.round(g * inv), -lim, lim).astype(dt)
        return (q,)
    assert mode == "flag", mode
    n = g * inv                                  # inv = 1/Sc (pow2, exact)
    nlo = jnp.round(n * 2.0 ** (k - 1))
    # |nlo| >= 2^(k-1) collapses to the hi regime (same value there)
    isbig = (jnp.abs(n) >= 1.0) | (jnp.abs(nlo) >= 2.0 ** (k - 1))
    hi = jnp.where(isbig, jnp.clip(jnp.round(n), -lim, lim), 0.0)
    lo = jnp.where(isbig, 0.0, jnp.clip(nlo, -lim, lim))
    return (hi.astype(dt), lo.astype(dt))


def _bwd_kernel(g_ref, b_ref, s_ref, o_ref, acc1, acc2, *, mode, k, dgrad):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc1[...] = jnp.zeros_like(acc1)
        if acc2 is not None:
            acc2[...] = jnp.zeros_like(acc2)

    planes = _quantize_block(g_ref[...], s_ref[0, 0], mode=mode, k=k)
    b = b_ref[...]
    for q, acc in zip(planes, (acc1, acc2)):
        if dgrad:        # (bm, bn) x (bk, bn) -> (bm, bk), contract on n
            acc[...] += lax.dot_general(q, b, (((1,), (1,)), ((), ())),
                                        preferred_element_type=jnp.int32)
        else:            # (bm, bk) x (bm, bn) -> (bk, bn), contract on m
            acc[...] += lax.dot_general(b, q, (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o = acc1[...].astype(jnp.float32) * s_ref[0, 1]
        if acc2 is not None:
            o = o + acc2[...].astype(jnp.float32) * s_ref[0, 2]
        o_ref[...] = o


def _bwd_call(g, other, scal, out_shape, specs, out_spec, grid, *,
              mode, k, dgrad, interpret):
    two = mode == "flag"
    bo = out_spec.block_shape
    if pltpu is not None:
        scratch = [pltpu.VMEM(bo, jnp.int32),
                   pltpu.VMEM(bo, jnp.int32) if two else None]
    else:  # pragma: no cover
        scratch = [pl.MemorySpace.ANY, pl.MemorySpace.ANY if two else None]
    if not two:
        scratch = scratch[:1]

    def kernel(g_ref, b_ref, s_ref, o_ref, acc1, acc2=None):
        _bwd_kernel(g_ref, b_ref, s_ref, o_ref, acc1, acc2,
                    mode=mode, k=k, dgrad=dgrad)

    kwargs = {}
    if not interpret and _CompilerParams is not None:
        kwargs["compiler_params"] = _CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(out_shape, jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(g, other, scal.reshape(1, 3))


@functools.partial(jax.jit, static_argnames=("mode", "k", "bm", "bk", "bn",
                                             "interpret"))
def bwd_dgrad(g: jax.Array, b8: jax.Array, scal: jax.Array, *, mode: str,
              k: int = 8, bm: int = 128, bk: int = 128, bn: int = 128,
              interpret: bool = True) -> jax.Array:
    """da (M, K) = sum_planes [Qe(g) (M, N) ·_int b8 (K, N)ᵀ] * s_plane.

    g: fp32 error; b8: int8 payload of the other forward operand (W);
    scal: (3,) f32 [inv, s1, s2].  Error quantization (mode, k) happens in
    the kernel prologue; no integer error tensor ever reaches HBM.
    """
    m, n = g.shape
    kk, n2 = b8.shape
    assert n == n2
    bm, bk, bn = min(bm, m), min(bk, kk), min(bn, n)
    pm, pk, pn = (-m) % bm, (-kk) % bk, (-n) % bn
    if pm or pn:
        g = jnp.pad(g, ((0, pm), (0, pn)))
    if pk or pn:
        b8 = jnp.pad(b8, ((0, pk), (0, pn)))
    grid = ((m + pm) // bm, (kk + pk) // bk, (n + pn) // bn)
    specs = [pl.BlockSpec((bm, bn), lambda i, j, l: (i, l)),
             pl.BlockSpec((bk, bn), lambda i, j, l: (j, l)),
             pl.BlockSpec((1, 3), lambda i, j, l: (0, 0))]
    out_spec = pl.BlockSpec((bm, bk), lambda i, j, l: (i, j))
    out = _bwd_call(g, b8, scal, (m + pm, kk + pk), specs, out_spec, grid,
                    mode=mode, k=k, dgrad=True, interpret=interpret)
    return out[:m, :kk]


@functools.partial(jax.jit, static_argnames=("mode", "k", "bm", "bk", "bn",
                                             "interpret"))
def bwd_wgrad(a8: jax.Array, g: jax.Array, scal: jax.Array, *, mode: str,
              k: int = 8, bm: int = 128, bk: int = 128, bn: int = 128,
              interpret: bool = True) -> jax.Array:
    """db (K, N) = sum_planes [a8 (M, K)ᵀ ·_int Qe(g) (M, N)] * s_plane.

    a8: int8 payload of the saved forward activation x0; g: fp32 error;
    scal: (3,) f32 [inv, s1, s2].  Same fused prologue as bwd_dgrad.
    """
    m, kk = a8.shape
    m2, n = g.shape
    assert m == m2
    bm, bk, bn = min(bm, m), min(bk, kk), min(bn, n)
    pm, pk, pn = (-m) % bm, (-kk) % bk, (-n) % bn
    if pm or pn:
        g = jnp.pad(g, ((0, pm), (0, pn)))
    if pm or pk:
        a8 = jnp.pad(a8, ((0, pm), (0, pk)))
    grid = ((kk + pk) // bk, (n + pn) // bn, (m + pm) // bm)
    specs = [pl.BlockSpec((bm, bn), lambda i, j, l: (l, j)),
             pl.BlockSpec((bm, bk), lambda i, j, l: (l, i)),
             pl.BlockSpec((1, 3), lambda i, j, l: (0, 0))]
    out_spec = pl.BlockSpec((bk, bn), lambda i, j, l: (i, j))
    out = _bwd_call(g, a8, scal, (kk + pk, n + pn), specs, out_spec, grid,
                    mode=mode, k=k, dgrad=False, interpret=interpret)
    return out[:kk, :n]
