"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def qmatmul_ref(a8: jax.Array, b8: jax.Array) -> jax.Array:
    """int8 (M,K) x int8 (K,N) -> int32 (M,N)."""
    return jnp.dot(a8, b8, preferred_element_type=jnp.int32)


def quantize_ref(x: jax.Array, inv_step: jax.Array, lim: float) -> jax.Array:
    """Fused shift/direct quantize payload: clip(round(x*inv_step), +-lim)."""
    return jnp.clip(jnp.round(x * inv_step), -lim, lim).astype(jnp.int8)


def cq_stochastic_ref(x: jax.Array, bits: jax.Array, inv_step: jax.Array,
                      dr: float) -> jax.Array:
    """Stochastic-rounding constant-quantize payload (paper Eq. 7).

    bits: uint32 random bits; u = low 24 bits / 2^24 in [0,1).
    Returns int16 payload on the dr grid: clip(Sr(x*inv_step), +-(dr-1)).
    """
    v = x * inv_step
    f = jnp.floor(v)
    u = (bits & jnp.uint32(0xFFFFFF)).astype(jnp.float32) * (2.0 ** -24)
    y = f + (u < (v - f)).astype(jnp.float32)
    return jnp.clip(y, -dr + 1.0, dr - 1.0).astype(jnp.int16)


def page_gather_ref(pages: jax.Array, table: jax.Array) -> jax.Array:
    """Paged KV gather: pages (P, page, ...) + table (B, NB) -> the
    contiguous per-lane view (B, NB, page, ...), all int8 (no dequantize).
    Out-of-range ids clamp (id 0 is the trash page dead lanes point at)."""
    p = pages.shape[0]
    return pages[jnp.clip(table, 0, p - 1)]


def selective_scan_ref(a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t (h_0 = 0);  y_t = sum_n c_t[n] * h_t[:, n].

    a, b: (B, S, D, N); c: (B, S, N) -> y: (B, S, D).
    """
    def scan_one(a1, b1, c1):
        def step(h, inp):
            ai, bi, ci = inp
            h = ai * h + bi
            return h, jnp.sum(h * ci[None, :], axis=-1)
        h0 = jnp.zeros(a1.shape[1:], jnp.float32)
        _, y = jax.lax.scan(step, h0, (a1, b1, c1))
        return y
    return jax.vmap(scan_one)(a, b, c)
