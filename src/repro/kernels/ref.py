"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def qmatmul_ref(a8: jax.Array, b8: jax.Array) -> jax.Array:
    """int8 (M,K) x int8 (K,N) -> int32 (M,N)."""
    return jnp.dot(a8, b8, preferred_element_type=jnp.int32)


def qmatmul_requant_ref(a8: jax.Array, b8: jax.Array, inv: jax.Array,
                        lim: float = 127.0) -> jax.Array:
    """Fused-epilogue matmul: clip(round(int_dot * inv), +-lim) int8.

    inv is the combined pow2 rescale a_scale * b_scale / out_step — the
    epilogue of kernels/qmatmul.qmatmul(requant_inv=...).
    """
    acc = qmatmul_ref(a8, b8).astype(jnp.float32)
    return jnp.clip(jnp.round(acc * inv), -lim, lim).astype(jnp.int8)


def bwd_error_planes_ref(g: jax.Array, inv: jax.Array, *, mode: str,
                         k: int) -> tuple:
    """Q_E payload plane(s) of an error tensor — the fused-prologue formula.

    "affine": one clip(round(g*inv), +-lim) plane (int8 for k<=8 else
    int16); "flag": the two disjoint-support int8 planes of Eq. 17.
    Bit-identical to the matching Quantizer.quantize payloads.
    """
    lim = 2.0 ** (k - 1) - 1.0
    dt = jnp.int8 if k <= 8 else jnp.int16
    if mode == "affine":
        return (jnp.clip(jnp.round(g * inv), -lim, lim).astype(dt),)
    assert mode == "flag", mode
    n = g * inv
    nlo = jnp.round(n * 2.0 ** (k - 1))
    isbig = (jnp.abs(n) >= 1.0) | (jnp.abs(nlo) >= 2.0 ** (k - 1))
    hi = jnp.where(isbig, jnp.clip(jnp.round(n), -lim, lim), 0.0)
    lo = jnp.where(isbig, 0.0, jnp.clip(nlo, -lim, lim))
    return (hi.astype(dt), lo.astype(dt))


def dgrad_ref(g: jax.Array, b8: jax.Array, scal: jax.Array, *, mode: str,
              k: int) -> jax.Array:
    """da (M,K) = sum_planes einsum('mn,kn->mk', Qe(g), b8)_int32 * s_plane.

    scal: (3,) f32 [inv, s1, s2] as in kernels/backward.bwd_dgrad.
    """
    planes = bwd_error_planes_ref(g, scal[0], mode=mode, k=k)
    y = None
    for q, s in zip(planes, (scal[1], scal[2])):
        t = jnp.einsum("mn,kn->mk", q, b8,
                       preferred_element_type=jnp.int32).astype(jnp.float32) \
            * s
        y = t if y is None else y + t
    return y


def wgrad_ref(a8: jax.Array, g: jax.Array, scal: jax.Array, *, mode: str,
              k: int) -> jax.Array:
    """db (K,N) = sum_planes einsum('mk,mn->kn', a8, Qe(g))_int32 * s_plane."""
    planes = bwd_error_planes_ref(g, scal[0], mode=mode, k=k)
    y = None
    for q, s in zip(planes, (scal[1], scal[2])):
        t = jnp.einsum("mk,mn->kn", a8, q,
                       preferred_element_type=jnp.int32).astype(jnp.float32) \
            * s
        y = t if y is None else y + t
    return y


def _q_direct_ref(x, k: int):
    s = 2.0 ** (k - 1)
    return jnp.round(x * s) / s


def ubn_norm_ref(x: jax.Array, gamma: jax.Array, beta: jax.Array | None, *,
                 kind: str, k_mu: int, k_sigma: int, k_bn: int, k_gamma: int,
                 k_beta: int, eps: float) -> jax.Array:
    """Fused-UBN oracle: stats + normalize + the five direct quantizers.

    x: (M, N); stats over N per row ("rms"/"layer") or over M per column
    ("batch").  Bit-identical to the sim-mode core/qnorm.py composition.
    """
    axis = 0 if kind == "batch" else -1
    if kind == "rms":
        sigma = jnp.sqrt(jnp.mean(jnp.square(x), axis=axis, keepdims=True))
        xhat = x / (_q_direct_ref(sigma, k_sigma) + eps)
    else:
        mu = jnp.mean(x, axis=axis, keepdims=True)
        var = jnp.mean(jnp.square(x), axis=axis, keepdims=True) \
            - jnp.square(mu)
        sigma = jnp.sqrt(jnp.maximum(var, 0.0))
        xhat = (x - _q_direct_ref(mu, k_mu)) \
            / (_q_direct_ref(sigma, k_sigma) + eps)
    xhat = _q_direct_ref(xhat, k_bn)
    y = _q_direct_ref(gamma.reshape(1, -1), k_gamma) * xhat
    if kind != "rms":
        y = y + _q_direct_ref(beta.reshape(1, -1), k_beta)
    return y


def quantize_ref(x: jax.Array, inv_step: jax.Array, lim: float) -> jax.Array:
    """Fused shift/direct quantize payload: clip(round(x*inv_step), +-lim)."""
    return jnp.clip(jnp.round(x * inv_step), -lim, lim).astype(jnp.int8)


def cq_stochastic_ref(x: jax.Array, bits: jax.Array, inv_step: jax.Array,
                      dr: float) -> jax.Array:
    """Stochastic-rounding constant-quantize payload (paper Eq. 7).

    bits: uint32 random bits; u = low 24 bits / 2^24 in [0,1).
    Returns int16 payload on the dr grid: clip(Sr(x*inv_step), +-(dr-1)).
    """
    v = x * inv_step
    f = jnp.floor(v)
    u = (bits & jnp.uint32(0xFFFFFF)).astype(jnp.float32) * (2.0 ** -24)
    y = f + (u < (v - f)).astype(jnp.float32)
    return jnp.clip(y, -dr + 1.0, dr - 1.0).astype(jnp.int16)


def page_gather_ref(pages: jax.Array, table: jax.Array) -> jax.Array:
    """Paged KV gather: pages (P, page, ...) + table (B, NB) -> the
    contiguous per-lane view (B, NB, page, ...), all int8 (no dequantize).
    Out-of-range ids clamp (id 0 is the trash page dead lanes point at)."""
    p = pages.shape[0]
    return pages[jnp.clip(table, 0, p - 1)]


def selective_scan_ref(a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t (h_0 = 0);  y_t = sum_n c_t[n] * h_t[:, n].

    a, b: (B, S, D, N); c: (B, S, N) -> y: (B, S, D).
    """
    def scan_one(a1, b1, c1):
        def step(h, inp):
            ai, bi, ci = inp
            h = ai * h + bi
            return h, jnp.sum(h * ci[None, :], axis=-1)
        h0 = jnp.zeros(a1.shape[1:], jnp.float32)
        _, y = jax.lax.scan(step, h0, (a1, b1, c1))
        return y
    return jax.vmap(scan_one)(a, b, c)
