"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def qmatmul_ref(a8: jax.Array, b8: jax.Array) -> jax.Array:
    """int8 (M,K) x int8 (K,N) -> int32 (M,N)."""
    return jnp.dot(a8, b8, preferred_element_type=jnp.int32)


def qmatmul_requant_ref(a8: jax.Array, b8: jax.Array, inv: jax.Array,
                        lim: float = 127.0) -> jax.Array:
    """Fused-epilogue matmul: clip(round(int_dot * inv), +-lim) int8.

    inv is the combined pow2 rescale a_scale * b_scale / out_step — the
    epilogue of kernels/qmatmul.qmatmul(requant_inv=...).
    """
    acc = qmatmul_ref(a8, b8).astype(jnp.float32)
    return jnp.clip(jnp.round(acc * inv), -lim, lim).astype(jnp.int8)


def bwd_error_planes_ref(g: jax.Array, inv: jax.Array, *, mode: str,
                         k: int) -> tuple:
    """Q_E payload plane(s) of an error tensor — the fused-prologue formula.

    "affine": one clip(round(g*inv), +-lim) plane (int8 for k<=8 else
    int16); "flag": the two disjoint-support int8 planes of Eq. 17.
    Bit-identical to the matching Quantizer.quantize payloads.
    """
    lim = 2.0 ** (k - 1) - 1.0
    dt = jnp.int8 if k <= 8 else jnp.int16
    if mode == "affine":
        return (jnp.clip(jnp.round(g * inv), -lim, lim).astype(dt),)
    assert mode == "flag", mode
    n = g * inv
    nlo = jnp.round(n * 2.0 ** (k - 1))
    isbig = (jnp.abs(n) >= 1.0) | (jnp.abs(nlo) >= 2.0 ** (k - 1))
    hi = jnp.where(isbig, jnp.clip(jnp.round(n), -lim, lim), 0.0)
    lo = jnp.where(isbig, 0.0, jnp.clip(nlo, -lim, lim))
    return (hi.astype(dt), lo.astype(dt))


def dgrad_ref(g: jax.Array, b8: jax.Array, scal: jax.Array, *, mode: str,
              k: int) -> jax.Array:
    """da (M,K) = sum_planes einsum('mn,kn->mk', Qe(g), b8)_int32 * s_plane.

    scal: (3,) f32 [inv, s1, s2] as in kernels/backward.bwd_dgrad.
    """
    planes = bwd_error_planes_ref(g, scal[0], mode=mode, k=k)
    y = None
    for q, s in zip(planes, (scal[1], scal[2])):
        t = jnp.einsum("mn,kn->mk", q, b8,
                       preferred_element_type=jnp.int32).astype(jnp.float32) \
            * s
        y = t if y is None else y + t
    return y


def wgrad_ref(a8: jax.Array, g: jax.Array, scal: jax.Array, *, mode: str,
              k: int) -> jax.Array:
    """db (K,N) = sum_planes einsum('mk,mn->kn', a8, Qe(g))_int32 * s_plane."""
    planes = bwd_error_planes_ref(g, scal[0], mode=mode, k=k)
    y = None
    for q, s in zip(planes, (scal[1], scal[2])):
        t = jnp.einsum("mk,mn->kn", a8, q,
                       preferred_element_type=jnp.int32).astype(jnp.float32) \
            * s
        y = t if y is None else y + t
    return y


def _q_direct_ref(x, k: int):
    s = 2.0 ** (k - 1)
    return jnp.round(x * s) / s


def ubn_norm_ref(x: jax.Array, gamma: jax.Array, beta: jax.Array | None, *,
                 kind: str, k_mu: int, k_sigma: int, k_bn: int, k_gamma: int,
                 k_beta: int, eps: float) -> jax.Array:
    """Fused-UBN oracle: stats + normalize + the five direct quantizers.

    x: (M, N); stats over N per row ("rms"/"layer") or over M per column
    ("batch").  Bit-identical to the sim-mode core/qnorm.py composition.
    """
    axis = 0 if kind == "batch" else -1
    if kind == "rms":
        sigma = jnp.sqrt(jnp.mean(jnp.square(x), axis=axis, keepdims=True))
        xhat = x / (_q_direct_ref(sigma, k_sigma) + eps)
    else:
        mu = jnp.mean(x, axis=axis, keepdims=True)
        var = jnp.mean(jnp.square(x), axis=axis, keepdims=True) \
            - jnp.square(mu)
        sigma = jnp.sqrt(jnp.maximum(var, 0.0))
        xhat = (x - _q_direct_ref(mu, k_mu)) \
            / (_q_direct_ref(sigma, k_sigma) + eps)
    xhat = _q_direct_ref(xhat, k_bn)
    y = _q_direct_ref(gamma.reshape(1, -1), k_gamma) * xhat
    if kind != "rms":
        y = y + _q_direct_ref(beta.reshape(1, -1), k_beta)
    return y


def quantize_ref(x: jax.Array, inv_step: jax.Array, lim: float) -> jax.Array:
    """Fused shift/direct quantize payload: clip(round(x*inv_step), +-lim)."""
    return jnp.clip(jnp.round(x * inv_step), -lim, lim).astype(jnp.int8)


def cq_stochastic_ref(x: jax.Array, bits: jax.Array, inv_step: jax.Array,
                      dr: float) -> jax.Array:
    """Stochastic-rounding constant-quantize payload (paper Eq. 7).

    bits: uint32 random bits; u = low 24 bits / 2^24 in [0,1).
    Returns int16 payload on the dr grid: clip(Sr(x*inv_step), +-(dr-1)).
    """
    v = x * inv_step
    f = jnp.floor(v)
    u = (bits & jnp.uint32(0xFFFFFF)).astype(jnp.float32) * (2.0 ** -24)
    y = f + (u < (v - f)).astype(jnp.float32)
    return jnp.clip(y, -dr + 1.0, dr - 1.0).astype(jnp.int16)


def page_gather_ref(pages: jax.Array, table: jax.Array) -> jax.Array:
    """Paged KV gather: pages (P, page, ...) + table (B, NB) -> the
    contiguous per-lane view (B, NB, page, ...), all int8 (no dequantize).
    Out-of-range ids clamp (id 0 is the trash page dead lanes point at)."""
    p = pages.shape[0]
    return pages[jnp.clip(table, 0, p - 1)]


NEG_INF = -1e9   # the attention mask fill (models/layers.py uses the same)


def _pow2_ceil(m):
    """Smallest power of two >= m; 1 for m <= 0 (== core.qfuncs.pow2_ceil,
    duplicated here because kernels/ must not import core/)."""
    safe = jnp.where(m > 0, m, 1.0)
    return jnp.where(m > 0, jnp.exp2(jnp.ceil(jnp.log2(safe))), 1.0)


# Mirror of core.qfuncs._AMAX_SYNC_AXIS, set by qfuncs.amax_sync (the
# import direction is core -> kernels, so the context pushes the axis down
# here rather than kernels reading it from core).  Inside a manual-TP
# shard_map body the oracles' in-kernel GridQuantizer decompositions span
# only the local head shard; without the pmax their pow2_ceil(amax) scale
# can land one power of two away from the tp=1 value whenever the global
# amax lives on another rank's heads — a rare, input-dependent bit
# divergence (the §12 exactness contract requires every scale be global).
_AMAX_SYNC_AXIS: str | None = None


def set_amax_sync_axis(axis):
    """Set the trace-time amax pmax axis; returns the previous value."""
    global _AMAX_SYNC_AXIS
    prev = _AMAX_SYNC_AXIS
    _AMAX_SYNC_AXIS = axis
    return prev


def _grid_decompose(x: jax.Array, k: int):
    """GridQuantizer decomposition (core/qtensor.py): pow2_ceil(amax) scale
    with a 2^-24 floor, payload clip(round(x/step), +-(2^(k-1)-1)) int8.
    Returns (payload, step).  Bit-identical to _decompose + quantize_ref.
    Under amax_sync the amax is pmax'ed over the model axis — same scalar
    collective contract as core.qfuncs.amax."""
    m = jnp.max(jnp.abs(x))
    if _AMAX_SYNC_AXIS is not None:
        m = jax.lax.pmax(m, _AMAX_SYNC_AXIS)
    s = jnp.maximum(_pow2_ceil(m), 2.0 ** -24)
    step = s * 2.0 ** (1 - k)
    lim = 2.0 ** (k - 1) - 1.0
    p8 = jnp.clip(jnp.round(x * (jnp.float32(1.0) / step)), -lim,
                  lim).astype(jnp.int8)
    return p8, step


def paged_attention_ref(q8: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        table: jax.Array, q_pos: jax.Array, t_valid,
                        q_scale, k_scale, v_scale, *, sm_scale: float,
                        k_a: int = 8) -> jax.Array:
    """Fused paged decode attention oracle — operation-for-operation the
    page_gather + decode_attention composition (models/layers.py), so the
    fused op is bit-exact against the unfused path by construction.

    q8: (B, H, dh) int8 query payload (one decode token per lane);
    k_pages/v_pages: (P, page, KV, dh) int8 arenas; table: (B, NB) page
    ids (0 = trash page); q_pos: (B,) int32 per-lane positions; t_valid:
    scalar upper bound on valid positions; q/k/v_scale: pow2 payload
    scales; sm_scale: 1/sqrt(dh).

    Returns (B, H, dh) f32 — the pre-Q_A attention output.  The single
    probability amax (GridQuantizer batch-global scale) lives here as a
    scalar reduction, exactly where the unfused qeinsum puts it.
    """
    p = k_pages.shape[0]
    page, kv, dh = k_pages.shape[1:]
    b, nb = table.shape
    g = q8.shape[1] // kv
    tb = jnp.clip(table, 0, p - 1)
    k8 = k_pages[tb].reshape(b, nb * page, kv, dh)
    v8 = v_pages[tb].reshape(b, nb * page, kv, dh)
    qr = q8.reshape(b, 1, kv, g, dh)
    sc = jnp.einsum("bskgd,btkd->bskgt", qr, k8,
                    preferred_element_type=jnp.int32).astype(jnp.float32) \
        * (q_scale * k_scale)
    sc = sc * sm_scale
    t = nb * page
    kp = jnp.arange(t)
    mask = (kp[None, :] <= q_pos[:, None]) & (kp[None, :] < t_valid)
    sc = jnp.where(mask[:, None, None, None, :], sc, NEG_INF)
    m = jnp.max(sc, axis=-1, keepdims=True)
    pex = jnp.exp(sc - m)
    pn = pex / jnp.sum(pex, axis=-1, keepdims=True)
    s_ = 2.0 ** (k_a - 1)
    pg = jnp.round(pn * s_) / s_                       # qprobs (Q_A grid)
    p8, step = _grid_decompose(pg, k_a)                # ONE batch-global amax
    out = jnp.einsum("bskgt,btkd->bskgd", p8, v8,
                     preferred_element_type=jnp.int32).astype(jnp.float32) \
        * (step * v_scale)
    return out.reshape(b, kv * g, dh)


def flash_attention_ref(q8: jax.Array, k8: jax.Array, v8: jax.Array,
                        q_pos: jax.Array, k_pos: jax.Array,
                        k_valid: jax.Array, q_scale, k_scale, v_scale, *,
                        causal: bool, sm_scale: float, q_chunk: int,
                        kv_chunk: int, k_a: int = 8) -> jax.Array:
    """Tiled online-softmax attention oracle on int8 payload operands.

    Chunk-for-chunk the pure-JAX chunked_attention composition
    (models/layers.py): scores and p·v run as integer dots with per-chunk
    GridQuantizer decompositions (amax over the full (B, chunk, heads)
    block — including the saturate-at-amax-pow2 corner), probabilities
    quantize UNNORMALIZED onto the Q_A grid per kv step, and the online
    rescale (m/l/alpha) runs in f32.  Bit-identical to the unfused path.

    q8: (B, S, H, dh) int8; k8/v8: (B, T, KV, dh) int8 — all pre-padded to
    chunk multiples (payload zeros); q_pos: (S,), k_pos: (T,) int32;
    k_valid: (T,) mask of real (non-padded) kv slots; scales: pow2 payload
    scales.  Returns (B, S, H, dh) f32 (padded rows included; the caller
    slices and applies Q_A).  Control flow (lax.scan over kv chunks,
    lax.map over q blocks) is structured exactly like the unfused body so
    the two compile to the same program shape.
    """
    b, s, h, dh = q8.shape
    t, kv = k8.shape[1], k8.shape[2]
    g = h // kv
    nq, nk = s // q_chunk, t // kv_chunk
    qf = (q8.astype(jnp.float32) * q_scale).reshape(b, s, kv, g, dh)
    kf = k8.astype(jnp.float32) * k_scale
    vf = v8.astype(jnp.float32) * v_scale
    s_ = 2.0 ** (k_a - 1)
    kc = kf.reshape(b, nk, kv_chunk, kv, dh).transpose(1, 0, 2, 3, 4)
    vc = vf.reshape(b, nk, kv_chunk, kv, dh).transpose(1, 0, 2, 3, 4)
    kpc = k_pos.reshape(nk, kv_chunk)
    kvc = (k_valid != 0).reshape(nk, kv_chunk)

    def q_block(qi, qp):
        qi8, q_step = _grid_decompose(qi, k_a)

        def kv_step(carry, inp):
            m, l, o = carry
            ki, vi, kp, kval = inp
            ki8, k_step = _grid_decompose(ki, k_a)
            sc = jnp.einsum("bskgd,btkd->bskgt", qi8, ki8,
                            preferred_element_type=jnp.int32) \
                .astype(jnp.float32) * (q_step * k_step)
            sc = sc * sm_scale
            mask = kval[None, :] if not causal else (
                (qp[:, None] >= kp[None, :]) & kval[None, :])
            sc = jnp.where(mask[None, :, None, None, :], sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            p = jnp.round(p * s_) / s_             # qprobs, unnormalized
            pi8, p_step = _grid_decompose(p, k_a)
            vi8, v_step = _grid_decompose(vi, k_a)
            pv = jnp.einsum("bskgt,btkd->bskgd", pi8, vi8,
                            preferred_element_type=jnp.int32) \
                .astype(jnp.float32) * (p_step * v_step)
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            o = o * alpha[..., None] + pv
            return (m_new, l, o), None

        m0 = jnp.full(qi.shape[:-1], NEG_INF, jnp.float32)
        l0 = jnp.zeros(qi.shape[:-1], jnp.float32)
        o0 = jnp.zeros(qi.shape, jnp.float32)
        (m, l, o), _ = jax.lax.scan(kv_step, (m0, l0, o0),
                                    (kc, vc, kpc, kvc))
        return o / jnp.maximum(l, 1e-9)[..., None]

    qb = qf.reshape(b, nq, q_chunk, kv, g, dh).transpose(1, 0, 2, 3, 4, 5)
    qpb = q_pos.reshape(nq, q_chunk)
    out = jax.lax.map(lambda args: q_block(*args), (qb, qpb))
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, dh)


def selective_scan_ref(a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t (h_0 = 0);  y_t = sum_n c_t[n] * h_t[:, n].

    a, b: (B, S, D, N); c: (B, S, N) -> y: (B, S, D).
    """
    def scan_one(a1, b1, c1):
        def step(h, inp):
            ai, bi, ci = inp
            h = ai * h + bi
            return h, jnp.sum(h * ci[None, :], axis=-1)
        h0 = jnp.zeros(a1.shape[1:], jnp.float32)
        _, y = jax.lax.scan(step, h0, (a1, b1, c1))
        return y
    return jax.vmap(scan_one)(a, b, c)
