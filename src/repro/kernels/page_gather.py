"""Pallas TPU kernel: paged KV-cache gather (the serving-engine hot loop).

page_gather — copy the physical int8 pages named by a per-lane page table
into a contiguous per-lane view: pages (P, page, D) + table (B, NB) ->
(B, NB, page, D).  The whole move stays int8 — the gathered view is the
payload the decode attention matmuls consume directly (no dequantize).

The page id for each (lane, block) grid cell is data-dependent, so the
input block index comes from a scalar-prefetch operand
(pltpu.PrefetchScalarGridSpec): the table is available before the kernel
body runs and drives the HBM->VMEM DMA of exactly one page per cell.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(table_ref, pages_ref, out_ref):
    # pages_ref already holds the page selected by the index_map below
    out_ref[0, 0] = pages_ref[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def page_gather(pages: jax.Array, table: jax.Array, *,
                interpret: bool = True) -> jax.Array:
    """pages: (P, page, D) int8; table: (B, NB) int32 -> (B, NB, page, D).

    Out-of-range page ids are clamped (id 0 is the engine's trash page, so
    dead lanes gather garbage that the attention mask never reads).
    """
    p, page, d = pages.shape
    b, nb = table.shape
    table = jnp.clip(table, 0, p - 1).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, nb),
        in_specs=[pl.BlockSpec((1, page, d),
                               lambda i, j, tref: (tref[i, j], 0, 0))],
        out_specs=pl.BlockSpec((1, 1, page, d),
                               lambda i, j, tref: (i, j, 0, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nb, page, d), pages.dtype),
        interpret=interpret,
    )(table, pages)
