"""Block-shape autotuner for the dispatched Pallas ops (DESIGN.md §13).

The kernels' tile sizes are performance knobs, not numerics knobs: every
candidate below changes only how the work is blocked over the grid (or how
the TPU pipeliner schedules the grid), never which elements share an amax
or a rounding step.  That is the autotuner's safety contract — a tuned
entry can change wall-clock but CANNOT change a single output bit, and
tests/test_autotune.py proves it per op against the default tiles.
Knobs that ARE numerics (flash attention's q_chunk/kv_chunk set the
per-chunk GridQuantizer amax granularity) are deliberately not tunable.

Cache design (modeled on XLA's compilation cache):

  key   = sha256 over {schema, op, shape/dtype signature, backend,
          jax.__version__} — any of those changing means the old winner is
          unvalidated, so it simply misses and defaults apply.
  entry = one JSON file per key under $REPRO_AUTOTUNE_DIR (default
          ~/.cache/repro-autotune): {"schema", "op", "sig", "backend",
          "jax", "tiles", "us"}.
  miss / corrupt / truncated file -> the op's current defaults, silently:
  the tuner is an accelerator, never a dependency.

`warm()` (also `python -m repro.kernels.autotune [--fast]`) sweeps
representative shapes for every tunable op and persists the winners, so a
fleet can pre-bake the cache exactly like it pre-bakes XLA's.
"""
from __future__ import annotations

import hashlib
import json
import os
import time

import jax

SCHEMA = 1

# numerics-neutral candidate grids per op.  First entry == the dispatch
# defaults, so a sweep can never do worse than shipping behavior.  "ds" is
# the pallas dimension_semantics hint (grid scheduling, not blocking).
CANDIDATES = {
    "qmatmul": (
        {"bm": 128, "bn": 128, "bk": 256},
        {"bm": 256, "bn": 128, "bk": 256},
        {"bm": 128, "bn": 256, "bk": 256},
        {"bm": 128, "bn": 128, "bk": 512},
        {"bm": 64, "bn": 128, "bk": 256},
        {"bm": 256, "bn": 256, "bk": 128},
    ),
    "dgrad": (
        {"bm": 128, "bk": 128, "bn": 128},
        {"bm": 256, "bk": 128, "bn": 128},
        {"bm": 128, "bk": 256, "bn": 128},
        {"bm": 64, "bk": 128, "bn": 256},
    ),
    "wgrad": (
        {"bm": 128, "bk": 128, "bn": 128},
        {"bm": 256, "bk": 128, "bn": 128},
        {"bm": 128, "bk": 256, "bn": 128},
        {"bm": 64, "bk": 128, "bn": 256},
    ),
    "ubn_norm": (
        {"bt": 256}, {"bt": 128}, {"bt": 64}, {"bt": 32},
    ),
    "flash_attention": (
        {"ds": ("parallel", "arbitrary")},
        {"ds": ("arbitrary", "arbitrary")},
    ),
    "paged_attention": (
        {"ds": ("parallel", "arbitrary")},
        {"ds": ("arbitrary", "arbitrary")},
    ),
}

# in-memory memo: key -> tiles dict or None (negative lookups memoize too —
# a missing cache must not cost a stat() per dispatched call)
_MEMO: dict = {}


def cache_dir() -> str:
    return os.path.expanduser(
        os.environ.get("REPRO_AUTOTUNE_DIR", "~/.cache/repro-autotune"))


def _canon(v):
    """JSON-stable form: tuples (shapes, ds) become lists recursively."""
    if isinstance(v, (tuple, list)):
        return [_canon(x) for x in v]
    if isinstance(v, dict):
        return {k: _canon(v[k]) for k in sorted(v)}
    return v


def cache_key(op: str, sig) -> str:
    """sha256 over everything that invalidates a tuned entry (the XLA
    compilation-cache recipe): schema, op, the caller's shape/dtype/static
    signature, the backend the timing ran on, and the jax version."""
    blob = json.dumps({"schema": SCHEMA, "op": op, "sig": _canon(sig),
                       "backend": jax.default_backend(),
                       "jax": jax.__version__}, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()


def _entry_path(key: str) -> str:
    return os.path.join(cache_dir(), key + ".json")


def _detuple(tiles: dict) -> dict:
    """JSON round-trips tuples as lists; restore tuple-typed knobs."""
    out = dict(tiles)
    if "ds" in out:
        out["ds"] = tuple(out["ds"])
    return out


def lookup(op: str, sig):
    """Tuned tiles for (op, sig) or None.  Corrupt, truncated, or
    wrong-schema entries behave exactly like a miss."""
    key = cache_key(op, sig)
    if key in _MEMO:
        return _MEMO[key]
    tiles = None
    try:
        with open(_entry_path(key)) as f:
            entry = json.load(f)
        if (entry.get("schema") == SCHEMA and entry.get("op") == op
                and isinstance(entry.get("tiles"), dict)):
            tiles = _detuple(entry["tiles"])
    except (OSError, ValueError):
        tiles = None
    _MEMO[key] = tiles
    return tiles


def store(op: str, sig, tiles: dict, us: float) -> str:
    """Persist a winner (atomic write: rename over a temp file so a killed
    process can only ever leave a whole entry or none)."""
    key = cache_key(op, sig)
    os.makedirs(cache_dir(), exist_ok=True)
    path = _entry_path(key)
    tmp = path + f".tmp.{os.getpid()}"
    entry = {"schema": SCHEMA, "op": op, "sig": _canon(sig),
             "backend": jax.default_backend(), "jax": jax.__version__,
             "tiles": _canon(tiles), "us": float(us)}
    with open(tmp, "w") as f:
        json.dump(entry, f, indent=1)
    os.replace(tmp, path)
    _MEMO[key] = _detuple(dict(tiles))
    return key


def clear_memo() -> None:
    """Drop the in-memory memo (tests mutate the disk cache under us)."""
    _MEMO.clear()


def tiles_for(op: str, sig, defaults: dict) -> dict:
    """The dispatch-time query: tuned tiles when a valid cache entry
    exists, else `defaults` verbatim.  Only knobs the caller's defaults
    name are taken from the entry — a stale entry with extra keys cannot
    inject unknown kwargs into a kernel call."""
    tuned = lookup(op, sig)
    if not tuned:
        return defaults
    return {**defaults, **{k: v for k, v in tuned.items() if k in defaults}}


def tune(op: str, sig, call, candidates=None, reps: int = 3) -> dict:
    """Time `call(tiles)` over the candidate grid and persist the winner.

    `call` must run the op end to end and return a jax array (or pytree);
    each candidate gets one untimed compile/warmup call, then `reps` timed
    calls — the median is the score.  Candidates that fail to compile or
    run are skipped (a tile too large for a shape is a candidate's
    problem, not the tuner's).
    """
    best, best_us = None, float("inf")
    for tiles in (candidates or CANDIDATES[op]):
        try:
            jax.block_until_ready(call(tiles))        # compile + warm
            ts = []
            for _ in range(reps):
                t0 = time.perf_counter()
                jax.block_until_ready(call(tiles))
                ts.append(time.perf_counter() - t0)
            us = sorted(ts)[len(ts) // 2] * 1e6
        except Exception:
            continue
        if us < best_us:
            best, best_us = tiles, us
    if best is None:
        raise RuntimeError(f"autotune: no candidate ran for op={op}")
    store(op, sig, best, best_us)
    return best


def entries() -> list:
    """All valid cache entries for the CURRENT backend+jax version, as
    dicts (sorted by op) — the report/banner surface."""
    out = []
    try:
        names = sorted(os.listdir(cache_dir()))
    except OSError:
        return out
    for name in names:
        if not name.endswith(".json"):
            continue
        try:
            with open(os.path.join(cache_dir(), name)) as f:
                e = json.load(f)
        except (OSError, ValueError):
            continue
        if (e.get("schema") == SCHEMA
                and e.get("backend") == jax.default_backend()
                and e.get("jax") == jax.__version__
                and isinstance(e.get("tiles"), dict)):
            out.append(e)
    return sorted(out, key=lambda e: (e["op"], json.dumps(e["sig"])))


def _fmt_tiles(tiles: dict) -> str:
    def one(v):
        return "x".join(map(str, v)) if isinstance(v, (list, tuple)) else v
    return "/".join(f"{k}={one(v)}" for k, v in sorted(tiles.items()))


def banner_fragment() -> str:
    """`tiles=...` summary for the [kernels] banner: per-op winning tiles
    of the warmed cache, or `defaults` when nothing is tuned."""
    es = entries()
    if not es:
        return "tiles=defaults"
    per_op = {}
    for e in es:
        per_op.setdefault(e["op"], e["tiles"])
    return "tiles=" + ",".join(
        f"{op}:{_fmt_tiles(t)}" for op, t in sorted(per_op.items()))


def report_rows() -> list:
    """(op, sig, tiles, us) rows for launch/report.py --section kernels."""
    return [(e["op"], json.dumps(e["sig"]), _fmt_tiles(e["tiles"]),
             e.get("us", 0.0)) for e in entries()]


# --------------------------------------------------------------------------
# cache warming (representative shapes per op)
# --------------------------------------------------------------------------


def warm(fast: bool = False, verbose: bool = True) -> dict:
    """Sweep representative shapes for every tunable op and persist the
    winners.  On CPU the kernels run in interpret mode (the cache key's
    backend field keeps those timings from ever leaking onto a TPU); on a
    TPU backend the same sweep times compiled kernels.

    fast=True trims each op to its first two candidates — the CI
    bench-smoke lane uses this to prove the full path (sweep -> disk ->
    reload) in seconds.
    """
    import jax.numpy as jnp
    import numpy as np

    from .backward import bwd_dgrad, bwd_wgrad
    from .qmatmul import qmatmul
    from .ubn import ubn_norm

    interp = jax.default_backend() != "tpu"
    rng = np.random.default_rng(0)
    m, k, n = (128, 128, 128) if fast else (256, 512, 256)
    a8 = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    b8 = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    g = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    gamma = jnp.ones((n,), jnp.float32)
    scal = jnp.asarray([128.0, 2.0 ** -7, 0.0], jnp.float32)
    reps = 1 if fast else 3

    jobs = {
        "qmatmul": ((a8.shape, "int8", b8.shape, "int8", False),
                    lambda t: qmatmul(a8, b8, interpret=interp, **t)),
        "dgrad": ((g.shape, b8.shape, "affine", 8),
                  lambda t: bwd_dgrad(g, b8, scal, mode="affine", k=8,
                                      interpret=interp, **t)),
        "wgrad": ((a8.shape, g.shape, "affine", 8),
                  lambda t: bwd_wgrad(a8, g, scal, mode="affine", k=8,
                                      interpret=interp, **t)),
        "ubn_norm": ((x.shape, "rms"),
                     lambda t: ubn_norm(x, gamma, None, kind="rms",
                                        interpret=interp, **t)),
    }
    won = {}
    for op, (sig, call) in jobs.items():
        cands = CANDIDATES[op][:2] if fast else CANDIDATES[op]
        won[op] = tune(op, sig, call, candidates=cands, reps=reps)
        if verbose:
            print(f"[autotune] {op} sig={sig} -> {_fmt_tiles(won[op])}")
    # attention ops tune only the scheduling hint; on CPU both candidates
    # lower identically under interpret mode, so warming them pins the
    # default hint into the cache (cheap, and exercises the ds plumbing)
    for op in ("flash_attention", "paged_attention"):
        sig = ("warm", "default")
        store(op, sig, CANDIDATES[op][0], 0.0)
        won[op] = CANDIDATES[op][0]
        if verbose:
            print(f"[autotune] {op} sig={sig} -> {_fmt_tiles(won[op])}")
    return won


def main(argv=None):
    import argparse

    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--fast", action="store_true",
                   help="first-two-candidates sweep (CI smoke)")
    p.add_argument("--report", action="store_true",
                   help="print the cached entries and exit")
    args = p.parse_args(argv)
    if args.report:
        for op, sig, tiles, us in report_rows():
            print(f"[autotune] {op} {tiles} ({us:.1f}us) sig={sig}")
        return
    warm(fast=args.fast)
    print(f"[autotune] cache dir {cache_dir()} "
          f"({len(entries())} entries for backend="
          f"{jax.default_backend()})")


if __name__ == "__main__":
    main()
