"""Pallas TPU kernels: fused int8 attention over the paged KV cache.

Two kernel families share one epilogue contract (integer q·k and p·v dots,
Q_A-grid probabilities, pow2 rescales in-register):

paged_attention — the serving DECODE hot loop.  The per-lane page table is
a scalar-prefetch operand (same contract as kernels/page_gather.py): each
(lane, block) grid cell DMAs exactly one int8 K/V page HBM->VMEM, so the
gathered contiguous KV view never exists in HBM.  Two streaming passes:

  pass 1  streams K pages, builds the masked score row in VMEM scratch,
          emits the per-row softmax max `m` and sum `l` (B, H) — int32
          q·k accumulation, one pow2 rescale, fp32 VPU softmax stats.
  glue    the SINGLE probability amax: the unfused path's GridQuantizer
          takes one batch-global amax over the normalized probabilities;
          max(p) per row is exactly 1/l, so the scale is a scalar
          reduction over `l` — it lives BETWEEN the passes, matching the
          training kernels' contract that scale reductions stay outside
          kernel bodies (DESIGN.md §8).
  pass 2  streams K and V pages, recomputes scores in-register, quantizes
          probabilities onto the Q_A grid at the glued scale, and
          accumulates p·v in int32 VMEM scratch; only the (B, H, dh)
          output is written.

flash_attention — the PREFILL/TRAINING tiled online-softmax kernel.  Each
(q-tile, kv-tile) grid cell re-derives the per-chunk GridQuantizer
decompositions in-register (amax over the full batch block — tiles carry
the whole batch so the chunk amaxes match the unfused qeinsum bit-for-bit,
including the saturate-at-pow2-amax corner), quantizes unnormalized
probabilities per kv step, and keeps m/l/o in VMEM scratch across the
sequential kv grid dimension.

Both are bit-exact against kernels/ref.py oracles, which are themselves
operation-for-operation the unfused model compositions — validated in
interpret mode (this container is CPU-only; TPU is the compile target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._compat import CompilerParams as _CompilerParams
from ._compat import pltpu
# the same decomposition formulas run in-register here and in the XLA
# oracles — one definition keeps the kernel-vs-oracle bit-exactness
# contract in one place
from .ref import NEG_INF, _grid_decompose, _pow2_ceil


# --------------------------------------------------------------------------
# paged decode attention
# --------------------------------------------------------------------------


def _page_scores(q, kpage, kq, sm_scale, qpos, tval, j, page, kv, g):
    """Masked f32 score block (H, page) for one lane x one page: integer
    q·k per kv head, pow2 rescale, softmax scale, position mask."""
    rows = []
    for h in range(kv):
        acc = jnp.dot(q[h * g:(h + 1) * g], kpage[:, h, :].T,
                      preferred_element_type=jnp.int32)      # (g, page)
        rows.append(acc)
    sc = jnp.concatenate(rows, axis=0).astype(jnp.float32) * kq * sm_scale
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    ok = (pos <= qpos) & (pos < tval)
    return jnp.where(ok, sc, NEG_INF)


def _decode_ml_kernel(table_ref, qpos_ref, tval_ref, q_ref, k_ref, kq_ref,
                      m_ref, l_ref, sc_ref, *, page, kv, g, nb,
                      sm_scale):
    i, j = pl.program_id(0), pl.program_id(1)
    sc = _page_scores(q_ref[0], k_ref[0], kq_ref[0, 0], sm_scale,
                      qpos_ref[i], tval_ref[0], j, page, kv, g)
    sc_ref[:, pl.dslice(j * page, page)] = sc

    @pl.when(j == nb - 1)
    def _reduce():
        # one max + one full-axis sum over the VMEM score row — the same
        # single reductions the unfused softmax runs
        m = jnp.max(sc_ref[...], axis=-1)
        m_ref[0] = m
        l_ref[0] = jnp.sum(jnp.exp(sc_ref[...] - m[:, None]), axis=-1)


def _decode_out_kernel(table_ref, qpos_ref, tval_ref, q_ref, k_ref, v_ref,
                       kq_ref, m_ref, l_ref, pinv_ref, pv_ref, o_ref,
                       acc_ref, *, page, kv, g, nb, sm_scale, k_a):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sc = _page_scores(q_ref[0], k_ref[0], kq_ref[0, 0], sm_scale,
                      qpos_ref[i], tval_ref[0], j, page, kv, g)
    p = jnp.exp(sc - m_ref[0][:, None]) / l_ref[0][:, None]
    s_ = 2.0 ** (k_a - 1)
    pg = jnp.round(p * s_) / s_                     # qprobs (Q_A grid)
    lim = s_ - 1.0
    p8 = jnp.clip(jnp.round(pg * pinv_ref[0, 0]), -lim,
                  lim).astype(jnp.int8)             # glued single-amax scale
    vpage = v_ref[0]
    for h in range(kv):
        acc_ref[h * g:(h + 1) * g] += jnp.dot(
            p8[h * g:(h + 1) * g], vpage[:, h, :],
            preferred_element_type=jnp.int32)       # (g, dh) int32

    @pl.when(j == nb - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(jnp.float32) * pv_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("sm_scale", "k_a", "ds",
                                             "interpret"))
def paged_attention(q8: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    table: jax.Array, q_pos: jax.Array, t_valid,
                    q_scale, k_scale, v_scale, *, sm_scale: float,
                    k_a: int = 8,
                    ds: tuple = ("parallel", "arbitrary"),
                    interpret: bool = True) -> jax.Array:
    """Fused paged decode attention (two streaming passes + scalar glue).

    q8: (B, H, dh) int8 query payload; k_pages/v_pages: (P, page, KV, dh)
    int8 arenas; table: (B, NB) int32 page ids (clamped; 0 = trash page);
    q_pos: (B,) int32; t_valid: scalar; scales: pow2 payload scales;
    sm_scale: 1/sqrt(dh); ds: dimension_semantics scheduling hint for the
    TPU pipeliner (autotuned — numerics-neutral, unlike the page size).
    Returns (B, H, dh) f32, bit-exact against ref.paged_attention_ref
    (== the unfused gather-then-attend path).
    """
    p_cnt, page, kv, dh = k_pages.shape
    b, kvg = q8.shape[:2]
    g = kvg // kv
    nb = table.shape[1]
    table = jnp.clip(table, 0, p_cnt - 1).astype(jnp.int32)
    qpos = q_pos.astype(jnp.int32)
    tval = jnp.asarray(t_valid, jnp.int32).reshape(1)
    kq = jnp.asarray(q_scale * k_scale, jnp.float32).reshape(1, 1)

    kwargs = {}
    if not interpret and _CompilerParams is not None:
        kwargs["compiler_params"] = _CompilerParams(
            dimension_semantics=tuple(ds))
    qspec = pl.BlockSpec((1, kvg, dh), lambda i, j, *_: (i, 0, 0))
    pagespec = pl.BlockSpec((1, page, kv, dh),
                            lambda i, j, tref, *_: (tref[i, j], 0, 0, 0))
    sspec = pl.BlockSpec((1, 1), lambda i, j, *_: (0, 0))
    rowspec = pl.BlockSpec((1, kvg), lambda i, j, *_: (i, 0))

    m, l = pl.pallas_call(
        functools.partial(_decode_ml_kernel, page=page, kv=kv, g=g, nb=nb,
                          sm_scale=sm_scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(b, nb),
            in_specs=[qspec, pagespec, sspec],
            out_specs=[rowspec, rowspec],
            scratch_shapes=[pltpu.VMEM((kvg, nb * page), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((b, kvg), jnp.float32)] * 2,
        interpret=interpret,
        **kwargs,
    )(table, qpos, tval, q8, k_pages, kq)

    # the single probability amax: max(p) per row is exp(0)/l == 1.0/l, so
    # the batch-global GridQuantizer scale of the quantized probabilities
    # reduces over `l` alone — a scalar reduction between the passes
    s_ = 2.0 ** (k_a - 1)
    amax_pg = jnp.round(jnp.max(1.0 / l) * s_) / s_
    step = jnp.maximum(_pow2_ceil(amax_pg), 2.0 ** -24) * 2.0 ** (1 - k_a)
    pinv = (jnp.float32(1.0) / step).reshape(1, 1)
    pv = (step * v_scale).reshape(1, 1).astype(jnp.float32)

    return pl.pallas_call(
        functools.partial(_decode_out_kernel, page=page, kv=kv, g=g, nb=nb,
                          sm_scale=sm_scale, k_a=k_a),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(b, nb),
            in_specs=[qspec, pagespec, pagespec, sspec, rowspec, rowspec,
                      sspec, sspec],
            out_specs=pl.BlockSpec((1, kvg, dh), lambda i, j, *_: (i, 0, 0)),
            scratch_shapes=[pltpu.VMEM((kvg, dh), jnp.int32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kvg, dh), jnp.float32),
        interpret=interpret,
        **kwargs,
    )(table, qpos, tval, q8, k_pages, v_pages, kq, m, l, pinv, pv)


# --------------------------------------------------------------------------
# flash attention (prefill / training forward)
# --------------------------------------------------------------------------


def _flash_kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, kval_ref, qs_ref,
                  ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref, *, b, kv, g,
                  dh, nk, causal, sm_scale, k_a):
    ik = pl.program_id(1)
    qc = q_ref.shape[1]
    kc = k_ref.shape[1]
    s_ = 2.0 ** (k_a - 1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # per-chunk GridQuantizer decompositions, amax over the FULL batch
    # block — bit-identical to the unfused per-chunk qeinsum entries
    qf = q_ref[...].astype(jnp.float32) * qs_ref[0, 0]
    q8, q_step = _grid_decompose(qf, k_a)
    kf = k_ref[...].astype(jnp.float32) * ks_ref[0, 0]
    k8, k_step = _grid_decompose(kf, k_a)
    vf = v_ref[...].astype(jnp.float32) * vs_ref[0, 0]
    v8, v_step = _grid_decompose(vf, k_a)

    q8r = q8.reshape(b, qc, kv, g, dh)
    sc = _tile_dots(q8r, k8, (q_step * k_step), swap=False)     # (b,qc,kv,g,kc)
    sc = sc * sm_scale
    kval = kval_ref[...] != 0
    qp, kp = qp_ref[...], kp_ref[...]
    mask = kval[None, :] if not causal else (
        (qp[:, None] >= kp[None, :]) & kval[None, :])
    sc = jnp.where(mask[None, :, None, None, :], sc, NEG_INF)

    m_old = m_ref[...].reshape(b, qc, kv, g)
    m_new = jnp.maximum(m_old, jnp.max(sc, axis=-1))
    p = jnp.exp(sc - m_new[..., None])
    p = jnp.round(p * s_) / s_                      # qprobs, unnormalized
    p8, p_step = _grid_decompose(p, k_a)
    pv = _tile_dots(p8, v8, (p_step * v_step), swap=True)       # (b,qc,kv,g,dh)
    alpha = jnp.exp(m_old - m_new)
    l_new = l_ref[...].reshape(b, qc, kv, g) * alpha + jnp.sum(p, axis=-1)
    o_new = acc_ref[...].reshape(b, qc, kv, g, dh) * alpha[..., None] + pv
    m_ref[...] = m_new.reshape(b, qc, kv * g)
    l_ref[...] = l_new.reshape(b, qc, kv * g)
    acc_ref[...] = o_new.reshape(b, qc, kv * g, dh)

    @pl.when(ik == nk - 1)
    def _flush():
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-9)[..., None]
        o_ref[...] = o.reshape(b, qc, kv * g, dh)


def _tile_dots(a8, b8, scale, *, swap):
    """Per-(batch, kv-head) integer dots, rescaled to f32.

    swap=False: scores — a8 (b, qc, kv, g, dh) x b8 (b, kc, kv, dh)
    -> (b, qc, kv, g, kc).  swap=True: p·v — a8 (b, qc, kv, g, kc) x
    b8 (b, kc, kv, dh) -> (b, qc, kv, g, dh).
    """
    b, qc, kv, g = a8.shape[:4]
    outs = []
    for bi in range(b):
        per_h = []
        for h in range(kv):
            lhs = a8[bi, :, h].reshape(qc * g, a8.shape[-1])
            rhs = b8[bi, :, h, :]
            rhs = rhs if swap else rhs.T
            acc = jnp.dot(lhs, rhs, preferred_element_type=jnp.int32)
            per_h.append(acc.reshape(qc, g, acc.shape[-1]))
        outs.append(jnp.stack(per_h, axis=1))       # (qc, kv, g, n)
    return jnp.stack(outs, 0).astype(jnp.float32) * scale


@functools.partial(jax.jit, static_argnames=("causal", "sm_scale", "q_chunk",
                                             "kv_chunk", "k_a", "ds",
                                             "interpret"))
def flash_attention(q8: jax.Array, k8: jax.Array, v8: jax.Array,
                    q_pos: jax.Array, k_pos: jax.Array, k_valid: jax.Array,
                    q_scale, k_scale, v_scale, *, causal: bool,
                    sm_scale: float, q_chunk: int, kv_chunk: int,
                    k_a: int = 8, ds: tuple = ("parallel", "arbitrary"),
                    interpret: bool = True) -> jax.Array:
    """Tiled online-softmax attention on int8 payloads (fwd only).

    q8: (B, S, H, dh) int8; k8/v8: (B, T, KV, dh) int8 — pre-padded to
    chunk multiples; q_pos (S,) / k_pos (T,) int32; k_valid (T,) int32
    mask of real kv slots.  Returns (B, S, H, dh) f32 pre-Q_A output,
    bit-exact against ref.flash_attention_ref (== the pure-JAX chunked
    online-softmax path in models/layers.py).
    """
    b, s, h, dh = q8.shape
    t, kv = k8.shape[1], k8.shape[2]
    g = h // kv
    nq, nk = s // q_chunk, t // kv_chunk
    qpos = q_pos.astype(jnp.int32)
    kpos = k_pos.astype(jnp.int32)
    kval = k_valid.astype(jnp.int32)
    scal = [jnp.asarray(v, jnp.float32).reshape(1, 1)
            for v in (q_scale, k_scale, v_scale)]

    kwargs = {}
    if not interpret and _CompilerParams is not None:
        kwargs["compiler_params"] = _CompilerParams(
            dimension_semantics=tuple(ds))
    sspec = pl.BlockSpec((1, 1), lambda iq, ik: (0, 0))
    out = pl.pallas_call(
        functools.partial(_flash_kernel, b=b, kv=kv, g=g, dh=dh, nk=nk,
                          causal=causal, sm_scale=sm_scale, k_a=k_a),
        grid=(nq, nk),
        in_specs=[
            pl.BlockSpec((b, q_chunk, h, dh), lambda iq, ik: (0, iq, 0, 0)),
            pl.BlockSpec((b, kv_chunk, kv, dh), lambda iq, ik: (0, ik, 0, 0)),
            pl.BlockSpec((b, kv_chunk, kv, dh), lambda iq, ik: (0, ik, 0, 0)),
            pl.BlockSpec((q_chunk,), lambda iq, ik: (iq,)),
            pl.BlockSpec((kv_chunk,), lambda iq, ik: (ik,)),
            pl.BlockSpec((kv_chunk,), lambda iq, ik: (ik,)),
            sspec, sspec, sspec,
        ],
        out_specs=pl.BlockSpec((b, q_chunk, h, dh),
                               lambda iq, ik: (0, iq, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((b, q_chunk, h), jnp.float32),
            pltpu.VMEM((b, q_chunk, h), jnp.float32),
            pltpu.VMEM((b, q_chunk, h, dh), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(q8, k8, v8, qpos, kpos, kval, *scal)
    return out
