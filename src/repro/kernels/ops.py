"""jit'd dispatch wrappers: Pallas kernel on TPU backends, jnp oracle on CPU.

This container lowers Pallas TPU kernels only under interpret=True, so the
default execution path on CPU is the oracle (identical math); tests sweep
the kernels in interpret mode against the oracles.  On a TPU backend the
compiled kernels are selected automatically.

The ten dispatched ops (DESIGN.md §8 maps them onto the paper's data
paths):

  qmatmul_op         — int8 x int8 -> int32 MAC, optional fused requantize
                       epilogue emitting an int8 payload directly
  quantize_op        — fused scale/round/clip payload emission (Q/SQ)
  cq_op              — stochastic-rounding CQ payload (Eq. 7)
  dgrad_op           — backward input-error dot e4 = W^T e3 with Q_E2 fused
                       into the matmul prologue (Alg. 2)
  wgrad_op           — backward weight-gradient dot g_W = e3 x0^T, same
                       fused prologue
  ubn_norm_op        — fused UBN: statistics + normalize + the five direct
                       quantizers in one pass
  page_gather_op     — paged int8 KV-cache gather (defrag / tests; the
                       decode hot loop streams pages via paged_attention_op)
  paged_attention_op — fused paged decode attention: pages stream through
                       VMEM, the gathered KV never exists in HBM (§7)
  flash_attention_op — tiled online-softmax prefill/training attention on
                       int8 payloads, per-chunk decompositions in-register
  selective_scan_op  — SSM recurrence (fp32 VPU over gridded inputs)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import autotune, ref
from .backward import bwd_dgrad, bwd_wgrad
from .page_gather import page_gather
from .paged_attention import flash_attention, paged_attention
from .qmatmul import qmatmul
from .quantize import cq_stochastic, quantize_fused
from .selective_scan import selective_scan
from .ubn import ubn_norm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def qmatmul_op(a8, b8, requant_inv=None, *, lim=127.0, force_kernel=False):
    """Integer matmul, optionally with the fused requantize epilogue.

    Args:
      a8: (M, K) int8 payload; b8: (K, N) int8 payload.
      requant_inv: optional scalar f32 — combined pow2 rescale
        a_scale * b_scale / out_step.  When given, the kernel epilogue
        emits clip(round(acc * requant_inv), +-lim) int8 directly; no fp32
        carrier and no separate quantize pass exist between the MAC and
        the payload.
      lim: epilogue clip bound (2^(k-1)-1 for a k-bit payload).

    Returns:
      (M, N) int32 accumulator, or (M, N) int8 payload with requant_inv.
    """
    if _on_tpu() or force_kernel:
        tiles = autotune.tiles_for(
            "qmatmul",
            (a8.shape, str(a8.dtype), b8.shape, str(b8.dtype),
             requant_inv is not None),
            {"bm": 128, "bn": 128, "bk": 256})
        return qmatmul(a8, b8, requant_inv, lim=lim,
                       interpret=not _on_tpu(), **tiles)
    if requant_inv is None:
        return ref.qmatmul_ref(a8, b8)
    return ref.qmatmul_requant_ref(a8, b8, requant_inv, lim)


def quantize_op(x, inv_step, lim=127.0, *, force_kernel=False):
    """Fused shift/direct quantize payload emission.

    Args:
      x: (M, N) f32 on/near a fixed-point grid; inv_step: scalar f32 exact
      pow2 reciprocal of the grid step; lim: clip bound.

    Returns:
      (M, N) int8 payload clip(round(x * inv_step), +-lim).
    """
    if _on_tpu():
        return quantize_fused(x, inv_step, lim=lim, interpret=False)
    if force_kernel:
        return quantize_fused(x, inv_step, lim=lim, interpret=True)
    return ref.quantize_ref(x, inv_step, lim)


def cq_op(x, bits, inv_step, dr=128.0, *, force_kernel=False):
    """Stochastic-rounding CQ payload (paper Eq. 7).

    Args:
      x: (M, N) f32 gradient; bits: (M, N) uint32 random bits;
      inv_step: scalar f32 rescale; dr: dynamic-range bound.

    Returns:
      (M, N) int16 payload clip(Sr(x * inv_step), +-(dr-1)).
    """
    if _on_tpu():
        return cq_stochastic(x, bits, inv_step, dr=dr, interpret=False)
    if force_kernel:
        return cq_stochastic(x, bits, inv_step, dr=dr, interpret=True)
    return ref.cq_stochastic_ref(x, bits, inv_step, dr)


def dgrad_op(g, b8, scal, *, mode="affine", k=8, force_kernel=False):
    """Fused-prologue backward input-error dot (paper Alg. 2, e4 = W^T e3).

    Args:
      g: (M, N) f32 incoming error e2; b8: (K, N) int8 payload of the
      forward weight operand; scal: (3,) f32 [inv, s1, s2] where inv is
      the exact pow2 reciprocal of the Q_E payload step and s1/s2 are the
      per-plane output scales (plane_step * b_scale).
      mode: "affine" (SQ/grid/direct, one plane) | "flag" (Eq. 17, two
      planes); k: Q_E bit width.

    Returns:
      (M, K) f32 da — the integer dots' dequantized sum.  The error payload
      is produced inside the kernel prologue and never stored.
    """
    if _on_tpu() or force_kernel:
        tiles = autotune.tiles_for(
            "dgrad", (g.shape, b8.shape, mode, k),
            {"bm": 128, "bk": 128, "bn": 128})
        return bwd_dgrad(g, b8, scal, mode=mode, k=k,
                         interpret=not _on_tpu(), **tiles)
    return ref.dgrad_ref(g, b8, scal, mode=mode, k=k)


def wgrad_op(a8, g, scal, *, mode="affine", k=8, force_kernel=False):
    """Fused-prologue backward weight-gradient dot (Alg. 2, g_W = e3 x0^T).

    Args:
      a8: (M, K) int8 payload of the saved forward activation x0;
      g: (M, N) f32 incoming error e2; scal: (3,) f32 [inv, s1, s2]
      (s1/s2 = plane_step * a_scale); mode/k as in dgrad_op.

    Returns:
      (K, N) f32 db on the same dequantized scale as the unfused path.
    """
    if _on_tpu() or force_kernel:
        tiles = autotune.tiles_for(
            "wgrad", (a8.shape, g.shape, mode, k),
            {"bm": 128, "bk": 128, "bn": 128})
        return bwd_wgrad(a8, g, scal, mode=mode, k=k,
                         interpret=not _on_tpu(), **tiles)
    return ref.wgrad_ref(a8, g, scal, mode=mode, k=k)


# the UBN kernel holds the full statistics axis in one VMEM block (the
# stats need every element); in + out f32 blocks => 8 bytes per element of
# (stats_axis x tile).  Tiles shrink to fit this budget, and shapes whose
# statistics axis alone exceeds it fall back to the XLA oracle.
_UBN_VMEM_BUDGET = 4 * 2 ** 20


def _ubn_tile(kind: str, m: int, n: int) -> int | None:
    """Largest safe tile along the non-statistics axis, or None -> oracle."""
    stats_axis = m if kind == "batch" else n
    fit = _UBN_VMEM_BUDGET // (8 * max(stats_axis, 1))
    return None if fit < 8 else min(256, fit)


def ubn_norm_op(x, gamma, beta=None, *, kind="rms", k_mu=16, k_sigma=16,
                k_bn=16, k_gamma=8, k_beta=8, eps=2.0 ** -8,
                force_kernel=False):
    """Fused UBN: statistics + normalize + output quantization, one pass.

    Args:
      x: (M, N) f32 — rows are tokens for "rms"/"layer"; for "batch" the
      caller flattens leading axes so statistics reduce over M per channel.
      gamma: (N,) f32; beta: (N,) f32 or None (rms has no shift).
      kind: "rms" | "layer" | "batch"; k_*: the paper's five norm widths;
      eps: epsilon_q (Eq. 12).

    Returns:
      (M, N) f32 on the k_BN/k_gamma grid, bit-identical to the unfused
      sim-mode composition in core/qnorm.py.  Shapes whose statistics axis
      cannot fit a VMEM block (huge flattened batch for "batch") lower
      through the XLA oracle instead — same math.
    """
    kw = dict(kind=kind, k_mu=k_mu, k_sigma=k_sigma, k_bn=k_bn,
              k_gamma=k_gamma, k_beta=k_beta, eps=eps)
    bt = _ubn_tile(kind, x.shape[0], x.shape[1])
    if bt is not None and (_on_tpu() or force_kernel):
        # the tuned tile competes with the heuristic but never exceeds
        # its VMEM-fit bound (the tile axis carries no statistics, so any
        # bt is bit-identical — tests/test_autotune.py proves it)
        tiles = autotune.tiles_for(
            "ubn_norm", (x.shape, kind), {"bt": bt})
        tiles["bt"] = min(tiles["bt"], bt)
        return ubn_norm(x, gamma, beta, interpret=not _on_tpu(),
                        **tiles, **kw)
    return ref.ubn_norm_ref(x, gamma, beta, **kw)


def page_gather_op(pages, table, *, force_kernel=False):
    """Paged int8 KV-cache gather (the serving engine's decode read).

    Args:
      pages: (P, page, *rest) int8 physical page arena; table: (B, NB)
      int32 per-lane page ids (out-of-range ids clamp; id 0 is the trash
      page dead lanes point at).

    Returns:
      (B, NB, page, *rest) int8 contiguous per-lane view — no dequantize.
      Trailing dims are flattened for the kernel and restored on the way
      out.
    """
    rest = pages.shape[2:]
    if _on_tpu() or force_kernel:
        p, page = pages.shape[:2]
        flat = pages.reshape(p, page, -1)
        out = page_gather(flat, table, interpret=not _on_tpu())
        return out.reshape(table.shape + (page,) + rest)
    return ref.page_gather_ref(pages, table)


# the decode score pass holds one lane's full (H, T) f32 score row in VMEM
# scratch; the flash kernel holds full-batch (B, qc, H[, dh]) m/l/o blocks.
# Shapes past these budgets lower through the XLA oracles instead (same
# math), mirroring the UBN tile guard above.
_ATTN_VMEM_BUDGET = 4 * 2 ** 20


def paged_attention_fits(kvg: int, t: int) -> bool:
    """Whether one lane's score row fits the decode kernel's VMEM scratch."""
    return 4 * kvg * t <= _ATTN_VMEM_BUDGET


def flash_attention_fits(b: int, qc: int, h: int, dh: int) -> bool:
    """Whether the flash kernel's full-batch m/l/o scratch fits VMEM."""
    return 4 * b * qc * h * (dh + 2) <= _ATTN_VMEM_BUDGET


def paged_attention_op(q8, k_pages, v_pages, table, q_pos, t_valid,
                       q_scale, k_scale, v_scale, *, sm_scale,
                       k_a=8, force_kernel=False):
    """Fused paged decode attention (the serving engine's decode hot loop).

    Streams int8 K/V pages through VMEM via a scalar-prefetched page table
    (two passes; the single probability amax lives between them as a scalar
    reduction over the row sums — DESIGN.md §7) and writes only the
    attention output: the gathered contiguous KV view never exists in HBM.

    Args:
      q8: (B, H, dh) int8 query payload (one decode token per lane);
      k_pages/v_pages: (P, page, KV, dh) int8 physical page arenas;
      table: (B, NB) int32 per-lane page ids (out-of-range ids clamp;
      id 0 is the trash page dead lanes point at); q_pos: (B,) int32
      per-lane positions; t_valid: scalar bound on valid positions;
      q/k/v_scale: pow2 payload scales; sm_scale: 1/sqrt(dh); k_a: the
      probability grid width.

    Returns:
      (B, H, dh) f32 pre-Q_A attention output, bit-exact against the
      unfused page_gather + decode_attention path.
    """
    page = k_pages.shape[1]
    fits = paged_attention_fits(q8.shape[1], table.shape[1] * page)
    # under manual TP (amax_sync active) the probability amax must pmax
    # over the model axis — a mesh collective the Pallas kernel body cannot
    # issue, so sharded decode stays on the (bit-identical) oracle
    tp_sync = ref._AMAX_SYNC_AXIS is not None
    if not tp_sync and (_on_tpu() or force_kernel) and fits:
        # the tunable here is the pipeliner's dimension_semantics hint —
        # the kv chunking itself is amax granularity (numerics), not a knob
        tiles = autotune.tiles_for(
            "paged_attention", (q8.shape, k_pages.shape, table.shape, k_a),
            {"ds": ("parallel", "arbitrary")})
        return paged_attention(q8, k_pages, v_pages, table, q_pos, t_valid,
                               q_scale, k_scale, v_scale, sm_scale=sm_scale,
                               k_a=k_a, ds=tiles["ds"],
                               interpret=not _on_tpu())
    return ref.paged_attention_ref(q8, k_pages, v_pages, table, q_pos,
                                   t_valid, q_scale, k_scale, v_scale,
                                   sm_scale=sm_scale, k_a=k_a)


def flash_attention_op(q8, k8, v8, q_pos, k_pos, k_valid, q_scale, k_scale,
                       v_scale, *, causal, sm_scale, q_chunk, kv_chunk,
                       k_a=8, force_kernel=False):
    """Tiled online-softmax attention on int8 payloads (prefill/training).

    One (q-tile, kv-tile) grid cell per chunk pair; per-chunk GridQuantizer
    decompositions run in-register over the full batch block, so the
    output is bit-identical to the pure-JAX chunked online-softmax in
    models/layers.py (including its saturate-at-pow2-amax corner).
    Forward-only: the training backward stays on the unfused composition
    (custom_vjp in models/layers.py), whose Q_E2 semantics are Alg. 2's.

    Args:
      q8: (B, S, H, dh) int8; k8/v8: (B, T, KV, dh) int8 — pre-padded to
      chunk multiples with payload zeros; q_pos (S,) / k_pos (T,) int32;
      k_valid: (T,) int mask of real kv slots; scales: pow2 payload
      scales; causal: mask mode; sm_scale: 1/sqrt(dh); q_chunk/kv_chunk:
      tile sizes (must divide S / T).

    Returns:
      (B, S, H, dh) f32 pre-Q_A output (padded rows included).
    """
    b, s, h, dh = q8.shape
    fits = flash_attention_fits(b, min(q_chunk, s), h, dh)
    # same manual-TP routing rule as paged_attention_op: in-kernel amax
    # cannot pmax, so sharded prefill/training takes the oracle
    tp_sync = ref._AMAX_SYNC_AXIS is not None
    if not tp_sync and (_on_tpu() or force_kernel) and fits:
        # q_chunk/kv_chunk are per-chunk amax granularity — numerics, never
        # autotuned; only the scheduling hint is a legal knob here
        tiles = autotune.tiles_for(
            "flash_attention",
            (q8.shape, k8.shape, causal, q_chunk, kv_chunk, k_a),
            {"ds": ("parallel", "arbitrary")})
        return flash_attention(q8, k8, v8, q_pos, k_pos, k_valid, q_scale,
                               k_scale, v_scale, causal=causal,
                               sm_scale=sm_scale, q_chunk=q_chunk,
                               kv_chunk=kv_chunk, k_a=k_a, ds=tiles["ds"],
                               interpret=not _on_tpu())
    return ref.flash_attention_ref(q8, k8, v8, q_pos, k_pos, k_valid,
                                   q_scale, k_scale, v_scale, causal=causal,
                                   sm_scale=sm_scale, q_chunk=q_chunk,
                                   kv_chunk=kv_chunk, k_a=k_a)


def selective_scan_op(a, b, c, *, force_kernel=False):
    """SSM selective-scan recurrence h_t = a_t h_{t-1} + b_t; y_t = c_t·h_t.

    Args:
      a, b: (B, S, D, N) f32 gridded scan inputs; c: (B, S, N) f32.

    Returns:
      (B, S, D) f32 outputs (fp32 VPU over 16-bit-gridded inputs —
      DESIGN.md §6).
    """
    if _on_tpu():
        return selective_scan(a, b, c, interpret=False)
    if force_kernel:
        return selective_scan(a, b, c, interpret=True)
    return ref.selective_scan_ref(a, b, c)


# --------------------------------------------------------------------------
# dispatch introspection (examples' startup banners, launch/report.py)
# --------------------------------------------------------------------------

OPS = ("qmatmul", "quantize", "cq", "dgrad", "wgrad", "ubn_norm",
       "page_gather", "paged_attention", "flash_attention", "selective_scan")


def dispatch_report(cfg=None) -> dict:
    """What the ops above resolve to right now.

    Returns {"backend", "route" ("kernel" on TPU else "oracle"),
    "ops": {name: route}}; with a QConfig also "mode" and "fused" (whether
    native mode routes backward/UBN/attention through the fused ops).
    """
    route = "kernel" if _on_tpu() else "oracle"
    rep = {"backend": jax.default_backend(), "route": route,
           "ops": {name: route for name in OPS}}
    rep["autotune"] = {"entries": len(autotune.entries()),
                       "dir": autotune.cache_dir()}
    from repro.runtime.compress import default_wire_codec
    codec, why = default_wire_codec(rep["backend"])
    rep["wire_codec"] = {"default": codec, "why": why}
    if cfg is not None:
        rep["mode"] = cfg.mode
        rep["fused"] = bool(cfg.native and getattr(cfg, "fuse_kernels", True))
    return rep


def dispatch_banner(cfg=None) -> str:
    """One-line startup banner, e.g.
    '[kernels] backend=cpu route=oracle mode=native bwd/ubn=fused
    attn=fused'."""
    rep = dispatch_report(cfg)
    line = f"[kernels] backend={rep['backend']} route={rep['route']}"
    if cfg is not None:
        fused = "fused" if rep["fused"] else "unfused"
        line += f" mode={rep['mode']} bwd/ubn={fused} attn={fused}"
    line += " " + autotune.banner_fragment()
    wc = rep["wire_codec"]
    line += f" wire_codec={wc['default']} ({wc['why']})"
    return line


COLLECTIVE_PRIMS = frozenset({
    "ppermute", "psum", "pmax", "pmin", "all_gather", "all_to_all",
    "reduce_scatter", "psum_scatter", "all_reduce"})


def collective_eqns(jaxpr) -> list:
    """(primitive name, out shape, out dtype) for every cross-device
    collective reachable from `jaxpr` (recursing through shard_map, scan,
    custom_vjp, ...).

    The sharded-training acceptance checks are phrased over this listing
    (DESIGN.md §9): with the integer-wire gradient sync, every `ppermute`
    or `all_gather` payload must be an integer dtype and every
    floating-point reduction (`psum`/`pmax`) must be scalar-shaped — the
    wire scale pmax and the loss-metric mean.  A tensor-shaped f32 psum
    means gradients crossed devices as floats (the XLA all-reduce baseline
    the jaxpr tests use as their positive control).
    """
    return [e for e in eqns_outside_pallas(jaxpr)
            if e[0] in COLLECTIVE_PRIMS]


def eqns_outside_pallas(jaxpr, out=None) -> list:
    """(primitive name, out shape, out dtype) for every eqn reachable from
    `jaxpr`, recursing through sub-jaxprs (pjit, scan, custom_vjp, ...) but
    NOT into pallas_call bodies — those record as ("pallas_call", None,
    None).

    The fused-decode acceptance checks are phrased over this listing: a
    dense gathered-KV-shaped int8 intermediate outside a pallas body means
    the decode step took the gather-then-attend route instead of streaming
    pages through the fused attention kernel.
    """
    if out is None:
        out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            out.append(("pallas_call", None, None))
            continue
        subs = []
        for v in eqn.params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for vv in vs:
                if hasattr(vv, "eqns"):
                    subs.append(vv)
                elif hasattr(vv, "jaxpr") and hasattr(vv.jaxpr, "eqns"):
                    subs.append(vv.jaxpr)
        if subs:
            for sub in subs:
                eqns_outside_pallas(sub, out)
        else:
            aval = eqn.outvars[0].aval if eqn.outvars else None
            out.append((eqn.primitive.name,
                        getattr(aval, "shape", ()),
                        getattr(aval, "dtype", None)))
    return out
