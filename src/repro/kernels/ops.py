"""jit'd dispatch wrappers: Pallas kernel on TPU backends, jnp oracle on CPU.

This container lowers Pallas TPU kernels only under interpret=True, so the
default execution path on CPU is the oracle (identical math); tests sweep
the kernels in interpret mode against the oracles.  On a TPU backend the
compiled kernels are selected automatically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .page_gather import page_gather
from .qmatmul import qmatmul
from .quantize import cq_stochastic, quantize_fused
from .selective_scan import selective_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def qmatmul_op(a8, b8, *, force_kernel=False):
    if _on_tpu():
        return qmatmul(a8, b8, interpret=False)
    if force_kernel:
        return qmatmul(a8, b8, interpret=True)
    return ref.qmatmul_ref(a8, b8)


def quantize_op(x, inv_step, lim=127.0, *, force_kernel=False):
    if _on_tpu():
        return quantize_fused(x, inv_step, lim=lim, interpret=False)
    if force_kernel:
        return quantize_fused(x, inv_step, lim=lim, interpret=True)
    return ref.quantize_ref(x, inv_step, lim)


def cq_op(x, bits, inv_step, dr=128.0, *, force_kernel=False):
    if _on_tpu():
        return cq_stochastic(x, bits, inv_step, dr=dr, interpret=False)
    if force_kernel:
        return cq_stochastic(x, bits, inv_step, dr=dr, interpret=True)
    return ref.cq_stochastic_ref(x, bits, inv_step, dr)


def page_gather_op(pages, table, *, force_kernel=False):
    """pages: (P, page, *rest) + table: (B, NB) -> (B, NB, page, *rest).

    The serving engine's paged-KV gather: physical int8 pages named by a
    per-lane page table become a contiguous per-lane view.  Trailing dims
    are flattened for the kernel and restored on the way out.
    """
    rest = pages.shape[2:]
    if _on_tpu() or force_kernel:
        p, page = pages.shape[:2]
        flat = pages.reshape(p, page, -1)
        out = page_gather(flat, table, interpret=not _on_tpu())
        return out.reshape(table.shape + (page,) + rest)
    return ref.page_gather_ref(pages, table)


def selective_scan_op(a, b, c, *, force_kernel=False):
    if _on_tpu():
        return selective_scan(a, b, c, interpret=False)
    if force_kernel:
        return selective_scan(a, b, c, interpret=True)
    return ref.selective_scan_ref(a, b, c)
