"""Pallas TPU kernels: fused quantization (paper Eq. 6/7/8 inner loops).

quantize_fused    — one pass over x: scale, round, saturate, emit the int8
                    payload (the Q / SQ hot loop after the amax prepass).
cq_stochastic     — the CQ stochastic-rounding loop (Eq. 7): floor + coin
                    flip from uniform bits, saturate to the dr range, int16
                    payload.  Random bits arrive as a uint32 input plane
                    (jax.random.bits outside -> deterministic and testable;
                    on real TPU swap in pltpu.prng_random_bits and drop the
                    input — kept as a flag-gated path).

Both are elementwise over 2D blocks: (bm, bn) VMEM tiles, 8x128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, s_ref, o_ref, *, lim):
    inv = s_ref[0, 0]
    v = jnp.round(x_ref[...] * inv)
    o_ref[...] = jnp.clip(v, -lim, lim).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("lim", "bm", "bn", "interpret"))
def quantize_fused(x: jax.Array, inv_step: jax.Array, *, lim: float = 127.0,
                   bm: int = 256, bn: int = 256,
                   interpret: bool = True) -> jax.Array:
    """x: (M, N) f32; inv_step: scalar f32 -> int8 payload (M, N)."""
    m, n = x.shape
    bm, bn = min(bm, m), min(bn, n)
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    grid = ((m + pm) // bm, (n + pn) // bn)
    out = pl.pallas_call(
        functools.partial(_quant_kernel, lim=lim),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + pm, n + pn), jnp.int8),
        interpret=interpret,
    )(x, inv_step.reshape(1, 1))
    return out[:m, :n]


def _cq_kernel(x_ref, bits_ref, s_ref, o_ref, *, dr):
    inv = s_ref[0, 0]
    v = x_ref[...] * inv
    f = jnp.floor(v)
    u = (bits_ref[...] & jnp.uint32(0xFFFFFF)).astype(jnp.float32) \
        * (2.0 ** -24)
    y = f + (u < (v - f)).astype(jnp.float32)
    o_ref[...] = jnp.clip(y, -dr + 1.0, dr - 1.0).astype(jnp.int16)


@functools.partial(jax.jit, static_argnames=("dr", "bm", "bn", "interpret"))
def cq_stochastic(x: jax.Array, bits: jax.Array, inv_step: jax.Array, *,
                  dr: float = 128.0, bm: int = 256, bn: int = 256,
                  interpret: bool = True) -> jax.Array:
    """Stochastic CQ payload (Eq. 7).  x,(bits): (M, N) -> int16 (M, N)."""
    m, n = x.shape
    bm, bn = min(bm, m), min(bn, n)
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
        bits = jnp.pad(bits, ((0, pm), (0, pn)))
    grid = ((m + pm) // bm, (n + pn) // bn)
    out = pl.pallas_call(
        functools.partial(_cq_kernel, dr=dr),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
                  pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + pm, n + pn), jnp.int16),
        interpret=interpret,
    )(x, bits, inv_step.reshape(1, 1))
    return out[:m, :n]
