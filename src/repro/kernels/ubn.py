"""Pallas TPU kernel: fused UBN — statistics, normalize, quantize, one pass.

The paper's quantized norm (Eq. 11-13) runs five direct quantizers around
the normalization arithmetic: Q(mu, k_mu), Q(sigma, k_sigma), Q(xhat, k_BN),
Q(gamma, k_gamma), Q(beta, k_beta).  As separate XLA passes each one
re-reads and re-writes the full activation between stages; here the whole
chain is ONE kernel pass per tile: statistics reduce in VMEM, the normalize
and every direct quantization happen in registers, and only the final
quantized-grid output is written back.  Direct quantization uses the FIXED
2^(1-k) grid step — no amax, no data-dependent rescan anywhere.

Kinds (static):
  "rms"   — per-row RMS stats (no mean, no beta):   qrmsnorm
  "layer" — per-row mean + variance:                qlayernorm
  "batch" — per-COLUMN mean + variance over the     qbatchnorm
            flattened batch axis (x arrives as (M, C), stats over M)

Output is the fp32 *grid* value (DESIGN.md §3): every intermediate lies
exactly on its fixed-point grid, so this is bit-identical to the sim-mode
composition in core/qnorm.py — validated against ref.ubn_norm_ref.

VMEM constraint: the statistics axis is held whole in each block (the
stats need every element), so the per-block footprint is
8 bytes x stats_axis x bt.  `ops.ubn_norm_op` shrinks `bt` to fit and
falls back to the XLA oracle for shapes whose statistics axis alone
exceeds the budget (e.g. a very large flattened batch under "batch").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qd(x, k: int):
    """Direct quantization Q(x, k) = round(x * 2^(k-1)) / 2^(k-1) (Eq. 6)."""
    s = 2.0 ** (k - 1)
    return jnp.round(x * s) / s


def _ubn_kernel(x_ref, g_ref, b_ref, o_ref, *, kind, k_mu, k_sigma, k_bn,
                k_gamma, k_beta, eps):
    x = x_ref[...]
    axis = 0 if kind == "batch" else -1
    if kind == "rms":
        sigma = jnp.sqrt(jnp.mean(jnp.square(x), axis=axis, keepdims=True))
        xhat = x / (_qd(sigma, k_sigma) + eps)
    else:
        mu = jnp.mean(x, axis=axis, keepdims=True)
        var = jnp.mean(jnp.square(x), axis=axis, keepdims=True) \
            - jnp.square(mu)
        sigma = jnp.sqrt(jnp.maximum(var, 0.0))
        xhat = (x - _qd(mu, k_mu)) / (_qd(sigma, k_sigma) + eps)
    xhat = _qd(xhat, k_bn)                                     # Q_BN
    y = _qd(g_ref[...], k_gamma) * xhat
    if kind != "rms":
        y = y + _qd(b_ref[...], k_beta)
    o_ref[...] = y


@functools.partial(jax.jit, static_argnames=("kind", "k_mu", "k_sigma",
                                             "k_bn", "k_gamma", "k_beta",
                                             "eps", "bt", "interpret"))
def ubn_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array | None, *,
             kind: str = "rms", k_mu: int = 16, k_sigma: int = 16,
             k_bn: int = 16, k_gamma: int = 8, k_beta: int = 8,
             eps: float = 2.0 ** -8, bt: int = 256,
             interpret: bool = True) -> jax.Array:
    """Fused stats + normalize + quantize over a 2-D view.

    Args:
      x: (M, N) f32 — rows are tokens for "rms"/"layer"; for "batch" the
        caller flattens all leading axes so columns are channels and the
        statistics reduce over M.
      gamma: (N,) f32 scale; beta: (N,) f32 shift (None for "rms").
      kind: "rms" | "layer" | "batch" (static; selects the stats recipe).
      k_*: paper bit widths for the five direct quantizers; eps: epsilon_q.
      bt: tile along the non-statistics axis.

    Returns:
      (M, N) f32 on the k_BN/k_gamma grid — bit-identical to the unfused
      sim-mode composition (the ref.ubn_*_ref oracles).
    """
    m, n = x.shape
    gamma = gamma.reshape(1, n)
    beta = (jnp.zeros((1, n), jnp.float32) if beta is None
            else beta.reshape(1, n))
    if kind == "batch":       # stats over M: tile columns, keep M whole
        bt = min(bt, n)
        pad = (-n) % bt
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad)))
            gamma = jnp.pad(gamma, ((0, 0), (0, pad)))
            beta = jnp.pad(beta, ((0, 0), (0, pad)))
        grid = ((n + pad) // bt,)
        xs = pl.BlockSpec((m, bt), lambda i: (0, i))
        vs = pl.BlockSpec((1, bt), lambda i: (0, i))
        out_spec, oshape = xs, (m, n + pad)
    else:                     # stats over N: tile rows, keep N whole
        bt = min(bt, m)
        pad = (-m) % bt
        if pad:
            x = jnp.pad(x, ((0, pad), (0, 0)))
        grid = ((m + pad) // bt,)
        xs = pl.BlockSpec((bt, n), lambda i: (i, 0))
        vs = pl.BlockSpec((1, n), lambda i: (0, 0))
        out_spec, oshape = xs, (m + pad, n)
    out = pl.pallas_call(
        functools.partial(_ubn_kernel, kind=kind, k_mu=k_mu,
                          k_sigma=k_sigma, k_bn=k_bn, k_gamma=k_gamma,
                          k_beta=k_beta, eps=eps),
        grid=grid,
        in_specs=[xs, vs, vs],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(oshape, jnp.float32),
        interpret=interpret,
    )(x, gamma, beta)
    return out[:m, :n]
