"""Elastic int8 training runtime (DESIGN.md §11).

Composes the three pieces that existed but never met — the DP×TP sharded
train step (launch/train.py), the QTensor-native checkpoint layer
(checkpoint/manager.py + qsave.py) and the fault primitives (fault.py) —
into one runner that survives preemption and DP membership changes
BIT-EXACTLY:

  * async QTensor checkpoints on a save cadence: the device->host snapshot
    is the only work on the step's critical path; packing (integer payloads
    + pow2 grid exponents, never densified to f32) and the atomic publish
    run on the writer thread;
  * restore-on-failure: any exception restores the latest checkpoint and
    replays — stochastic-rounding keys and batches derive from the step
    index, so the resumed trajectory equals the uninterrupted one bit for
    bit (tests/test_elastic.py chaos suite);
  * deterministic DP reshard: because PR 5 parameterized the algorithm by
    `n_shards` (virtual batch shards = quantization granularity), not by
    devices, a checkpoint written under dp_old resumes under any dp_new
    dividing n_shards with an identical trajectory.  Params are replicated
    (re-placed through the restore mesh path); the flat ZeRO-1 Momentum
    chunks re-chunk via launch/shard.zero_reshard (unpad + repad — padding
    provably stays zero);
  * watchdog-triggered rebalance: when StepWatchdog flags enough
    stragglers, the runner shrinks DP to the next divisor of n_shards —
    the virtual shards redistribute over the surviving devices and the
    trajectory still does not change.

The one invariant the runner enforces rather than recovers from: `n_shards`
(and the opt_shard layout family) must match the checkpoint — changing the
quantization granularity mid-run would silently change the math, so it
raises instead.
"""
from __future__ import annotations

import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.fault import SimulatedFailure, StepWatchdog

log = logging.getLogger("repro.runtime.elastic")


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
        tree)


def next_divisor_down(n_shards: int, dp: int) -> int:
    """Largest dp' < dp with n_shards % dp' == 0 (rebalance target)."""
    for d in range(dp - 1, 0, -1):
        if n_shards % d == 0:
            return d
    return 1


class ElasticRunner:
    """Elastic checkpoint/restore/reshard driver over the sharded step.

    Args:
      model: built with tp_size == tp (build_model).
      qcfg / labels: the training QConfig and the param-label tree.
      ckpt: CheckpointManager over {"params": ..., "opt": ...} trees.
      batch_fn: step index -> HOST batch tree (must be deterministic in the
        step index — the bit-exact-resume contract replays steps).
      dp / tp: initial mesh; n_shards: virtual-shard count (fixed for the
        life of the run — the quantization granularity).
      opt_shard: "replicated" | "zero1" (flat chunked Momentum, tp == 1).
      rebalance_flags: >0 enables watchdog-driven shrink after that many
        straggler flags since the last (re)start or reshard.
    """

    def __init__(self, model, qcfg, labels, ckpt, batch_fn, *,
                 dp: int, n_shards: int, tp: int = 1,
                 opt_shard: str = "replicated", lr: float = 0.05,
                 mom: float = 0.75, dr_bits: int = 8, wire_bits: int = 16,
                 grad_sync: str = "int_ring", save_every: int = 50,
                 max_restarts: int = 10,
                 watchdog: StepWatchdog | None = None,
                 rebalance_flags: int = 0, log_every: int = 0):
        if n_shards % dp:
            raise ValueError(f"n_shards={n_shards} must be divisible by "
                             f"dp={dp}")
        self.model, self.qcfg, self.labels = model, qcfg, labels
        self.ckpt = ckpt
        self.batch_fn = batch_fn
        self.dp, self.tp, self.n_shards = dp, tp, n_shards
        self.opt_shard = opt_shard
        self.lr, self.mom, self.dr_bits = lr, mom, dr_bits
        self.wire_bits, self.grad_sync = wire_bits, grad_sync
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.watchdog = watchdog or StepWatchdog()
        self.rebalance_flags = rebalance_flags
        self.log_every = log_every
        self.restarts = 0
        self.reshards: list[tuple[int, int, int]] = []  # (step, dp_old, new)
        self._flags_since_rebalance = 0
        self._built: dict[int, tuple] = {}      # dp -> (mesh, fn, specs)
        self._ptmpl = None                      # param ShapeDtypeStructs

    # ------------- step/mesh construction -------------

    def _engage(self, dp: int):
        """(mesh, jitted step, specs) for a DP membership, cached per dp."""
        if dp not in self._built:
            from repro.launch.mesh import make_cpu_mesh
            from repro.launch.train import make_sharded_train_step

            mesh = make_cpu_mesh(dp, self.tp)
            raw, specs = make_sharded_train_step(
                self.model, self.qcfg, self.labels, mesh, self._ptmpl,
                lr=self.lr, mom=self.mom, dr_bits=self.dr_bits,
                n_shards=self.n_shards, wire_bits=self.wire_bits,
                grad_sync=self.grad_sync, opt_shard=self.opt_shard)
            self._built[dp] = (mesh, jax.jit(raw, donate_argnums=(0, 1)),
                               specs)
        return self._built[dp]

    def _place(self, params, opt):
        from repro.launch import shard as S
        mesh, _, specs = self._engage(self.dp)
        return (S.shard_arrays(mesh, params, specs["params"]),
                S.shard_arrays(mesh, opt, specs["opt"]))

    def _opt_template(self, dp: int):
        from repro.launch import shard as S
        if self.opt_shard == "zero1":
            return S.zero_template(self._ptmpl, dp)
        from repro.optim import MomentumState
        return MomentumState(acc=self._ptmpl,
                             step=jax.ShapeDtypeStruct((), jnp.int32))

    # ------------- checkpoint / reshard -------------

    def _aux(self):
        return {"dp": self.dp, "tp": self.tp, "n_shards": self.n_shards,
                "opt_shard": self.opt_shard}

    def save(self, step: int, params, opt, block=False):
        self.ckpt.save(step, {"params": params, "opt": opt},
                       aux=self._aux(), block=block)

    def restore(self):
        """Latest checkpoint -> (params, opt, step) PLACED under the
        CURRENT membership, resharding the ZeRO-1 chunks if the checkpoint
        was written under a different dp.  Raises FileNotFoundError when no
        checkpoint exists and ValueError on a granularity mismatch."""
        from repro.launch import shard as S

        step = self.ckpt.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.ckpt.dir}")
        aux = self.ckpt.meta(step)["aux"]
        if aux.get("n_shards", self.n_shards) != self.n_shards:
            raise ValueError(
                f"checkpoint n_shards={aux['n_shards']} != runner "
                f"n_shards={self.n_shards}: the virtual-shard count is the "
                f"quantization granularity — changing it breaks the "
                f"bit-exact trajectory (start a fresh run instead)")
        if aux.get("opt_shard", self.opt_shard) != self.opt_shard:
            raise ValueError(
                f"checkpoint opt_shard={aux['opt_shard']!r} != runner "
                f"opt_shard={self.opt_shard!r}")
        dp_ckpt = int(aux.get("dp", self.dp))
        target = {"params": self._ptmpl, "opt": self._opt_template(dp_ckpt)}
        mesh, _, specs = self._engage(self.dp)
        if dp_ckpt == self.dp or self.opt_shard != "zero1":
            # same chunking (or replicated opt): leaves re-place directly
            # through the restore mesh path under the current membership
            state, got, _ = self.ckpt.restore(
                target, step=step, mesh=mesh,
                pspec_tree={"params": specs["params"],
                            "opt": specs["opt"]})
            return state["params"], state["opt"], got
        # dp changed under ZeRO-1: restore to host, re-chunk the flat
        # accumulator leaves (bit-exact unpad+repad), then re-place
        state, got, _ = self.ckpt.restore(target, step=step)
        opt = state["opt"]
        acc = S.zero_reshard(jax.device_get(opt.acc), self._ptmpl, self.dp)
        opt = opt._replace(acc=acc)
        log.warning("resharded ZeRO-1 chunks dp=%d -> dp=%d at step %d",
                    dp_ckpt, self.dp, got)
        params, opt = self._place(jax.device_get(state["params"]), opt)
        return params, opt, got

    def resize(self, dp_new: int, params, opt, *, step: int | None = None):
        """Live membership change: reshard the current device state onto a
        dp_new mesh.  Bit-exact — `n_shards` is unchanged, so the step
        math is too; only the placement (and ZeRO-1 chunking) moves."""
        from repro.launch import shard as S
        if self.n_shards % dp_new:
            raise ValueError(f"dp_new={dp_new} must divide "
                             f"n_shards={self.n_shards}")
        host_p = jax.device_get(params)
        host_o = jax.device_get(opt)
        if self.opt_shard == "zero1" and dp_new != self.dp:
            host_o = host_o._replace(
                acc=S.zero_reshard(host_o.acc, self._ptmpl, dp_new))
        self.reshards.append((-1 if step is None else step, self.dp, dp_new))
        self.dp = dp_new
        self.watchdog.reset()
        self._flags_since_rebalance = 0
        return self._place(host_p, host_o)

    # ------------- the elastic loop -------------

    def run(self, params, opt, n_steps: int, *, start_step: int = 0,
            resume: bool = False, fail_at=None,
            fail_save_at: int | None = None,
            resize_at: dict | None = None):
        """Train to n_steps with elastic recovery.  Returns
        (host_params, host_opt, last_metrics).

        params/opt: HOST (or replicated device) trees for a cold start —
        the runner places them; with resume=True the latest checkpoint wins
        when one exists.  Chaos hooks: `fail_at` (step or iterable of steps
        that raise SimulatedFailure), `fail_save_at` (the async writer of
        the save at that step dies before publishing — a kill -9 mid-save),
        `resize_at` ({step: dp_new} planned membership changes).
        """
        from repro.launch.shard import put_batch
        if self._ptmpl is None:
            self._ptmpl = _sds(params)
        fail_at = (set() if fail_at is None else
                   {fail_at} if isinstance(fail_at, int) else set(fail_at))
        resize_at = dict(resize_at or {})
        # host copy of the cold-start state: the jitted step donates its
        # input buffers, so a cold restart cannot reuse the placed arrays
        init_host = (jax.tree.map(np.asarray, params),
                     jax.tree.map(np.asarray, opt))
        step = start_step
        if resume and self.ckpt.latest_step() is not None:
            params, opt, step = self.restore()
            log.warning("resumed from checkpoint at step %d (dp=%d)",
                        step, self.dp)
        else:
            params, opt = self._place(params, opt)
        mesh, fn, _ = self._engage(self.dp)
        metrics = None
        while step < n_steps:
            try:
                while step < n_steps:
                    if step in resize_at and resize_at[step] != self.dp:
                        params, opt = self.resize(resize_at.pop(step),
                                                  params, opt, step=step)
                        mesh, fn, _ = self._engage(self.dp)
                    t0 = time.time()
                    if step in fail_at:
                        fail_at.discard(step)       # fail exactly once
                        raise SimulatedFailure(f"injected at step {step}")
                    batch = put_batch(mesh, self.batch_fn(step))
                    params, opt, metrics = fn(params, opt, batch,
                                              jnp.int32(step))
                    if self.watchdog.observe(step, time.time() - t0):
                        self._flags_since_rebalance += 1
                    step += 1
                    if step % self.save_every == 0 or step == n_steps:
                        if fail_save_at is not None and step == fail_save_at:
                            fail_save_at = None
                            self.ckpt._fail_next_write = True
                        self.save(step, params, opt)
                    if self.log_every and step % self.log_every == 0:
                        log.info("step %d loss %.4f dp=%d", step,
                                 float(metrics["loss"]), self.dp)
                    if (self.rebalance_flags and self.dp > 1
                            and self._flags_since_rebalance
                            >= self.rebalance_flags):
                        dp_new = next_divisor_down(self.n_shards, self.dp)
                        log.warning("watchdog rebalance at step %d: "
                                    "dp %d -> %d", step, self.dp, dp_new)
                        params, opt = self.resize(dp_new, params, opt,
                                                  step=step)
                        mesh, fn, _ = self._engage(self.dp)
            except Exception as e:  # noqa: BLE001 — any fault restarts
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                log.warning("step %d failed (%s); restoring latest "
                            "checkpoint", step, e)
                try:                    # a mid-save writer death surfaces
                    self.ckpt.wait()    # here — swallow it, the restore
                except Exception:       # below decides what state survives
                    pass
                try:
                    params, opt, step = self.restore()
                except FileNotFoundError:
                    step = start_step   # no checkpoint yet: cold restart
                    params, opt = self._place(*init_host)
        try:
            self.ckpt.wait()
        except Exception as e:  # noqa: BLE001 — final async write died:
            log.warning("final async save failed (%s); rewriting "
                        "synchronously", e)
            self.save(step, params, opt, block=True)
        return (jax.device_get(params), jax.device_get(opt), metrics)
