from .compress import compressed_psum_int, ring_reduce_scatter_int
from .fault import StepWatchdog, TrainRunner, SimulatedFailure

__all__ = ["compressed_psum_int", "ring_reduce_scatter_int", "StepWatchdog",
           "TrainRunner", "SimulatedFailure"]
