from .compress import (compressed_psum_int, ring_allreduce_int,
                       ring_reduce_scatter_int, wire_limit, wire_quantize,
                       wire_shift, wire_sync_mean)
from .elastic import ElasticRunner, next_divisor_down
from .fault import StepWatchdog, TrainRunner, SimulatedFailure

__all__ = ["compressed_psum_int", "ring_allreduce_int",
           "ring_reduce_scatter_int", "wire_limit", "wire_quantize",
           "wire_shift", "wire_sync_mean", "StepWatchdog", "TrainRunner",
           "SimulatedFailure", "ElasticRunner", "next_divisor_down"]
