from .compress import (compressed_psum_int, pack_int8_pairs,
                       ring_allreduce_int, ring_reduce_scatter_int,
                       unpack_int16_pairs, wire_limit, wire_presum,
                       wire_quantize, wire_shift, wire_sync_mean,
                       wire_sync_tree)
from .elastic import ElasticRunner, next_divisor_down
from .fault import StepWatchdog, TrainRunner, SimulatedFailure

__all__ = ["compressed_psum_int", "pack_int8_pairs", "ring_allreduce_int",
           "ring_reduce_scatter_int", "unpack_int16_pairs", "wire_limit",
           "wire_presum", "wire_quantize", "wire_shift", "wire_sync_mean",
           "wire_sync_tree", "StepWatchdog", "TrainRunner",
           "SimulatedFailure", "ElasticRunner", "next_divisor_down"]
