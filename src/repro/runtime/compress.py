"""Integer gradient compression collectives (shard_map + ppermute ring).

The paper's CQ already puts weight gradients on a 15-bit grid with a shared
power-of-two scale — so the gradient wire format can be an integer QTensor
(int16 halves f32 traffic, int8 quarters it) with NO extra information loss
beyond what WAGEUBN's own optimizer quantization discards.  We implement the
ring reduce-scatter manually so every hop's message really is the integer
payload on the wire (XLA's native all-reduce would keep the accumulator
dtype on the wire).

The wire format IS a QTensor: `_wire_quantize` decomposes the local chunks
once into (int payload, shared pow2 scale) and the ring ships the payload;
`wire_quantize` is exported for tests and for QTensor-native callers that
want to hand the payload to other transports.

Overflow control: with n shards, partial sums of b-bit operands need
b + ceil(log2 n) bits; we pre-shift the grid by ceil(log2 n) so every
partial sum stays within the wire width (the discarded low bits are below
CQ's own grid once divided by n — documented trade-off, error-feedback hook
below).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import SHARD_MAP_KW as _SM_KW
from repro.compat import shard_map as _shard_map
from repro.core import qfuncs as qf
from repro.core.qtensor import QTensor, payload_dtype


def wire_quantize(chunks, amax, bits: int, shift: int) -> QTensor:
    """Decompose gradient chunks into the integer wire QTensor.

    scale = pow2_ceil(amax) * 2^(1 - bits + shift): the pre-shift keeps
    n-way partial sums inside the wire width.  `amax` must already be the
    global max across participating shards (pmax'ed by the caller).
    """
    lim = 2.0 ** (bits - 1) - 1.0
    scale = qf.pow2_ceil(amax) * 2.0 ** (1 - bits + shift)
    data = jnp.clip(jnp.round(chunks / scale), -lim,
                    lim).astype(payload_dtype(bits))
    return QTensor(data, scale, bits)


def _ring_reduce_scatter(qt: QTensor, axis_name, n):
    """qt.data: (n, chunk) integer contributions per rank.

    Classic ring: rank r starts with its contribution to chunk (r-1)%n and
    after n-1 hops holds the fully reduced chunk r.  Every message on the
    wire is the integer payload dtype (int8/int16), never fp32.
    """
    x_int, lim = qt.data, float(2.0 ** (qt.k - 1) - 1.0)
    dtype = x_int.dtype
    idx = lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    acc = jnp.take(x_int, (idx - 1) % n, axis=0).astype(jnp.int32)

    def hop(i, acc):
        msg = jnp.clip(acc, -lim, lim).astype(dtype)   # integer wire
        msg = lax.ppermute(msg, axis_name, perm)
        k = (idx - 2 - i) % n
        return msg.astype(jnp.int32) + jnp.take(x_int, k, axis=0)

    acc = lax.fori_loop(0, n - 1, hop, acc) if n > 1 else acc
    return acc


def ring_reduce_scatter_int(x, mesh, axis_name: str, bits: int = 16):
    """Reduce-scatter x (replicated-shape per device) over `axis_name`,
    quantizing every wire message to the `bits`-wide integer payload.
    Returns the per-device shard of the mean, fp32.
    """
    n = mesh.shape[axis_name]
    shift = max(0, math.ceil(math.log2(max(n, 1))))

    def f(xl):
        flat = xl.reshape(-1)
        pad = -flat.size % n
        flat = jnp.pad(flat, (0, pad))
        chunks = flat.reshape(n, -1)
        amax = lax.pmax(jnp.max(jnp.abs(chunks)), axis_name)
        qt = wire_quantize(chunks, amax, bits, shift)
        acc = _ring_reduce_scatter(qt, axis_name, n)
        return acc.astype(jnp.float32) * qt.scale / n

    spec = P(*((None,) * x.ndim))
    fn = _shard_map(f, mesh=mesh, in_specs=(spec,),
                    out_specs=P(axis_name), **_SM_KW)
    return fn(x)


def compressed_psum_int(x, mesh, axis_name: str, bits: int = 16):
    """integer-wire all-reduce mean = ring reduce-scatter + all-gather."""
    n = mesh.shape[axis_name]
    shift = max(0, math.ceil(math.log2(max(n, 1))))

    def f(xl):
        shape = xl.shape
        flat = xl.reshape(-1)
        pad = -flat.size % n
        flat = jnp.pad(flat, (0, pad))
        chunks = flat.reshape(n, -1)
        amax = lax.pmax(jnp.max(jnp.abs(chunks)), axis_name)
        qt = wire_quantize(chunks, amax, bits, shift)
        acc = _ring_reduce_scatter(qt, axis_name, n)
        # all-gather the reduced chunks; rank i holds chunk i so rank order
        # IS chunk order
        gathered = lax.all_gather(acc, axis_name, axis=0)  # (n, chunk)
        full = gathered.reshape(-1)
        full = full[: flat.size - pad] if pad else full
        return (full.astype(jnp.float32) * qt.scale / n).reshape(shape)

    spec = P(*((None,) * x.ndim))
    fn = _shard_map(f, mesh=mesh, in_specs=(spec,), out_specs=spec,
                    **_SM_KW)
    return fn(x)
