"""Integer gradient compression collectives (shard_map + ppermute ring).

The paper's CQ already puts weight gradients on a 15-bit grid with a shared
power-of-two scale — so the gradient wire format can be int16 (half of f32
traffic) with NO extra information loss beyond what WAGEUBN's own optimizer
quantization discards.  We implement the ring reduce-scatter manually so
every hop's message really is int16 on the wire (XLA's native all-reduce
would keep the accumulator dtype on the wire).

Overflow control: with n shards, partial sums of b-bit operands need
b + ceil(log2 n) bits; we pre-shift the grid by ceil(log2 n) so every
partial sum stays within int16 (the discarded low bits are below CQ's own
grid once divided by n — documented trade-off, error-feedback hook below).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

try:
    from jax import shard_map as _shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map

from jax.sharding import PartitionSpec as P


def _ring_reduce_scatter(x16, axis_name, n):
    """x16: (n, chunk) int16 local contributions per rank.

    Classic ring: rank r starts with its contribution to chunk (r-1)%n and
    after n-1 hops holds the fully reduced chunk r.  Every message on the
    wire is int16.
    """
    idx = lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    acc = jnp.take(x16, (idx - 1) % n, axis=0).astype(jnp.int32)

    def hop(i, acc):
        msg = jnp.clip(acc, -32767, 32767).astype(jnp.int16)  # int16 wire
        msg = lax.ppermute(msg, axis_name, perm)
        k = (idx - 2 - i) % n
        return msg.astype(jnp.int32) + jnp.take(x16, k, axis=0)

    acc = lax.fori_loop(0, n - 1, hop, acc) if n > 1 else acc
    return acc


def ring_reduce_scatter_int(x, mesh, axis_name: str, bits: int = 16):
    """Reduce-scatter x (replicated-shape per device) over `axis_name`,
    quantizing every wire message to int16.  Returns the per-device shard of
    the mean, fp32.
    """
    n = mesh.shape[axis_name]
    shift = max(0, math.ceil(math.log2(max(n, 1))))

    def f(xl):
        flat = xl.reshape(-1)
        pad = -flat.size % n
        flat = jnp.pad(flat, (0, pad))
        chunks = flat.reshape(n, -1)
        amax = lax.pmax(jnp.max(jnp.abs(chunks)), axis_name)
        safe = jnp.where(amax > 0, amax, 1.0)
        scale = jnp.exp2(jnp.ceil(jnp.log2(safe))) * 2.0 ** (
            1 - bits + shift)
        q = jnp.clip(jnp.round(chunks / scale), -32767, 32767).astype(
            jnp.int16)
        acc = _ring_reduce_scatter(q, axis_name, n)
        return acc.astype(jnp.float32) * scale / n

    spec = P(*((None,) * x.ndim))
    fn = _shard_map(f, mesh=mesh, in_specs=(spec,),
                    out_specs=P(axis_name), check_vma=False)
    return fn(x)


def compressed_psum_int(x, mesh, axis_name: str, bits: int = 16):
    """int16-wire all-reduce mean = ring reduce-scatter + all-gather."""
    n = mesh.shape[axis_name]
    shift = max(0, math.ceil(math.log2(max(n, 1))))

    def f(xl):
        shape = xl.shape
        flat = xl.reshape(-1)
        pad = -flat.size % n
        flat = jnp.pad(flat, (0, pad))
        chunks = flat.reshape(n, -1)
        amax = lax.pmax(jnp.max(jnp.abs(chunks)), axis_name)
        safe = jnp.where(amax > 0, amax, 1.0)
        scale = jnp.exp2(jnp.ceil(jnp.log2(safe))) * 2.0 ** (
            1 - bits + shift)
        q = jnp.clip(jnp.round(chunks / scale), -32767, 32767).astype(
            jnp.int16)
        acc = _ring_reduce_scatter(q, axis_name, n)
        # all-gather the reduced chunks; rank i holds chunk i so rank order
        # IS chunk order
        gathered = lax.all_gather(acc, axis_name, axis=0)  # (n, chunk)
        full = gathered.reshape(-1)
        full = full[: flat.size - pad] if pad else full
        return (full.astype(jnp.float32) * scale / n).reshape(shape)

    spec = P(*((None,) * x.ndim))
    fn = _shard_map(f, mesh=mesh, in_specs=(spec,), out_specs=spec,
                    check_vma=False)
    return fn(x)
