"""Integer gradient compression collectives (shard_map + ppermute ring).

The paper's CQ already puts weight gradients on a 15-bit grid with a shared
power-of-two scale — so the gradient wire format can be an integer QTensor
(int16 halves f32 traffic, int8 quarters it) with NO extra information loss
beyond what WAGEUBN's own optimizer quantization discards.  We implement the
ring reduce-scatter manually so every hop's message really is the integer
payload on the wire (XLA's native all-reduce would keep the accumulator
dtype on the wire).

The wire format IS a QTensor: `_wire_quantize` decomposes the local chunks
once into (int payload, shared pow2 scale) and the ring ships the payload;
`wire_quantize` is exported for tests and for QTensor-native callers that
want to hand the payload to other transports.

Overflow control: with n contributions, partial sums of b-bit operands need
b + ceil(log2 n) bits; `wire_quantize` pre-shifts the grid by `shift` and
clips payloads to `wire_limit(bits, shift)` = 2^(bits-1-shift) - 1, so ANY
partial sum of up to 2^shift payloads stays strictly inside the signed wire
width (tests/test_qtensor.py proves the bound by property for n <= 256).
The discarded low bits are below CQ's own grid once divided by n —
documented trade-off.

Staged widening (`wire_plan`): when the fan-in bound fails (shift > bits-2,
e.g. 4-bit wires at dp*n_shards >= 8), the payload keeps (nearly) full
`bits`-bit resolution and the partial sums ride int16 hops instead — the
exact-integer-sum guarantee is unchanged, only the hop dtype widens.  A
hard error remains only when even int16 cannot carry the fan-in
(shift > 14, i.e. > 16384-way sums).

Two layers of API:

  outer wrappers (`compressed_psum_int`, `ring_reduce_scatter_int`) own
  their shard_map — drop-in collectives for replicated callers.

  in-body primitives (`ring_allreduce_int`, `wire_sync_mean`,
  `wire_sync_tree`) run INSIDE an enclosing shard_map (the sharded training
  step, launch/train.py): the caller already holds per-device values and an
  axis name.  `wire_sync_mean` is the per-leaf DP-invariant gradient sync
  (DESIGN.md §9): payload rounding happens per VIRTUAL shard against a
  globally pmax'ed pow2 scale with a shift derived from the STATIC shard
  count, and every cross-device reduction is an exact integer sum — so the
  result is bitwise independent of how the virtual shards are laid out over
  devices.  `wire_sync_tree` is the same algorithm restructured for
  wall-clock (DESIGN.md §13): one stacked pmax for all leaves, the payload
  round/clip fused into the local pre-sum, and a single double-buffered
  ring over the concatenated pre-sums whose int8 hops pack two-per-int16 —
  bitwise identical outputs, a fraction of the collectives.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import SHARD_MAP_KW as _SM_KW
from repro.compat import shard_map as _shard_map
from repro.core import qfuncs as qf
from repro.core.qtensor import QTensor, payload_dtype


def wire_shift(n: int) -> int:
    """Grid pre-shift covering n-way partial sums: ceil(log2 n)."""
    return max(0, math.ceil(math.log2(max(n, 1))))


def wire_limit(bits: int, shift: int) -> float:
    """Largest payload magnitude such that any partial sum of up to 2^shift
    payloads stays strictly inside the signed `bits`-wide wire dtype.

    Raises when the wire is too narrow to carry ANY signal at that fan-in
    (shift > bits - 2, e.g. 256-way sums on an int8 wire): silently clipping
    every payload to zero would be a correctness bug dressed as compression.
    """
    if shift > bits - 2:
        raise ValueError(
            f"{bits}-bit wire cannot carry {2 ** shift}-way partial sums "
            f"(need shift <= bits - 2 = {bits - 2}, got {shift}); "
            f"wire_plan() stages such fan-ins onto int16 hops instead")
    return 2.0 ** (bits - 1 - shift) - 1.0


def wire_plan(bits: int, shift: int) -> tuple[int, int]:
    """Resolve how `bits`-bit payloads survive a 2^shift-way fan-in.

    Returns (clip_shift, hop_bits):

      classic   — shift <= bits - 2: the grid pre-shift is absorbed by the
        payload clip (`wire_limit(bits, shift)`) and partial sums ride hops
        of the payload width itself (hop_bits == bits).
      staged widening — narrow wires at large fan-in (e.g. 4-bit payloads
        summed 8-way) would otherwise clip every payload to zero.  Instead
        the payload keeps full `bits`-bit resolution minus only what int16
        cannot absorb (clip_shift = max(0, shift + bits - 16)) and the
        partial sums ride int16 hops: |payload| <= 2^(bits-1-clip_shift)-1,
        so any sum of up to 2^shift payloads is < 2^15 - exact on an int16
        hop, and < 2^24 so the f32 pre-sum accumulation is also exact.

    Raises only when int16 hops cannot carry the fan-in either
    (clip_shift > bits - 2, i.e. shift > 14).
    """
    if shift <= bits - 2:
        return shift, bits
    clip_shift = max(0, shift + bits - 16)
    if clip_shift > bits - 2:
        raise ValueError(
            f"{bits}-bit payloads cannot survive {2 ** shift}-way partial "
            f"sums even on an int16 hop (needs shift <= 14, got {shift})")
    return clip_shift, 16


def _clip_limit_f32(bits: int, shift: int) -> np.float32:
    """wire_limit as an f32 clip bound that never exceeds the true bound.

    The clip runs in f32, where wide limits (bits=32) are not exactly
    representable — 2^30 - 1 would round UP to 2^30 and let payloads
    escape the partial-sum bound — so the bound is lowered to the nearest
    f32 at or below it (identical for bits <= 24).
    """
    lim = wire_limit(bits, shift)
    limf = np.float32(lim)
    if float(limf) > lim:                  # f32 rounded up: step back one ulp
        limf = np.nextafter(limf, np.float32(0.0), dtype=np.float32)
    return limf


def wire_quantize(chunks, amax, bits: int, shift: int) -> QTensor:
    """Decompose gradient chunks into the integer wire QTensor.

    scale = pow2_ceil(amax) * 2^(1 - bits + clip_shift): the effective
    pre-shift (`wire_plan` — the full `shift` on the classic path, the
    int16-staged remainder otherwise) keeps n-way partial sums inside the
    HOP width (payloads clip to `wire_limit(bits, clip_shift)`, so the
    bound holds even at the saturate-at-pow2-amax corner).  `amax` must
    already be the global max across participating shards (pmax'ed by the
    caller).
    """
    clip_shift, _ = wire_plan(bits, shift)
    limf = _clip_limit_f32(bits, clip_shift)
    scale = qf.pow2_ceil(amax) * 2.0 ** (1 - bits + clip_shift)
    data = jnp.clip(jnp.round(chunks / scale), -limf,
                    limf).astype(payload_dtype(bits))
    return QTensor(data, scale, bits)


def wire_presum(g, amax, bits: int, shift: int):
    """Fused payload round/clip + local pre-sum — no payload tensor.

    Same grid and clip as `wire_quantize` over g: (vs_local, *shape), but
    the per-shard integer payloads are summed over axis 0 IN the producing
    expression: round and clip feed the reduction directly, so no
    (vs_local, *shape) integer tensor is ever materialized (XLA fuses
    elementwise producers into reductions; the jaxpr acceptance test in
    tests/test_sharded_train.py checks no such tensor exists).

    Exactness: rounded/clipped payloads are integers with magnitude
    <= 2^(bits-1-clip_shift), and summing up to 2^shift of them stays
    below 2^(hop_bits-1) (wire_plan's invariant, classic or staged).  For
    bits <= 16 that is < 2^24, exactly representable in f32, so the f32
    accumulation equals the integer sum bit for bit.  Wider wires can pass
    2^24, where f32 addition rounds — those sum the materialized int32
    payload instead (same values, exact by dtype).

    Returns (int32 pre-sum of shape g.shape[1:], pow2 wire scale).
    """
    clip_shift, _ = wire_plan(bits, shift)
    limf = _clip_limit_f32(bits, clip_shift)
    scale = qf.pow2_ceil(amax) * 2.0 ** (1 - bits + clip_shift)
    vals = jnp.clip(jnp.round(g / scale), -limf, limf)
    if bits > 16:
        return jnp.sum(vals.astype(jnp.int32), axis=0), scale
    return jnp.sum(vals, axis=0).astype(jnp.int32), scale


def pack_int8_pairs(x):
    """Pack consecutive int8 pairs two-per-int16 (the wire-bits=8 codec).

    x: (..., 2m) int8 -> (..., m) int16 with element i carrying
    (x[2i] in the low byte, x[2i+1] in the high byte).  The low byte rides
    as its two's-complement bit pattern (uint8 view), so every value
    including -128 round-trips exactly through `unpack_int16_pairs`.
    """
    lo = x[..., 0::2].astype(jnp.uint8).astype(jnp.int16)
    hi = x[..., 1::2].astype(jnp.int16) << 8
    return hi | lo


def unpack_int16_pairs(p):
    """Inverse of `pack_int8_pairs`: (..., m) int16 -> (..., 2m) int8.

    Low byte recovers through the uint8 view (wrap-on-cast restores the
    sign, -128 included); high byte through an arithmetic shift.
    """
    lo = (p & 0xFF).astype(jnp.uint8).astype(jnp.int8)
    hi = (p >> 8).astype(jnp.int8)
    return jnp.stack([lo, hi], axis=-1).reshape(p.shape[:-1] + (-1,))


def _ring_reduce_scatter(qt: QTensor, axis_name, n, hop_bits: int | None = None):
    """qt.data: (n, chunk) integer contributions per rank.

    Classic ring: rank r starts with its contribution to chunk (r-1)%n and
    after n-1 hops holds the fully reduced chunk r.  Every message on the
    wire is the `hop_bits` integer dtype (default: the payload width;
    staged widening passes 16 to carry sub-8 payload sums), never fp32.
    """
    x_int = qt.data
    hop_bits = qt.k if hop_bits is None else hop_bits
    # clip in the int32 domain: float bounds near 2^31 are not exactly
    # representable in f32 and would promote the accumulator
    lim = jnp.asarray(min(2 ** (hop_bits - 1) - 1, 2 ** 31 - 1), jnp.int32)
    dtype = payload_dtype(hop_bits)
    idx = lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    acc = jnp.take(x_int, (idx - 1) % n, axis=0).astype(jnp.int32)

    def hop(i, acc):
        msg = jnp.clip(acc, -lim, lim).astype(dtype)   # integer wire
        msg = lax.ppermute(msg, axis_name, perm)
        k = (idx - 2 - i) % n
        return msg.astype(jnp.int32) + jnp.take(x_int, k, axis=0)

    acc = lax.fori_loop(0, n - 1, hop, acc) if n > 1 else acc
    return acc


def ring_reduce_scatter_int(x, mesh, axis_name: str, bits: int = 16):
    """Reduce-scatter x (replicated-shape per device) over `axis_name`,
    quantizing every wire message to the `bits`-wide integer payload.
    Returns the per-device shard of the mean, fp32.
    """
    n = mesh.shape[axis_name]
    shift = wire_shift(n)
    _, hop_bits = wire_plan(bits, shift)

    def f(xl):
        flat = xl.reshape(-1)
        pad = -flat.size % n
        flat = jnp.pad(flat, (0, pad))
        chunks = flat.reshape(n, -1)
        amax = lax.pmax(jnp.max(jnp.abs(chunks)), axis_name)
        qt = wire_quantize(chunks, amax, bits, shift)
        acc = _ring_reduce_scatter(qt, axis_name, n, hop_bits)
        return acc.astype(jnp.float32) * qt.scale / n

    spec = P(*((None,) * x.ndim))
    fn = _shard_map(f, mesh=mesh, in_specs=(spec,),
                    out_specs=P(axis_name), **_SM_KW)
    return fn(x)


def compressed_psum_int(x, mesh, axis_name: str, bits: int = 16):
    """integer-wire all-reduce mean = ring reduce-scatter + all-gather."""
    n = mesh.shape[axis_name]
    shift = wire_shift(n)
    _, hop_bits = wire_plan(bits, shift)

    def f(xl):
        shape = xl.shape
        flat = xl.reshape(-1)
        pad = -flat.size % n
        flat = jnp.pad(flat, (0, pad))
        chunks = flat.reshape(n, -1)
        amax = lax.pmax(jnp.max(jnp.abs(chunks)), axis_name)
        qt = wire_quantize(chunks, amax, bits, shift)
        acc = _ring_reduce_scatter(qt, axis_name, n, hop_bits)
        # all-gather the reduced chunks; rank i holds chunk i so rank order
        # IS chunk order
        gathered = lax.all_gather(acc, axis_name, axis=0)  # (n, chunk)
        full = gathered.reshape(-1)
        full = full[: flat.size - pad] if pad else full
        return (full.astype(jnp.float32) * qt.scale / n).reshape(shape)

    spec = P(*((None,) * x.ndim))
    fn = _shard_map(f, mesh=mesh, in_specs=(spec,), out_specs=spec,
                    **_SM_KW)
    return fn(x)


# --------------------------------------------------------------------------
# in-body primitives (run INSIDE an enclosing shard_map)
# --------------------------------------------------------------------------


def ring_allreduce_int(x, axis_name: str, n: int, bits: int, *,
                       pack: bool = False, buckets: int = 1):
    """Exact integer all-reduce-sum of per-device int32 contributions.

    Ring reduce-scatter (messages in the `bits`-wide wire dtype) followed by
    an integer all-gather.  The caller guarantees every partial sum fits the
    wire width — the contract `wire_quantize` establishes via its shift/clip
    — so the per-hop dtype cast never wraps and the sum is exact.  Must run
    inside shard_map with `axis_name` manual; `n` is the axis size.

    `bits` is the HOP width — the payload width on the classic path,
    16 when `wire_plan` staged a narrower payload onto int16 hops.

    pack (int8-dtype hops, i.e. bits <= 8): consecutive int8 payload pairs
    ride two-per-int16, halving each hop's on-wire message element count —
    pack/unpack is a lossless bit-pattern transform, so the sum is
    unchanged.  buckets=2 double-buffers the ring: each chunk splits in
    two and BOTH buckets' ppermutes are issued before either received
    message is consumed, so a hop's send overlaps the other bucket's
    accumulate (and gives the compiler two in-flight transfers to overlap
    with whatever compute surrounds the sync).  Bucket order is restored
    before the all-gather — the reduced values are identical for any
    bucket count.
    """
    assert not (pack and bits > 8), "pair packing needs int8-dtype hops"
    dtype = payload_dtype(bits)
    shape = x.shape
    flat = x.reshape(-1)
    unit = n * buckets * (2 if pack else 1)
    pad = -flat.size % unit
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, buckets, -1)   # chunk r = buckets row-slices
    idx = lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    start = jnp.take(chunks, (idx - 1) % n, axis=0).astype(jnp.int32)
    accs = tuple(start[b] for b in range(buckets))

    def to_wire(a):
        a = a.astype(dtype)
        return pack_int8_pairs(a) if pack else a

    def from_wire(m):
        return (unpack_int16_pairs(m) if pack else m).astype(jnp.int32)

    def hop(i, accs):
        # double-buffered: every bucket's ppermute is issued before any
        # received message feeds an add
        msgs = [lax.ppermute(to_wire(a), axis_name, perm) for a in accs]
        nxt = jnp.take(chunks, (idx - 2 - i) % n, axis=0)
        return tuple(from_wire(m) + nxt[b] for b, m in enumerate(msgs))

    accs = lax.fori_loop(0, n - 1, hop, accs) if n > 1 else accs
    acc = (jnp.concatenate([a.reshape(-1) for a in accs])
           if buckets > 1 else accs[0].reshape(-1))
    full = lax.all_gather(acc, axis_name, axis=0).reshape(-1)
    full = full[: flat.size - pad] if pad else full
    return full.reshape(shape)


def wire_sync_mean(g, axis_name: str, *, n_shards: int, n_dev: int,
                   bits: int = 16):
    """DP-invariant integer-wire mean of per-virtual-shard contributions.

    g: (vs_local, *shape) f32 — this device's virtual-shard gradient
    contributions.  Returns (*shape,) f32: the mean over all `n_shards`
    virtual shards across the `axis_name` axis (size `n_dev`).

    Bit-exactness contract (DESIGN.md §9): the ONE cross-device scale
    reduction is the lax.pmax on the shard-local amax; payload rounding
    happens per VIRTUAL shard against that shared pow2 scale with
    shift = ceil(log2 n_shards) (a STATIC property of the algorithm, not of
    the device layout), and both the local pre-sum and the ring are exact
    integer additions.  Every quantity is therefore a pure function of
    (n_shards, global batch) — how the virtual shards map onto devices
    cannot change a single bit of the result.
    """
    shift = wire_shift(n_shards)
    _, hop_bits = wire_plan(bits, shift)
    amax = lax.pmax(jnp.max(jnp.abs(g)), axis_name)
    qt = wire_quantize(g, amax, bits, shift)
    local = jnp.sum(qt.data.astype(jnp.int32), axis=0)
    total = ring_allreduce_int(local, axis_name, n_dev, hop_bits)
    return total.astype(jnp.float32) * qt.scale / n_shards


def wire_sync_tree(grads, axis_name: str, *, n_shards: int, n_dev: int,
                   bits: int = 16):
    """Whole-tree integer-wire gradient sync — the packed wire codec.

    Value-identical to mapping `wire_sync_mean` over the tree (same amax,
    same grid, same exact integer sums — tests prove bitwise equality),
    but shaped for wall-clock instead of per-leaf dispatch:

      * ONE stacked scale reduction: every leaf's local amax pmaxes in a
        single (n_leaves,)-shaped collective instead of n_leaves scalar
        pmaxes (pmax is elementwise, so each lane equals its scalar run).
      * fused pre-sum (`wire_presum`): each leaf's payload round/clip
        feeds its local shard-sum directly — no per-shard integer payload
        tensor is materialized.
      * ONE ring: the int32 pre-sums concatenate into a flat buffer that
        rides a single double-buffered ring + all-gather — 2(n_dev-1)
        ppermutes and one gather per STEP, not per leaf.  At wire-bits=8
        the hop messages pack two-per-int16 (`pack_int8_pairs`), halving
        the on-wire element count.

    grads: pytree of (vs_local, *shape) f32 per-virtual-shard sums.
    Returns the matching pytree of (*shape,) f32 means over all
    `n_shards` virtual shards.
    """
    leaves, treedef = jax.tree.flatten(grads)
    if not leaves:
        return grads
    shift = wire_shift(n_shards)
    _, hop_bits = wire_plan(bits, shift)
    amax = lax.pmax(
        jnp.stack([jnp.max(jnp.abs(g)) for g in leaves]), axis_name)
    presums, scales, shapes = [], [], []
    for i, g in enumerate(leaves):
        ps, scale = wire_presum(g, amax[i], bits, shift)
        presums.append(ps.reshape(-1))
        scales.append(scale)
        shapes.append(ps.shape)
    flat = (jnp.concatenate(presums) if len(presums) > 1 else presums[0])
    total = ring_allreduce_int(flat, axis_name, n_dev, hop_bits,
                               pack=(hop_bits <= 8),
                               buckets=2 if n_dev > 1 else 1)
    outs, off = [], 0
    for shape, scale in zip(shapes, scales):
        size = int(np.prod(shape)) if shape else 1
        seg = total[off:off + size]
        # same float expression as wire_sync_mean -> bitwise-equal means
        outs.append((seg.astype(jnp.float32) * scale
                     / n_shards).reshape(shape))
        off += size
    return jax.tree.unflatten(treedef, outs)


def default_wire_codec(backend: str | None = None) -> tuple[str, str]:
    """Backend-aware `--wire-codec auto` resolution.  Returns (codec, why).

    The packed whole-tree codec halves on-wire elements and issues 2
    ppermutes/step — a win where transfers are real DMAs (TPU) — but on the
    CPU backend XLA serializes ppermutes, so the single big packed ring
    wall-clocks SLOWER than per-leaf rings even as the wire work halves
    (the measured PR 9 caveat, BENCH_train train/wire_codec).  Both codecs
    are bitwise-identical, so the default can follow the backend freely.
    """
    backend = backend or jax.default_backend()
    if backend == "tpu":
        return "packed", "tpu: 2x fewer on-wire elements, 2 ppermutes/step"
    return "leaf", (f"{backend}: serialized ppermutes make the packed "
                    "single-ring slower than per-leaf rings")
