"""Fault tolerance primitives: watchdog, simple auto-resume train runner.

  * StepWatchdog — tracks per-step wall times over a bounded rolling
    window; flags stragglers by a deadline policy (median * factor).  The
    serving engine times every fused decode step through the same watchdog:
    flagged steps log here and surface as `straggler_steps` in
    `serving.Engine.metrics()` (DESIGN.md §7).  `reset()` clears the stats
    when the step-time baseline legitimately changes (e.g. after an elastic
    reshard moves virtual shards across devices).
  * TrainRunner — wraps the jitted step in a crash/restart loop: on ANY
    exception it restores the latest checkpoint and continues.  Combined
    with deterministic data + stochastic-rounding keys derived from the step
    counter, a restart reproduces the exact same trajectory (tested).
  * SimulatedFailure — fault-injection hook for tests/chaos drills.

The straggler-eviction / membership-change control plane these primitives
were designed for is implemented in `runtime/elastic.py` (ElasticRunner,
DESIGN.md §11): it composes this watchdog with the sharded train step and
the QTensor-native checkpoint layer into bit-exact preemption recovery and
DP reshard.  TrainRunner remains the single-device (unsharded-step) loop.
"""
from __future__ import annotations

import logging
import time
from typing import Callable

log = logging.getLogger("repro.runtime")


class SimulatedFailure(RuntimeError):
    pass


class StepWatchdog:
    def __init__(self, factor: float = 3.0, warmup: int = 5,
                 window: int = 256):
        self.factor = factor
        self.warmup = warmup
        self.window = window
        self.times: list[float] = []
        self.flags: list[int] = []

    def reset(self):
        """Clear the timing stats (keeps config).  Call when the step-time
        baseline legitimately changes — e.g. after an elastic reshard — so
        the next steps are not judged against the old layout's median."""
        self.times.clear()
        self.flags.clear()

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler by the deadline policy.

        The history is a rolling window of the last `window` step times —
        long runs neither grow memory without bound nor freeze the median
        on ancient steps."""
        self.times.append(dt)
        if len(self.times) > self.window:
            del self.times[: len(self.times) - self.window]
        if len(self.times) <= self.warmup:
            return False
        hist = sorted(self.times[:-1])
        median = hist[len(hist) // 2]
        if dt > self.factor * median:
            self.flags.append(step)
            log.warning("straggler: step %d took %.3fs (median %.3fs)",
                        step, dt, median)
            return True
        return False


class TrainRunner:
    """Checkpoint/restart training loop with fault injection hooks."""

    def __init__(self, step_fn: Callable, ckpt, save_every: int = 50,
                 max_restarts: int = 10, watchdog: StepWatchdog | None = None):
        self.step_fn = step_fn              # (state, step) -> (state, metrics)
        self.ckpt = ckpt                    # CheckpointManager over `state`
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.watchdog = watchdog or StepWatchdog()
        self.restarts = 0

    def run(self, state, n_steps: int, start_step: int = 0,
            fail_at: int | None = None):
        """Runs to n_steps; restores+retries on failure.  Returns state."""
        step = start_step
        metrics = None
        while step < n_steps:
            try:
                while step < n_steps:
                    t0 = time.time()
                    if fail_at is not None and step == fail_at:
                        fail_at = None      # fail exactly once
                        raise SimulatedFailure(f"injected at step {step}")
                    state, metrics = self.step_fn(state, step)
                    self.watchdog.observe(step, time.time() - t0)
                    step += 1
                    if step % self.save_every == 0 or step == n_steps:
                        self.ckpt.save(step, state)
            except Exception as e:  # noqa: BLE001 — any fault triggers restart
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                log.warning("step %d failed (%s); restoring latest checkpoint",
                            step, e)
                try:
                    state, step, _ = self.ckpt.restore(state)
                except FileNotFoundError:
                    step = start_step       # no checkpoint yet: cold restart
        self.ckpt.wait()
        return state, metrics
