"""Assigned architecture configs (exact, from the assignment block) + the
paper's own ResNets.  `get(name)` returns the full ArchConfig; `--arch <id>`
in the launchers resolves through ARCHS.
"""
from .base import ArchConfig, LM_SHAPES
from .chameleon_34b import CFG as chameleon_34b
from .granite_moe_1b_a400m import CFG as granite_moe_1b_a400m
from .moonshot_v1_16b_a3b import CFG as moonshot_v1_16b_a3b
from .granite_3_8b import CFG as granite_3_8b
from .phi4_mini_3_8b import CFG as phi4_mini_3_8b
from .minitron_4b import CFG as minitron_4b
from .granite_34b import CFG as granite_34b
from .falcon_mamba_7b import CFG as falcon_mamba_7b
from .zamba2_7b import CFG as zamba2_7b
from .seamless_m4t_large_v2 import CFG as seamless_m4t_large_v2
from .resnets import RESNET18, RESNET34, RESNET50

ARCHS = {
    c.name: c for c in [
        chameleon_34b, granite_moe_1b_a400m, moonshot_v1_16b_a3b,
        granite_3_8b, phi4_mini_3_8b, minitron_4b, granite_34b,
        falcon_mamba_7b, zamba2_7b, seamless_m4t_large_v2,
        RESNET18, RESNET34, RESNET50,
    ]
}

ASSIGNED = [
    "chameleon-34b", "granite-moe-1b-a400m", "moonshot-v1-16b-a3b",
    "granite-3-8b", "phi4-mini-3.8b", "minitron-4b", "granite-34b",
    "falcon-mamba-7b", "zamba2-7b", "seamless-m4t-large-v2",
]


def get(name: str) -> ArchConfig:
    return ARCHS[name]


__all__ = ["ArchConfig", "LM_SHAPES", "ARCHS", "ASSIGNED", "get"]
