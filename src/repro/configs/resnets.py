"""The paper's own models: ResNet18/34/50 (ImageNet scale)."""
from .base import ArchConfig

RESNET18 = ArchConfig(name="resnet18", family="resnet", block="basic",
                      stage_sizes=(2, 2, 2, 2), num_classes=1000,
                      img_size=224, shapes=())
RESNET34 = ArchConfig(name="resnet34", family="resnet", block="basic",
                      stage_sizes=(3, 4, 6, 3), num_classes=1000,
                      img_size=224, shapes=())
RESNET50 = ArchConfig(name="resnet50", family="resnet", block="bottleneck",
                      stage_sizes=(3, 4, 6, 3), num_classes=1000,
                      img_size=224, shapes=())
