"""seamless-m4t-large-v2 [audio]: 24L d_model=1024 16H (GQA kv=16) d_ff=8192
vocab=256206 — enc-dec, multimodal [arXiv:2308.11596; hf].

Backbone only: the audio frontend is a stub (input_specs provides
precomputed frame embeddings).  Enc-dec (NOT encoder-only) => decode shapes
run; long_500k skipped (full attention).  24L = 24 encoder + 24 decoder
layers (the v2 backbone splits; recorded in DESIGN.md).
"""
from .base import ArchConfig

CFG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=0, d_model=1024, n_heads=16, n_kv=16, d_ff=8192,
    vocab=256206, head_dim=64, norm="layernorm", act="gelu",
    enc_layers=24, dec_layers=24, tgt_ratio=4,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes={"long_500k": "pure full attention (quadratic): skipped"},
    source="arXiv:2308.11596; hf",
)
