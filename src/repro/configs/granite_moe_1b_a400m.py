"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32e top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from .base import ArchConfig

CFG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv=8, d_ff=512,
    vocab=49155, head_dim=64, norm="rmsnorm", act="silu",
    moe_experts=32, moe_topk=8,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes={"long_500k": "pure full attention (quadratic): skipped"},
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
