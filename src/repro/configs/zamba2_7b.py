"""zamba2-7b [hybrid]: 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks
[arXiv:2411.15242; unverified].

Hybrid: decode attention over the shared-block KV cache is O(S) per token
(sub-quadratic) -> long_500k runs.
"""
from .base import ArchConfig

CFG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv=32, d_ff=14336,
    vocab=32000, head_dim=112, norm="rmsnorm", act="silu",
    ssm_state=64, ssm_kind="mamba2", d_conv=4, expand=2, headdim=64,
    attn_every=6,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    source="arXiv:2411.15242; unverified",
)
