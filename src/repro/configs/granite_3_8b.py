"""granite-3-8b [dense]: 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155 — GQA [hf:ibm-granite/granite-3.0-2b-base; hf]."""
from .base import ArchConfig

CFG = ArchConfig(
    name="granite-3-8b", family="lm",
    n_layers=40, d_model=4096, n_heads=32, n_kv=8, d_ff=12800,
    vocab=49155, head_dim=128, norm="rmsnorm", act="silu",
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes={"long_500k": "pure full attention (quadratic): skipped"},
    source="hf:ibm-granite/granite-3.0-2b-base; hf",
)
