"""Architecture configuration schema + the shape sets assigned to this paper."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


# Assigned LM shape set: name -> (seq_len, global_batch, kind)
# kind: "train" lowers train_step; "decode" lowers serve_step (one token,
# KV cache of seq_len); "prefill" lowers train-like forward (no loss bwd? —
# prefill is inference forward: lowered as serve prefill over seq_len).
LM_SHAPES = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # lm | moe | ssm | hybrid | encdec | resnet
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv: int = 0
    d_ff: int = 0
    vocab: int = 0
    head_dim: int = 0            # 0 -> d_model // n_heads
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "silu"
    rope_theta: float = 1e4

    # MoE
    moe_experts: int = 0
    moe_topk: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba1/mamba2)
    ssm_state: int = 0
    ssm_kind: str = ""           # mamba1 | mamba2
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64            # mamba2 head dim

    # hybrid (zamba2): shared attention block every `attn_every` layers
    attn_every: int = 0

    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0
    tgt_ratio: int = 4           # tgt_len = seq_len // tgt_ratio

    # resnet
    block: str = ""              # basic | bottleneck
    stage_sizes: tuple = ()
    num_classes: int = 1000
    img_size: int = 224

    # which assigned shapes run (others are recorded skips, see DESIGN.md §6)
    shapes: tuple = ("train_4k", "prefill_32k", "decode_32k")
    skip_notes: dict = field(default_factory=dict, hash=False, compare=False)

    # attention chunking (memory control for 32k prefill)
    q_chunk: int = 1024
    kv_chunk: int = 512
    # SSM sequence-chunk size (mamba1 associative-scan / mamba2 SSD chunks)
    scan_chunk: int = 256
    # unroll scan-over-layers (cost-analysis compiles only: XLA counts while
    # bodies once, so exact FLOP/byte accounting needs unrolled layers)
    unroll_layers: bool = False
    # remat policy for scan-over-layers: "full" (checkpoint every layer)
    # or "none" (save everything; trades HBM for recompute, §Perf)
    remat: str = "full"
    # unroll the SSM chunk scans (cost-analysis compiles: exact counting
    # without the giant single-chunk masks that stall constant folding)
    unroll_scan_chunks: bool = False

    source: str = ""

    @property
    def dh(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def vocab_padded(self) -> int:
        """vocab padded to a multiple of 512 for TP divisibility."""
        return ((self.vocab + 511) // 512) * 512

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2), d_model=64,
            n_heads=4, n_kv=min(self.n_kv, 2) if self.n_kv else 0,
            d_ff=96 if self.d_ff else 0, vocab=min(self.vocab, 128),
            head_dim=16, q_chunk=16, kv_chunk=16,
        )
        if self.moe_experts:
            kw.update(moe_experts=4, moe_topk=2)
        if self.ssm_state:
            kw.update(ssm_state=4, headdim=8)
        if self.attn_every:
            kw.update(attn_every=1, n_layers=2)
        if self.enc_layers:
            kw.update(enc_layers=2, dec_layers=2)
        if self.family == "resnet":
            kw = dict(stage_sizes=(1, 1), num_classes=10, img_size=16)
        return self.replace(name=self.name + "-smoke", **kw)
