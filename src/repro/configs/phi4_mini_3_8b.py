"""phi4-mini-3.8b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064 — RoPE SwiGLU GQA [arXiv:2412.08905; hf]."""
from .base import ArchConfig

CFG = ArchConfig(
    name="phi4-mini-3.8b", family="lm",
    n_layers=32, d_model=3072, n_heads=24, n_kv=8, d_ff=8192,
    vocab=200064, head_dim=128, norm="rmsnorm", act="silu",
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes={"long_500k": "pure full attention (quadratic): skipped"},
    source="arXiv:2412.08905; hf",
)
