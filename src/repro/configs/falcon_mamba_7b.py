"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attn-free) d_ff=0 vocab=65024,
ssm_state=16 — mamba1 arch [arXiv:2410.05355; unverified].

Sub-quadratic: runs all four shapes including long_500k (O(1) decode state).
"""
from .base import ArchConfig

CFG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, d_ff=0, vocab=65024,
    ssm_state=16, ssm_kind="mamba1", d_conv=4, expand=2,
    norm="rmsnorm",
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    source="arXiv:2410.05355; unverified",
)
