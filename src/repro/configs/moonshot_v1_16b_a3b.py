"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64e top-6 — kimi/moonlight
[hf:moonshotai/Moonlight-16B-A3B; hf]."""
from .base import ArchConfig

CFG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv=16, d_ff=1408,
    vocab=163840, head_dim=128, norm="rmsnorm", act="silu",
    moe_experts=64, moe_topk=6,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes={"long_500k": "pure full attention (quadratic): skipped"},
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
)
