"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 — early-fusion, VQ image tokens [arXiv:2405.09818; unverified].

Frontend stub: VQ image tokens are vocabulary entries, so input_specs()
provides token ids directly (DESIGN.md §6).  long_500k skipped: pure full
attention (quadratic) — recorded skip.
"""
from .base import ArchConfig

CFG = ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv=8, d_ff=22016,
    vocab=65536, head_dim=128, norm="rmsnorm", act="silu",
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes={"long_500k": "pure full attention (quadratic): skipped"},
    source="arXiv:2405.09818; unverified",
)
