"""granite-34b [dense]: 88L d_model=6144 48H (GQA kv=1 = MQA) d_ff=24576
vocab=49152 — llama-arch, code [arXiv:2405.04324; hf]."""
from .base import ArchConfig

CFG = ArchConfig(
    name="granite-34b", family="lm",
    n_layers=88, d_model=6144, n_heads=48, n_kv=1, d_ff=24576,
    vocab=49152, head_dim=128, norm="rmsnorm", act="silu",
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_notes={"long_500k": "pure full attention (quadratic): skipped"},
    source="arXiv:2405.04324; hf",
)
