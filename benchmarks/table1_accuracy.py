"""Paper Table I: vanilla FP32 vs full-8-bit WAGEUBN vs 16-bit-E2 WAGEUBN,
extended with the sub-8 / wide-gradient lanes (DESIGN.md §14).

Protocol (scaled to this CPU): reduced ResNet on the resolved image task —
the real npz pipeline when REPRO_DATA_DIR is set, the learnable synthetic
blobs otherwise — identical data/steps/seeds across numeric configs;
report held-out accuracy.  The paper's claim to validate: WAGEUBN trains
large nets to accuracy *competitive with* FP32, with 16-bit E2 >= full
8-bit; the lanes show how far below 8 bits each path degrades.
"""
from __future__ import annotations

from repro.core import preset

from .common import emit, steps_default, train_resnet


def main() -> dict:
    steps = steps_default(120)
    out = {}
    task = None
    data = "?"
    for name, qcfg in [("fp32", preset("fp32")),
                       ("wageubn-e2-16", preset("e2_16", "sim")),
                       ("wageubn-full8", preset("full8", "sim")),
                       ("wageubn-w4a8", preset("w4a8", "sim")),
                       ("wageubn-a4", preset("a4", "sim")),
                       ("wageubn-g16", preset("g16", "sim"))]:
        r = train_resnet(qcfg, steps, task=task)
        if task is None:              # resolve once, share across configs
            task, data = r["task"], r["data"]
        out[name] = r["acc"]
        emit(f"table1/{name}", r["wall_s"] / steps * 1e6,
             f"holdout_acc={r['acc']:.4f} data={data}")
    for name in ("full8", "w4a8", "a4", "g16", "e2-16"):
        gap = out["fp32"] - out[f"wageubn-{name}"]
        emit(f"table1/gap-{name}", 0.0, f"acc_gap_vs_fp32={gap:.4f}")
    return out


if __name__ == "__main__":
    main()
