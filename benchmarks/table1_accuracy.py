"""Paper Table I: vanilla FP32 vs full-8-bit WAGEUBN vs 16-bit-E2 WAGEUBN.

Protocol (scaled to this CPU): reduced ResNet on the learnable synthetic
image task, identical data/steps/seeds across numeric configs; report
held-out accuracy.  The paper's claim to validate: WAGEUBN trains large
nets to accuracy *competitive with* FP32, with 16-bit E2 >= full 8-bit.
"""
from __future__ import annotations

from repro.core import preset

from .common import emit, steps_default, train_resnet


def main() -> dict:
    steps = steps_default(120)
    out = {}
    for name, qcfg in [("fp32", preset("fp32")),
                       ("wageubn-e2-16", preset("e2_16", "sim")),
                       ("wageubn-full8", preset("full8", "sim"))]:
        r = train_resnet(qcfg, steps)
        out[name] = r["acc"]
        emit(f"table1/{name}", r["wall_s"] / steps * 1e6,
             f"holdout_acc={r['acc']:.4f}")
    gap8 = out["fp32"] - out["wageubn-full8"]
    gap16 = out["fp32"] - out["wageubn-e2-16"]
    emit("table1/gap-full8", 0.0, f"acc_gap_vs_fp32={gap8:.4f}")
    emit("table1/gap-e2-16", 0.0, f"acc_gap_vs_fp32={gap16:.4f}")
    return out


if __name__ == "__main__":
    main()
