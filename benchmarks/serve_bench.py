"""Serving benchmark: continuous-batching engine under open-loop Poisson
traffic at several arrival rates, vs the sequential naive baseline.

CSV rows (name,us_per_call,derived — `derived` is ';'-separated):
  serve/rate<r>  — us per fused decode step; decode tok/s, mean/max TTFT,
                   preemptions under rate r req/s
  serve/naive    — us per decode step of one-request-at-a-time serving
  serve/speedup  — engine-vs-naive aggregate decode tok/s ratio
  serve/pool     — int8-vs-fp32 footprint ratio + resident-seq capacity

Scale knobs: REPRO_BENCH_FAST halves the request count and drops the
highest rate; the arch is the reduced granite-3-8b (CPU scale).
"""
from __future__ import annotations

import os

from .common import emit


def main():
    import jax

    from repro.configs import get
    from repro.core import preset
    from repro.models import build_model
    from repro.serving import Engine, naive_serve, poisson_traffic, run_load

    fast = bool(os.environ.get("REPRO_BENCH_FAST"))
    n_requests = 6 if fast else 12
    rates = (4.0, 16.0) if fast else (4.0, 16.0, 64.0)
    gen_lens = (4, 8) if fast else (4, 8, 12)

    model = build_model(get("granite-3-8b").reduced(),
                        preset("full8", "native"))
    params = model.init(jax.random.PRNGKey(0))

    def traffic_at(rate):
        return poisson_traffic(rate=rate, n_requests=n_requests,
                               prompt_lens=(8, 16, 24), gen_lens=gen_lens,
                               vocab=128, seed=7)

    engine_tokps = 0.0
    pool_rep = None
    for rate in rates:
        engine = Engine(model, params, max_lanes=4, page_size=8, max_ctx=48)
        _, m = run_load(engine, traffic_at(rate))
        us = (m["decode_wall_s"] / max(1, m["decode_steps"])) * 1e6
        emit(f"serve/rate{rate:g}", us,
             f"tokps={m['decode_tok_s']:.2f};"
             f"ttft_ms_mean={m['ttft_mean_s'] * 1e3:.1f};"
             f"ttft_ms_max={m['ttft_max_s'] * 1e3:.1f};"
             f"steps={m['decode_steps']};preempt={m['preemptions']};"
             f"straggler={m['straggler_steps']}")
        engine_tokps = max(engine_tokps, m["decode_tok_s"])
        pool_rep = m.get("pool", pool_rep)

    _, nm = naive_serve(model, params, traffic_at(rates[0]))
    n_us = (nm["decode_wall_s"] / max(1, nm["decode_steps"])) * 1e6
    emit("serve/naive", n_us,
         f"tokps={nm['decode_tok_s']:.2f};steps={nm['decode_steps']}")
    emit("serve/speedup", 0.0,
         f"engine_vs_naive={engine_tokps / max(nm['decode_tok_s'], 1e-9):.2f}x")
    if pool_rep is not None:
        emit("serve/pool", 0.0,
             f"int8_vs_fp32={pool_rep['footprint_ratio']:.2f}x;"
             f"seqs_int8={pool_rep['capacity_seqs_int8']};"
             f"seqs_fp32={pool_rep['capacity_seqs_fp32']}")


if __name__ == "__main__":
    main()
