"""Serving benchmark: continuous-batching engine under open-loop Poisson
traffic at several arrival rates, vs the sequential naive baseline, plus
the fused-vs-unfused decode comparison (mirroring train_bench's fused
column: QConfig.fuse_kernels toggles the paged-attention route, bit-exact
either way, so the delta isolates the page-gather traffic the fused kernel
removes).

CSV rows (name,us_per_call,derived — `derived` is ';'-separated):
  serve/rate<r>         — us per fused decode step; decode tok/s, mean/max
                          + p50/p99 TTFT, p50/p99 TPOT, preemptions under
                          rate r req/s
  serve/rate<r>_chunked — same load through the chunked-prefill engine
                          (one jit-stable prefill trace for every prompt
                          length instead of a compile per length — the
                          TTFT lever)
  serve/ttft_breakdown  — TTFT split queue_ms vs prefill_ms at the middle
                          rate, one row per prefill mode (both polarities:
                          mode=monolithic and mode=chunked; CI greps both)
  serve/prefix_hit      — radix-cache sweep over sharing {0, 0.5, 0.9}:
                          hit_rate, tok/s, mean TTFT per sharing level
                          (CI greps the sharing=0 and sharing=0.9 rows)
  serve/sharded         — one row per (tp, dp) layout at the middle rate:
                          aggregate decode tok/s + p50/p99 TTFT and TPOT
                          through the shard_map'd engine (tp=2) and the
                          replica Router (dp=2); device-gated, so the
                          multi-device CI lane greps both tp polarities
  serve/naive           — us per decode step of one-request-at-a-time serving
  serve/speedup         — engine-vs-naive aggregate decode tok/s ratio
  serve/pool            — int8-vs-fp32 footprint ratio + resident-seq
                          capacity
  serve/fused_ctx<N>    — us per decode step at max_ctx=N, fused route
  serve/unfused_ctx<N>  — same engine load, gather-then-attend route
  serve/decode_fusion   — fused-vs-unfused step-time ratio at the largest
                          context config
  serve/decode_path     — fused_active=True/False per route, from the
                          decode-step jaxpr (CI fails on a silent fallback)

Scale knobs: REPRO_BENCH_FAST halves the request count and drops the
highest rate + largest context; the arch is the reduced granite-3-8b (CPU
scale).
"""
from __future__ import annotations

import os

from .common import emit, roofline_derived, step_cost

ARCH = "granite-3-8b"


def _decode_cost(eng) -> dict:
    """flops/bytes of the engine's fused decode step at its exact shapes
    (same fresh-wrapper trick as Engine.decode_jaxpr: never share the live
    _decode_jit's tracing cache)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    slots = dict(eng.slots, pos=jnp.zeros((eng.max_lanes,), jnp.int32))
    if eng.paged:
        kp, vp = eng.pool.k, eng.pool.v
    else:
        kp = jnp.zeros((0,), jnp.int8)
        vp = jnp.zeros((0,), jnp.int8)
    fn = jax.jit(lambda *a: eng._decode_step(*a))
    return step_cost(fn, eng.params, slots, kp, vp, jnp.asarray(eng.table),
                     jnp.asarray(eng.h_tokens), np.int32(0))


def _measure_decode(engine, n_lanes: int, prompt_len: int, max_new: int):
    """Fill every lane, drain, and return (us per full-lane decode step,
    steps measured) — deltas against the engine counters, so repeated
    measurements never reset engine/watchdog state."""
    import numpy as np

    wall0, steps0 = engine.decode_wall_s, engine.decode_steps
    for i in range(n_lanes):
        engine.submit(np.arange(1 + i, prompt_len + 1 + i), max_new)
    engine.drain()
    steps = engine.decode_steps - steps0
    return ((engine.decode_wall_s - wall0) / max(1, steps)) * 1e6, steps


def _fused_vs_unfused(ctxs, fast: bool):
    from repro.serving import fused_decode_active, make_engine

    n_rep = 2 if fast else 3
    ratio_at_largest = None
    for i, ctx in enumerate(ctxs):
        engines, us = {}, {}
        for fused in (True, False):
            eng = make_engine(ARCH, mode="native", fuse_kernels=fused,
                              max_lanes=4, page_size=8, max_ctx=ctx)
            active = fused_decode_active(eng)
            if i == 0:      # route report once per polarity (CI greps it)
                emit("serve/decode_path", 0.0,
                     f"fuse_kernels={fused};fused_active={active}")
            # a fused engine that silently took the gather route (or vice
            # versa) invalidates the comparison — fail loudly
            assert active == fused, (
                f"silent decode-route fallback: fuse_kernels={fused} "
                f"resolved to fused_active={active}")
            eng.submit([1, 2, 3, 4], 2)       # warm prefill/decode traces
            eng.drain()
            engines[fused] = eng
        # alternate routes, keep the min-of-n per route: back-to-back
        # interleaving cancels machine drift that a single pass cannot
        steps = 0
        for _ in range(n_rep):
            for fused, eng in engines.items():
                t, steps = _measure_decode(eng, 4, 8, ctx - 16)
                label = "fused" if fused else "unfused"
                us[label] = min(us.get(label, t), t)
        for fused in engines:
            label = "fused" if fused else "unfused"
            cost = _decode_cost(engines[fused])
            emit(f"serve/{label}_ctx{ctx}", us[label],
                 f"steps={steps};reps={n_rep};fused_active={fused};"
                 + roofline_derived(cost, us[label] / 1e6))
        ratio_at_largest = us["unfused"] / max(us["fused"], 1e-9)
    emit("serve/decode_fusion", 0.0,
         f"fused_vs_unfused={ratio_at_largest:.2f}x;ctx={ctxs[-1]}")


def main():
    import jax

    from repro.configs import get
    from repro.core import preset
    from repro.models import build_model
    from repro.serving import (Engine, naive_serve, poisson_traffic,
                               run_load, shared_prefix_traffic)

    fast = bool(os.environ.get("REPRO_BENCH_FAST"))
    n_requests = 6 if fast else 12
    rates = (4.0, 16.0) if fast else (4.0, 16.0, 64.0)
    gen_lens = (4, 8) if fast else (4, 8, 12)
    mid_rate = 16.0

    model = build_model(get(ARCH).reduced(), preset("full8", "native"))
    params = model.init(jax.random.PRNGKey(0))

    def traffic_at(rate):
        return poisson_traffic(rate=rate, n_requests=n_requests,
                               prompt_lens=(8, 16, 24), gen_lens=gen_lens,
                               vocab=128, seed=7)

    engine_tokps = 0.0
    pool_rep = None
    breakdown = {}                       # mode -> metrics at mid_rate
    for mode in ("monolithic", "chunked"):
        suffix = "" if mode == "monolithic" else "_chunked"
        for rate in rates:
            engine = Engine(model, params, max_lanes=4, page_size=8,
                            max_ctx=48, prefill_mode=mode, prefill_chunk=2)
            _, m = run_load(engine, traffic_at(rate))
            us = (m["decode_wall_s"] / max(1, m["decode_steps"])) * 1e6
            emit(f"serve/rate{rate:g}{suffix}", us,
                 f"tokps={m['decode_tok_s']:.2f};"
                 f"ttft_ms_mean={m['ttft_mean_s'] * 1e3:.1f};"
                 f"ttft_ms_p50={m['ttft_p50_s'] * 1e3:.1f};"
                 f"ttft_ms_p99={m['ttft_p99_s'] * 1e3:.1f};"
                 f"ttft_ms_max={m['ttft_max_s'] * 1e3:.1f};"
                 f"tpot_ms_p50={m['tpot_p50_s'] * 1e3:.2f};"
                 f"tpot_ms_p99={m['tpot_p99_s'] * 1e3:.2f};"
                 f"steps={m['decode_steps']};preempt={m['preemptions']};"
                 f"straggler={m['straggler_steps']}")
            if rate == mid_rate:
                breakdown[mode] = m
            if mode == "monolithic":
                engine_tokps = max(engine_tokps, m["decode_tok_s"])
                pool_rep = m.get("pool", pool_rep)
    for mode, m in breakdown.items():   # both polarities — CI greps each
        emit("serve/ttft_breakdown", 0.0,
             f"mode={mode};rate={mid_rate:g};"
             f"queue_ms={m['queue_ms_mean']:.1f};"
             f"prefill_ms={m['prefill_ms_mean']:.1f};"
             f"ttft_ms_mean={m['ttft_mean_s'] * 1e3:.1f};"
             f"ttft_ms_p99={m['ttft_p99_s'] * 1e3:.1f}")
    # the chunked TTFT claim, enforced: streaming page-sized chunks through
    # ONE prefill trace keeps even the p99 TTFT under the monolithic MEAN
    # (which eats a fresh XLA compile per novel prompt length)
    assert (breakdown["chunked"]["ttft_p99_s"]
            < breakdown["monolithic"]["ttft_mean_s"]), (
        f"chunked p99 {breakdown['chunked']['ttft_p99_s']:.3f}s >= "
        f"monolithic mean {breakdown['monolithic']['ttft_mean_s']:.3f}s")

    # radix prefix-cache sweep: same arrival process, rising fractions of
    # prompts opening with a common 2-page prefix (prompts are short, so a
    # fixed request count keeps hit-rate statistics comparable across
    # fast/full runs)
    for sharing in (0.0, 0.5, 0.9):
        engine = Engine(model, params, max_lanes=4, page_size=8, max_ctx=48,
                        prefill_mode="chunked", prefill_chunk=2,
                        radix_cache=True)
        traffic = shared_prefix_traffic(rate=mid_rate, n_requests=12,
                                        sharing=sharing, prefix_len=16,
                                        n_prefixes=1, tail_lens=(4, 8),
                                        gen_lens=gen_lens, seed=7)
        _, m = run_load(engine, traffic)
        us = (m["decode_wall_s"] / max(1, m["decode_steps"])) * 1e6
        emit("serve/prefix_hit", us,
             f"sharing={sharing:g};hit_rate={m['prefix_hit_rate']:.2f};"
             f"tokps={m['decode_tok_s']:.2f};"
             f"ttft_ms_mean={m['ttft_mean_s'] * 1e3:.1f};"
             f"queue_ms={m['queue_ms_mean']:.1f};"
             f"prefill_ms={m['prefill_ms_mean']:.1f};"
             f"shared_pages={m['pool']['shared_pages']}")

    # sharded layouts: tp=2 shard_map engine, dp=2 replica router (gated on
    # the host's device count — the 8-virtual-device CI lane sees them all)
    from repro.serving import make_router, make_sharded_engine
    n_dev = len(jax.devices())
    layouts = [(1, 1)]
    if n_dev >= 2:
        layouts += [(2, 1), (1, 2)]
    if n_dev >= 4 and not fast:
        layouts.append((2, 2))
    skw = dict(max_lanes=4, page_size=8, max_ctx=48,
               prefill_mode="chunked", prefill_chunk=2)
    for tp, dp in layouts:
        if dp == 1:
            tgt = make_sharded_engine(ARCH, tp=tp, **skw)
        else:
            tgt = make_router(ARCH, replicas=dp, tp=tp, **skw)
        _, m = run_load(tgt, traffic_at(mid_rate))
        us = (m["decode_wall_s"] / max(1, m["decode_steps"])) * 1e6
        emit("serve/sharded", us,
             f"tp={tp};dp={dp};tokps={m['decode_tok_s']:.2f};"
             f"ttft_ms_p50={m['ttft_p50_s'] * 1e3:.1f};"
             f"ttft_ms_p99={m['ttft_p99_s'] * 1e3:.1f};"
             f"tpot_ms_p50={m['tpot_p50_s'] * 1e3:.2f};"
             f"tpot_ms_p99={m['tpot_p99_s'] * 1e3:.2f};"
             f"completed={m['completed']}")

    _, nm = naive_serve(model, params, traffic_at(rates[0]))
    n_us = (nm["decode_wall_s"] / max(1, nm["decode_steps"])) * 1e6
    emit("serve/naive", n_us,
         f"tokps={nm['decode_tok_s']:.2f};steps={nm['decode_steps']}")
    emit("serve/speedup", 0.0,
         f"engine_vs_naive={engine_tokps / max(nm['decode_tok_s'], 1e-9):.2f}x")
    if pool_rep is not None:
        emit("serve/pool", 0.0,
             f"int8_vs_fp32={pool_rep['footprint_ratio']:.2f}x;"
             f"seqs_int8={pool_rep['capacity_seqs_int8']};"
             f"seqs_fp32={pool_rep['capacity_seqs_fp32']}")

    # fused-vs-unfused decode column + the dispatch-route report (the fused
    # engine must stream pages through the fused kernel and the unfused one
    # must not — a silent fallback fails the bench, and CI greps the rows)
    _fused_vs_unfused((32,) if fast else (32, 64), fast)


if __name__ == "__main__":
    main()
