"""Training benchmark: fused-epilogue kernels vs the unfused native path,
plus the DP-scaling column for the sharded shard_map step.

Times one full fwd+bwd+update step of native-mode WAGEUBN training with the
fused dgrad/wgrad/UBN route on and off (QConfig.fuse_kernels — the two are
bit-exact, so this isolates the data-movement win of fusing Q_E2 into the
matmul prologues and the five UBN quantizers into one pass).

CSV rows (name,us_per_call,derived — `derived` is ';'-separated):
  train/<config>_fused    — us per training step; tokens/s
  train/<config>_unfused  — same, fuse_kernels=False
  train/<config>_speedup  — fused-vs-unfused step-time ratio
  train/dp<N>_intwire     — sharded step @ DP=N, integer-wire grad sync
  train/dp<N>_f32wire     — same layout, XLA f32 all-reduce sync
  train/dp_scaling        — dp4-vs-dp1 step-time ratio (int wire)

The DP rows run in a subprocess (virtual host devices must be configured
before jax initializes) over a fixed n_shards=4, so every layout computes
bit-identical math — the column isolates parallel speedup + wire cost.

Scale knobs: REPRO_BENCH_FAST drops the largest config and shortens the
timed window.  On this CPU container both paths dispatch to the XLA
oracles (identical math, different fusion structure); on a TPU backend the
same toggle compares the compiled Pallas kernels.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

from .common import emit


def _configs(fast: bool):
    from repro.configs.base import ArchConfig

    def lm(name, d, layers, d_ff):
        return ArchConfig(name=name, family="lm", n_layers=layers,
                          d_model=d, n_heads=max(d // 64, 2),
                          n_kv=max(d // 128, 1), d_ff=d_ff, vocab=256,
                          head_dim=64, q_chunk=64, kv_chunk=64)

    cfgs = [("lm-64", lm("bench-lm-64", 64, 2, 128), 4, 32),
            ("lm-128", lm("bench-lm-128", 128, 2, 256), 4, 64)]
    if not fast:
        cfgs.append(("lm-192", lm("bench-lm-192", 192, 3, 384), 4, 64))
    return cfgs


def _time_steps(step_fn, params, opt, batch, n_steps):
    import jax
    import jax.numpy as jnp

    # one warmup step outside the timer (compile + first dispatch)
    p, o, m = step_fn(params, opt, batch, jnp.int32(0))
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for i in range(n_steps):
        p, o, m = step_fn(p, o, batch, jnp.int32(i + 1))
    jax.block_until_ready(m["loss"])
    return (time.perf_counter() - t0) / n_steps


def main():
    import jax
    import jax.numpy as jnp

    from repro.core import preset
    from repro.data import TokenTask
    from repro.launch.train import make_train_step
    from repro.models import build_model
    from repro.optim import init_momentum

    fast = bool(os.environ.get("REPRO_BENCH_FAST"))
    n_steps = 3 if fast else 8

    for name, arch, batch_sz, seq in _configs(fast):
        task = TokenTask(vocab=arch.vocab, seq_len=seq, global_batch=batch_sz)
        batch = jax.tree.map(jnp.asarray, task.batch(0))
        tokens = batch_sz * seq
        step_us = {}
        for label, fused in (("fused", True), ("unfused", False)):
            qcfg = preset("full8", "native").replace(fuse_kernels=fused)
            model = build_model(arch, qcfg)
            params = model.init(jax.random.PRNGKey(0))
            opt = init_momentum(params)
            step_fn = jax.jit(
                make_train_step(model, qcfg, model.labels(params)))
            dt = _time_steps(step_fn, params, opt, batch, n_steps)
            step_us[label] = dt * 1e6
            emit(f"train/{name}_{label}", dt * 1e6,
                 f"tok_s={tokens / dt:.1f};steps={n_steps}")
        emit(f"train/{name}_speedup", 0.0,
             f"fused_vs_unfused={step_us['unfused'] / step_us['fused']:.2f}x")
    _dp_scaling(fast)


# --------------------------------------------------------------------------
# DP scaling (sharded shard_map step, integer wire vs f32 wire)
# --------------------------------------------------------------------------


def _dp_scaling(fast: bool):
    """Spawn the DP worker (device count must precede jax init) and re-emit
    its rows into this process's record stream."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu")
    env.setdefault("PYTHONPATH", "src")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "-m", "benchmarks.train_bench",
                       "--dp-worker"], capture_output=True, text=True,
                       timeout=1800, env=env, cwd=root)
    if r.returncode != 0:
        raise RuntimeError(f"dp worker failed:\n{r.stdout[-2000:]}"
                           f"\n{r.stderr[-2000:]}")
    for line in r.stdout.splitlines():
        if line.startswith("ROW,"):
            _, name, us, derived = line.split(",", 3)
            emit(name, float(us), derived)


def _dp_worker():
    import jax
    import jax.numpy as jnp

    from repro.core import preset
    from repro.data import TokenTask
    from repro.launch import shard as S
    from repro.launch.mesh import make_cpu_mesh
    from repro.launch.train import make_sharded_train_step
    from repro.models import build_model
    from repro.optim import init_momentum

    fast = bool(os.environ.get("REPRO_BENCH_FAST"))
    n_steps = 2 if fast else 6
    name, arch, batch_sz, seq = _configs(fast)[0]
    task = TokenTask(vocab=arch.vocab, seq_len=seq, global_batch=batch_sz)
    tokens = batch_sz * seq
    base_us = {}
    for dp in (1, 2, 4):
        for sync, tag in (("int_ring", "intwire"), ("psum", "f32wire")):
            mesh = make_cpu_mesh(dp, 1)
            qcfg = preset("full8", "native")
            model = build_model(arch, qcfg)
            params = model.init(jax.random.PRNGKey(0))
            opt = init_momentum(params)
            raw, specs = make_sharded_train_step(
                model, qcfg, model.labels(params), mesh, params,
                n_shards=4, grad_sync=sync)
            step_fn = jax.jit(raw)
            params = S.shard_arrays(mesh, params, specs["params"])
            opt = S.shard_arrays(mesh, opt, specs["opt"])
            batch = S.put_batch(mesh, task.batch(0))
            params, opt, m = step_fn(params, opt, batch, jnp.int32(0))
            jax.block_until_ready(m["loss"])
            t0 = time.perf_counter()
            for i in range(n_steps):
                params, opt, m = step_fn(params, opt, batch,
                                         jnp.int32(i + 1))
            jax.block_until_ready(m["loss"])
            dt = (time.perf_counter() - t0) / n_steps
            base_us[(dp, tag)] = dt * 1e6
            print(f"ROW,train/dp{dp}_{tag},{dt * 1e6:.1f},"
                  f"tok_s={tokens / dt:.1f};steps={n_steps};arch={name}")
    ratio = base_us[(1, 'intwire')] / base_us[(4, 'intwire')]
    wire = base_us[(4, 'f32wire')] / base_us[(4, 'intwire')]
    print(f"ROW,train/dp_scaling,0.0,"
          f"dp4_vs_dp1={ratio:.2f}x;f32_vs_int_at_dp4={wire:.2f}x")


if __name__ == "__main__":
    if "--dp-worker" in sys.argv:
        _dp_worker()
    else:
        main()
