"""Training benchmark: fused-epilogue kernels vs the unfused native path,
plus the DP-scaling column for the sharded shard_map step.

Times one full fwd+bwd+update step of native-mode WAGEUBN training with the
fused dgrad/wgrad/UBN route on and off (QConfig.fuse_kernels — the two are
bit-exact, so this isolates the data-movement win of fusing Q_E2 into the
matmul prologues and the five UBN quantizers into one pass).

CSV rows (name,us_per_call,derived — `derived` is ';'-separated):
  train/<config>_fused    — us per training step; tokens/s; %_of_roofline
                            at the bf16 and int8 peaks (common.measure
                            warmup-corrected CV-guarded timing throughout)
  train/<config>_unfused  — same, fuse_kernels=False
  train/<config>_speedup  — fused-vs-unfused step-time ratio
  train/dp<N>_intwire     — sharded step @ DP=N, integer-wire grad sync
                            (the packed wire_sync_tree codec)
  train/dp<N>_f32wire     — same layout, XLA f32 all-reduce sync
  train/dp_scaling        — dp4-vs-dp1 step-time ratio (int wire)
  train/wire_codec        — dp=2 wire-bits=8: packed (tree codec,
                            two-per-int16 hops) vs unpacked (per-leaf
                            rings) step time + per-hop on-wire message
                            element counts from the traced jaxpr
  train/ckpt              — packed QTensor checkpoint: save/restore
                            latency, packed-vs-dense-f32 state bytes
                            (lossless resume format) and the int8 serving
                            export ratio (qsave.export_int8, ≥3x)

The DP rows run in a subprocess (virtual host devices must be configured
before jax initializes) over a fixed n_shards=4, so every layout computes
bit-identical math — the column isolates parallel speedup + wire cost.

Scale knobs: REPRO_BENCH_FAST drops the largest config and shortens the
timed window.  On this CPU container both paths dispatch to the XLA
oracles (identical math, different fusion structure); on a TPU backend the
same toggle compares the compiled Pallas kernels.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

from .common import emit, measure, roofline_derived, step_cost


def _configs(fast: bool):
    from repro.configs.base import ArchConfig

    def lm(name, d, layers, d_ff):
        return ArchConfig(name=name, family="lm", n_layers=layers,
                          d_model=d, n_heads=max(d // 64, 2),
                          n_kv=max(d // 128, 1), d_ff=d_ff, vocab=256,
                          head_dim=64, q_chunk=64, kv_chunk=64)

    cfgs = [("lm-64", lm("bench-lm-64", 64, 2, 128), 4, 32),
            ("lm-128", lm("bench-lm-128", 128, 2, 256), 4, 64)]
    if not fast:
        cfgs.append(("lm-192", lm("bench-lm-192", 192, 3, 384), 4, 64))
    return cfgs


def _time_steps(step_fn, params, opt, batch):
    """CV-guarded step timing (common.measure): warmup absorbed outside
    the timer, samples accumulate until stable.  Returns (s, cv, n)."""
    import jax.numpy as jnp

    state = {"p": params, "o": opt, "i": 0}

    def call():
        state["i"] += 1
        state["p"], state["o"], m = step_fn(
            state["p"], state["o"], batch, jnp.int32(state["i"]))
        return m["loss"]

    return measure(call)


def main():
    import jax
    import jax.numpy as jnp

    from repro.core import preset
    from repro.data import TokenTask
    from repro.launch.train import make_train_step
    from repro.models import build_model
    from repro.optim import init_momentum

    fast = bool(os.environ.get("REPRO_BENCH_FAST"))

    for name, arch, batch_sz, seq in _configs(fast):
        task = TokenTask(vocab=arch.vocab, seq_len=seq, global_batch=batch_sz)
        batch = jax.tree.map(jnp.asarray, task.batch(0))
        tokens = batch_sz * seq
        step_us = {}
        for label, fused in (("fused", True), ("unfused", False)):
            qcfg = preset("full8", "native").replace(fuse_kernels=fused)
            model = build_model(arch, qcfg)
            params = model.init(jax.random.PRNGKey(0))
            opt = init_momentum(params)
            step_fn = jax.jit(
                make_train_step(model, qcfg, model.labels(params)))
            dt, cv, n = _time_steps(step_fn, params, opt, batch)
            cost = step_cost(step_fn, params, opt, batch, jnp.int32(0))
            step_us[label] = dt * 1e6
            emit(f"train/{name}_{label}", dt * 1e6,
                 f"tok_s={tokens / dt:.1f};steps={n};cv={cv:.3f};"
                 + roofline_derived(cost, dt))
        emit(f"train/{name}_speedup", 0.0,
             f"fused_vs_unfused={step_us['unfused'] / step_us['fused']:.2f}x")
    _ckpt_bench(fast)
    _dp_scaling(fast)


def _ckpt_bench(fast: bool):
    """train/ckpt row: packed QTensor checkpoint save/restore latency and
    bytes, vs a dense-f32 write of the SAME state, plus the lossy int8
    serving-export ratio.

    The lossless resume state is floored by the 24-bit k_WU master-weight
    grid (~3 bytes/param — DESIGN.md §11), so packed-vs-f32 lands around
    1.3-1.7x; the ≥3x criterion belongs to the int8 export."""
    import shutil
    import tempfile

    import jax
    import jax.numpy as jnp

    from repro.checkpoint import CheckpointManager, qsave
    from repro.checkpoint.manager import _flatten_with_paths
    from repro.core import preset
    from repro.data import TokenTask
    from repro.launch.train import make_train_step
    from repro.models import build_model
    from repro.optim import init_momentum

    name, arch, batch_sz, seq = _configs(fast)[0]
    qcfg = preset("full8", "native")
    model = build_model(arch, qcfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_momentum(params)
    task = TokenTask(vocab=arch.vocab, seq_len=seq, global_batch=batch_sz)
    batch = jax.tree.map(jnp.asarray, task.batch(0))
    step_fn = jax.jit(make_train_step(model, qcfg, model.labels(params)))
    # two real steps land every leaf on its WAGEUBN grid (params on the
    # 2^(1-k_WU) grid, Momentum acc on 2^(1-k_Acc)) — the state a real
    # elastic save cadence checkpoints
    for i in range(2):
        params, opt, m = step_fn(params, opt, batch, jnp.int32(i))
    jax.block_until_ready(m["loss"])
    state = {"params": params, "opt": opt}

    root = tempfile.mkdtemp(prefix="ckpt_bench_")
    try:
        packed = CheckpointManager(os.path.join(root, "q"), keep=1)
        t0 = time.perf_counter()
        packed.save(2, state, block=True)
        save_us = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        restored, _, _ = packed.restore(state, step=2)
        jax.block_until_ready(restored)
        restore_us = (time.perf_counter() - t0) * 1e6
        rep = packed.size_report(2)

        dense = CheckpointManager(os.path.join(root, "f32"), keep=1,
                                  packed=False)
        dense.save(2, state, block=True)
        dense_disk = dense.size_report(2)["disk_bytes"]

        _, fmt8 = qsave.pack_tree(
            _flatten_with_paths(qsave.export_int8(params)))
        int8_ratio = qsave.report(fmt8)["ratio"]
    finally:
        shutil.rmtree(root, ignore_errors=True)

    state_ratio = rep["ratio"]
    assert int8_ratio >= 3.0, (
        f"int8 serving export only {int8_ratio:.2f}x smaller than dense "
        f"f32 — the QTensor payload packing regressed")
    emit("train/ckpt", save_us,
         f"restore_us={restore_us:.0f};state_bytes={rep['ckpt_bytes_q']};"
         f"f32_bytes={rep['ckpt_bytes_f32_dense']};"
         f"disk_bytes={rep['disk_bytes']};dense_disk={dense_disk};"
         f"state_vs_f32={state_ratio:.2f}x;int8_vs_f32={int8_ratio:.2f}x;"
         f"arch={name}")


# --------------------------------------------------------------------------
# DP scaling (sharded shard_map step, integer wire vs f32 wire)
# --------------------------------------------------------------------------


def _dp_scaling(fast: bool):
    """Spawn the DP worker (device count must precede jax init) and re-emit
    its rows into this process's record stream."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu")
    env.setdefault("PYTHONPATH", "src")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "-m", "benchmarks.train_bench",
                       "--dp-worker"], capture_output=True, text=True,
                       timeout=1800, env=env, cwd=root)
    if r.returncode != 0:
        raise RuntimeError(f"dp worker failed:\n{r.stdout[-2000:]}"
                           f"\n{r.stderr[-2000:]}")
    for line in r.stdout.splitlines():
        if line.startswith("ROW,"):
            _, name, us, derived = line.split(",", 3)
            emit(name, float(us), derived)


def _dp_worker():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import preset
    from repro.data import TokenTask
    from repro.launch import shard as S
    from repro.launch.mesh import make_cpu_mesh
    from repro.launch.train import make_sharded_train_step
    from repro.models import build_model
    from repro.optim import init_momentum

    fast = bool(os.environ.get("REPRO_BENCH_FAST"))
    name, arch, batch_sz, seq = _configs(fast)[0]
    task = TokenTask(vocab=arch.vocab, seq_len=seq, global_batch=batch_sz)
    tokens = batch_sz * seq

    def run(dp, sync, codec="packed", wire_bits=16):
        mesh = make_cpu_mesh(dp, 1)
        qcfg = preset("full8", "native")
        model = build_model(arch, qcfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = init_momentum(params)
        raw, specs = make_sharded_train_step(
            model, qcfg, model.labels(params), mesh, params,
            n_shards=4, grad_sync=sync, wire_codec=codec,
            wire_bits=wire_bits)
        step_fn = jax.jit(raw)
        params = S.shard_arrays(mesh, params, specs["params"])
        opt = S.shard_arrays(mesh, opt, specs["opt"])
        batch = S.put_batch(mesh, task.batch(0))
        dt, cv, n = _time_steps(step_fn, params, opt, batch)
        cost = step_cost(step_fn, params, opt, batch, jnp.int32(0))
        return dt, cv, n, cost

    base_us = {}
    for dp in (1, 2, 4):
        for sync, tag in (("int_ring", "intwire"), ("psum", "f32wire")):
            dt, cv, n, cost = run(dp, sync)
            base_us[(dp, tag)] = dt * 1e6
            print(f"ROW,train/dp{dp}_{tag},{dt * 1e6:.1f},"
                  f"tok_s={tokens / dt:.1f};steps={n};cv={cv:.3f};"
                  f"arch={name};" + roofline_derived(cost, dt))
    ratio = base_us[(1, 'intwire')] / base_us[(4, 'intwire')]
    wire = base_us[(4, 'f32wire')] / base_us[(4, 'intwire')]
    print(f"ROW,train/dp_scaling,0.0,"
          f"dp4_vs_dp1={ratio:.2f}x;f32_vs_int_at_dp4={wire:.2f}x")

    # wire-codec A/B at dp=2, wire-bits=8: packed tree codec (one ring,
    # two-per-int16 hops) vs the per-leaf unpacked rings — bit-identical
    # weights, different wires.  Message elements come from the traced
    # jaxpr (per hop: every ppermute eqn fires each of the n-1 hops).
    dt_p, _, _, _ = run(2, "int_ring", codec="packed", wire_bits=8)
    dt_u, _, _, _ = run(2, "int_ring", codec="leaf", wire_bits=8)

    def hop_elems(codec):
        mesh = make_cpu_mesh(2, 1)
        qcfg = preset("full8", "native")
        model = build_model(arch, qcfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = init_momentum(params)
        raw, _ = make_sharded_train_step(
            model, qcfg, model.labels(params), mesh, params, n_shards=4,
            grad_sync="int_ring", wire_codec=codec, wire_bits=8)
        batch = jax.tree.map(jnp.asarray, task.batch(0))
        jaxpr = jax.make_jaxpr(raw)(params, opt, batch, jnp.int32(0))
        from repro.kernels.ops import collective_eqns
        pps = [c for c in collective_eqns(jaxpr.jaxpr)
               if c[0] == "ppermute"]
        return sum(int(np.prod(c[1])) for c in pps), len(pps)

    pe, pn = hop_elems("packed")
    ue, un = hop_elems("leaf")
    print(f"ROW,train/wire_codec,{dt_p * 1e6:.1f},"
          f"packed_us={dt_p * 1e6:.1f};unpacked_us={dt_u * 1e6:.1f};"
          f"packed_vs_unpacked={dt_u / dt_p:.2f}x;"
          f"hop_elems_packed={pe};hop_elems_unpacked={ue};"
          f"elem_reduction={ue / pe:.2f}x;"
          f"ppermutes_packed={pn};ppermutes_unpacked={un};"
          f"dp=2;wire_bits=8")


if __name__ == "__main__":
    if "--dp-worker" in sys.argv:
        _dp_worker()
    else:
        main()
