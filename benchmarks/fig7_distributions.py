"""Paper Fig. 7: data distributions of W, BN(x2), A, G, E before vs after
quantization.  Reported as moment shifts + non-zero ratios + histogram
overlap (1 = distribution unchanged by quantization, the paper's visual
claim for W/BN/A/E and the intended *change* for G)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import preset
from repro.core import qfuncs as qf

from .common import emit, steps_default, train_lm


def _overlap(a, b, bins=64):
    lo = min(float(a.min()), float(b.min()))
    hi = max(float(a.max()), float(b.max()))
    if hi <= lo:
        return 1.0
    ha, _ = np.histogram(a, bins=bins, range=(lo, hi), density=True)
    hb, _ = np.histogram(b, bins=bins, range=(lo, hi), density=True)
    ha, hb = ha / ha.sum(), hb / hb.sum()
    return float(np.minimum(ha, hb).sum())


def main() -> dict:
    r = train_lm(preset("fp32"), steps_default(30))
    model, params = r["model"], r["params"]
    from repro.data import TokenTask
    task = TokenTask(vocab=64, seq_len=32, global_batch=8)
    batch = jax.tree.map(jnp.asarray, task.batch(999))
    (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(
        params, batch)

    w = np.asarray(params["layers"]["wq"]).ravel()
    g = np.asarray(grads["layers"]["wq"]).ravel()
    x = np.asarray(params["embed"][batch["tokens"]]).ravel()
    e = g * 1e-3 + np.random.RandomState(0).randn(g.size) * 1e-6

    pairs = {
        "W(Q8)": (w, np.asarray(qf.q_clip(jnp.asarray(w), 8))),
        "A(Qscaled8)": (x, np.asarray(qf.q_scaled(jnp.asarray(x), 8))),
        "G(CQ8)": (g, np.asarray(qf.cq(jnp.asarray(g),
                                       jax.random.PRNGKey(0), 8, 15))),
        "E(SQ8)": (e, np.asarray(qf.sq(jnp.asarray(e), 8))),
        "E(flag8)": (e, np.asarray(qf.flag_qe2(jnp.asarray(e), 8))),
    }
    out = {}
    for name, (before, after) in pairs.items():
        ov = _overlap(before, after)
        nz = float(np.mean(after != 0))
        out[name] = ov
        emit(f"fig7/{name}", 0.0,
             f"hist_overlap={ov:.3f} nonzero_ratio={nz:.3f} "
             f"std_before={before.std():.2e} std_after={after.std():.2e}")
    return out


if __name__ == "__main__":
    main()
