"""Paper Fig. 7: data distributions of W, A, G, E before vs after
quantization, reported as moment shifts + non-zero ratios + histogram
overlap (1 = distribution unchanged by quantization, the paper's visual
claim for W/A/E and the intended *change* for G).

Tensors come from a short ResNet run on the resolved image task (real npz
pipeline when REPRO_DATA_DIR is set): W from a trained conv weight, A from
the real input images, G/E from the step's gradients.  Each pair runs at
k=8 and k=4 — the sub-8 lanes' distribution cost, per path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import preset
from repro.core import qfuncs as qf

from .common import emit, steps_default, train_resnet


def _overlap(a, b, bins=64):
    lo = min(float(a.min()), float(b.min()))
    hi = max(float(a.max()), float(b.max()))
    if hi <= lo:
        return 1.0
    ha, _ = np.histogram(a, bins=bins, range=(lo, hi), density=True)
    hb, _ = np.histogram(b, bins=bins, range=(lo, hi), density=True)
    ha, hb = ha / ha.sum(), hb / hb.sum()
    return float(np.minimum(ha, hb).sum())


def _first_weight(params) -> np.ndarray:
    """Largest matmul/conv kernel leaf (ndim >= 2) — a real weight tensor,
    not a BN vector whose near-zero trained values quantize to nothing."""
    kernels = [leaf for leaf in jax.tree_util.tree_leaves(params)
               if leaf.ndim >= 2]
    biggest = max(kernels, key=lambda leaf: leaf.size)
    return np.asarray(biggest).ravel()


def main() -> dict:
    r = train_resnet(preset("fp32"), steps_default(30))
    model, params, task, data = (r["model"], r["params"], r["task"],
                                 r["data"])
    batch = jax.tree.map(jnp.asarray, task.holdout_batch(0))
    (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(
        params, batch)

    w = _first_weight(params)
    g = _first_weight(grads)
    x = np.asarray(batch["images"]).ravel()
    e = g * 1e-3 + np.random.RandomState(0).randn(g.size) * 1e-6

    out = {}
    for bits in (8, 4):
        pairs = {
            f"W(Q{bits})": (w, np.asarray(qf.q_clip(jnp.asarray(w), bits))),
            f"A(Qscaled{bits})": (x, np.asarray(
                qf.q_scaled(jnp.asarray(x), bits))),
            f"G(CQ{bits})": (g, np.asarray(qf.cq(
                jnp.asarray(g), jax.random.PRNGKey(0), bits, 15))),
            f"E(SQ{bits})": (e, np.asarray(qf.sq(jnp.asarray(e), bits))),
            f"E(flag{bits})": (e, np.asarray(qf.flag_qe2(jnp.asarray(e),
                                                         bits))),
        }
        for name, (before, after) in pairs.items():
            ov = _overlap(before, after)
            nz = float(np.mean(after != 0))
            out[name] = ov
            emit(f"fig7/{name}", 0.0,
                 f"hist_overlap={ov:.3f} nonzero_ratio={nz:.3f} "
                 f"std_before={before.std():.2e} "
                 f"std_after={after.std():.2e} data={data}")
    return out


if __name__ == "__main__":
    main()
