"""Paper Fig. 11 + §IV-F cost discussion: per-op and per-model cost model.

Two parts:
  (1) measured: XLA int8 vs fp32 matmul microbenchmark on this host (CPU —
      direction-of-effect check only; TPU MXU int8 is the real target where
      peak is 2x bf16);
  (2) modeled: the paper's FPGA-derived per-op constants and the memory
      footprint of every WAGEUBN datapath vs FP32 (the ~4x claim).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get

from .common import emit

# paper Fig. 11 constants (relative to FP32 = 1.0): speed-up, power-down,
# area-down for multiplication / accumulation.
PAPER_MUL = {"int8": (3.0, 10.0, 9.0), "fp16": (1.5, 2.2, 2.1),
             "int16": (2.0, 4.0, 3.8), "fp8": (2.3, 4.5, 4.0),
             "int32": (1.2, 1.6, 1.6)}
PAPER_ACC = {"int8": (9.0, 30.0, 30.0), "fp16": (1.8, 2.5, 2.4),
             "int16": (4.5, 8.0, 8.0), "fp8": (2.5, 5.0, 4.8),
             "int32": (2.2, 3.0, 3.0)}


def _time(f, *args, iters=20):
    f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        r = f(*args)
    r.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def main() -> dict:
    m = k = n = 1024
    a8 = jax.random.randint(jax.random.PRNGKey(0), (m, k), -128, 128,
                            jnp.int8)
    b8 = jax.random.randint(jax.random.PRNGKey(1), (k, n), -128, 128,
                            jnp.int8)
    af = a8.astype(jnp.float32)
    bf = b8.astype(jnp.float32)

    dot8 = jax.jit(lambda a, b: jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32))
    dotf = jax.jit(lambda a, b: a @ b)
    us8 = _time(dot8, a8, b8)
    usf = _time(dotf, af, bf)
    emit("fig11/matmul-int8-1k", us8, f"speedup_vs_f32={usf / us8:.2f}x")
    emit("fig11/matmul-f32-1k", usf, "baseline=1.0x")

    for dt, (s, p, ar) in PAPER_MUL.items():
        emit(f"fig11/paper-mul-{dt}", 0.0,
             f"speed={s}x power=1/{p}x area=1/{ar}x")
    for dt, (s, p, ar) in PAPER_ACC.items():
        emit(f"fig11/paper-acc-{dt}", 0.0,
             f"speed={s}x power=1/{p}x area=1/{ar}x")

    # memory model in BITS (the paper's accounting): per datapath widths
    # W_master k_WU=24, Acc k_Acc=13, compute/cache tensors (A/E/KV) 8-bit,
    # G 15-bit transient vs 32-bit everything for FP32.
    acfg = get("granite-3-8b")
    n_p = (acfg.n_layers * (acfg.d_model * (acfg.n_heads + 2 * acfg.n_kv)
                            * acfg.dh + acfg.n_heads * acfg.dh * acfg.d_model
                            + 3 * acfg.d_model * acfg.d_ff))
    tokens = 4096 * 4
    act = acfg.n_layers * tokens * acfg.d_model
    fp32_bits = 32 * (2 * n_p) + 32 * act        # W+Acc states, activations
    wage_bits = (24 + 13) * n_p + 8 * act        # 24b master+13b acc, A8
    comp_fp32 = 32 * act
    comp_wage = 8 * act                          # the paper's headline 4x
    emit("fig11/memory-model", 0.0,
         f"state+act_saving={fp32_bits/wage_bits:.2f}x "
         f"compute_tensor_saving={comp_fp32/comp_wage:.2f}x "
         f"(paper claims ~4x on compute tensors)")
    return {"speedup": usf / us8,
            "mem_saving": fp32_bits / wage_bits}


if __name__ == "__main__":
    main()
