"""Paper Table II: single-path quantization sensitivity on the WAGEUBN
framework (quantize exactly ONE of W/A/BN/G/E1/E2 to 8-bit with the FP32
update path, everything else fp32)."""
from __future__ import annotations

from repro.core import preset

from .common import emit, steps_default, train_resnet

OFF = dict(quant_w=False, quant_a=False, quant_bn=False, quant_g=False,
           quant_e1=False, quant_e2=False, quant_u=False)

RUNS = {
    "kW=8": dict(quant_w=True),
    "kBN=8": dict(quant_bn=True),
    "kA=8": dict(quant_a=True),
    "kGW=8": dict(quant_g=True),
    "kE1=8": dict(quant_e1=True),
    "kE2=8": dict(quant_e2=True),
}


def main() -> dict:
    steps = steps_default(100)
    base = train_resnet(preset("fp32"), steps)
    emit("table2/fp32", base["wall_s"] / steps * 1e6,
         f"holdout_acc={base['acc']:.4f}")
    out = {"fp32": base["acc"]}
    for name, on in RUNS.items():
        # Table II's kBN=8 run narrows the norm widths to 8
        qcfg = preset("full8", "sim").replace(**{**OFF, **on})
        if name == "kBN=8":
            qcfg = qcfg.replace(k_bn=8, k_mu=8, k_sigma=8)
        r = train_resnet(qcfg, steps)
        out[name] = r["acc"]
        emit(f"table2/{name}", r["wall_s"] / steps * 1e6,
             f"holdout_acc={r['acc']:.4f} delta={r['acc']-base['acc']:+.4f}")
    return out


if __name__ == "__main__":
    main()
