"""Paper Table II: single-path quantization sensitivity on the WAGEUBN
framework — quantize exactly ONE of W/A/BN/G/E1/E2 with the FP32 update
path, everything else fp32 — swept over bit widths.

Two axes beyond the paper's 8-bit column:
  * per-path width: every swept path also runs at k=4 (rows table2/kW=4,
    table2/kA=4, ...) — the sub-8 lanes (DESIGN.md §14) through the same
    registry quantizers (BN stays 8-wide: Eq. 13 needs the 16-bit stats);
  * gradient wire: table2/wire={16,8,4} trains fp32 numerics through the
    sharded step's integer wire on a 1-device mesh (n_shards=2 virtual
    shards), so the ONLY quantizer in the run is the wire itself —
    wire=4 exercises the staged int16-hop widening of compress.wire_plan.

Rows carry data=<real:...|synthetic> from the resolved input pipeline.
"""
from __future__ import annotations

from repro.core import preset

from .common import emit, steps_default, train_resnet, train_resnet_sharded

OFF = dict(quant_w=False, quant_a=False, quant_bn=False, quant_g=False,
           quant_e1=False, quant_e2=False, quant_u=False)

# path label -> (enable switch, width field); each runs at k in SWEEP_BITS
PATHS = {
    "kW": ("quant_w", "k_w"),
    "kA": ("quant_a", "k_a"),
    "kGW": ("quant_g", "k_gw"),
    "kE1": ("quant_e1", "k_e1"),
    "kE2": ("quant_e2", "k_e2"),
}
SWEEP_BITS = (8, 4)
WIRE_BITS = (16, 8, 4)


def main() -> dict:
    steps = steps_default(100)
    base = train_resnet(preset("fp32"), steps)
    data = base["data"]
    task = base["task"]           # share one resolved pipeline across runs
    emit("table2/fp32", base["wall_s"] / steps * 1e6,
         f"holdout_acc={base['acc']:.4f} data={data}")
    out = {"fp32": base["acc"]}

    # Table II's kBN run narrows the norm widths (stats stay 16b elsewhere)
    qbn = preset("full8", "sim").replace(
        **{**OFF, "quant_bn": True, "k_bn": 8, "k_mu": 8, "k_sigma": 8})
    r = train_resnet(qbn, steps, task=task)
    out["kBN=8"] = r["acc"]
    emit("table2/kBN=8", r["wall_s"] / steps * 1e6,
         f"holdout_acc={r['acc']:.4f} delta={r['acc']-base['acc']:+.4f} "
         f"data={data}")

    for path, (switch, width) in PATHS.items():
        for bits in SWEEP_BITS:
            qcfg = preset("full8", "sim").replace(
                **{**OFF, switch: True, width: bits})
            r = train_resnet(qcfg, steps, task=task)
            name = f"{path}={bits}"
            out[name] = r["acc"]
            emit(f"table2/{name}", r["wall_s"] / steps * 1e6,
                 f"holdout_acc={r['acc']:.4f} "
                 f"delta={r['acc']-base['acc']:+.4f} data={data}")

    for bits in WIRE_BITS:
        r = train_resnet_sharded(preset("fp32"), steps, wire_bits=bits,
                                 n_shards=2, task=task)
        name = f"wire={bits}"
        out[name] = r["acc"]
        emit(f"table2/{name}", r["wall_s"] / steps * 1e6,
             f"holdout_acc={r['acc']:.4f} "
             f"delta={r['acc']-base['acc']:+.4f} data={data}")
    return out


if __name__ == "__main__":
    main()
