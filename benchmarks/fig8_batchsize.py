"""Paper Fig. 8: batch-size sensitivity of WAGEUBN vs full precision.
Claim: accuracy holds down to small batches; only very small batch (16 in
the paper) degrades the quantized net noticeably more than FP32."""
from __future__ import annotations

from repro.core import preset

from .common import emit, steps_default, train_resnet


def main() -> dict:
    out = {}
    for bs in (64, 32, 16, 8):
        steps = steps_default(100)
        for name, qcfg in [("fp32", preset("fp32")),
                           ("full8", preset("full8", "sim"))]:
            r = train_resnet(qcfg, steps, batch=bs)
            out[f"{name}/bs{bs}"] = r["acc"]
            emit(f"fig8/{name}-bs{bs}", r["wall_s"] / steps * 1e6,
                 f"holdout_acc={r['acc']:.4f}")
    return out


if __name__ == "__main__":
    main()
