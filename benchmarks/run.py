"""Benchmark harness: one module per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig11] [--fast]

Each benchmark prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> None:
    p = argparse.ArgumentParser("benchmarks.run")
    p.add_argument("--only", default="",
                   help="comma-separated subset (table1,table2,fig7,...)")
    p.add_argument("--fast", action="store_true")
    args = p.parse_args()
    if args.fast:
        os.environ["REPRO_BENCH_FAST"] = "1"

    from . import (fig7_distributions, fig8_batchsize, fig9_10_e3,
                   fig11_cost, roofline_bench, serve_bench, table1_accuracy,
                   table2_sensitivity, train_bench)
    benches = {
        "table1": table1_accuracy.main,
        "table2": table2_sensitivity.main,
        "fig7": fig7_distributions.main,
        "fig8": fig8_batchsize.main,
        "fig9_10": fig9_10_e3.main,
        "fig11": fig11_cost.main,
        "roofline": roofline_bench.main,
        "serve": serve_bench.main,
        "train": train_bench.main,
    }
    only = [s.strip() for s in args.only.split(",") if s.strip()]
    print("name,us_per_call,derived")
    failures = []
    for name, fn in benches.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"{name}/ERROR,0.0,{e!r}")
        print(f"{name}/total,{(time.time() - t0) * 1e6:.0f},done",
              file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
