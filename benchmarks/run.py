"""Benchmark harness: one module per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig11] [--fast]

Each benchmark prints ``name,us_per_call,derived`` CSV rows, and every
suite's rows are also appended to ``BENCH_<suite>.json`` (in --bench-dir,
default the repo root) as one commit-stamped entry per run — the
machine-readable perf trajectory across PRs.  Entry shape:

    {"commit": "<git short sha>", "timestamp": <unix seconds>,
     "fast": bool, "rows": [{"name", "us_per_call", "derived"}, ...]}
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent


def git_commit() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, check=True).stdout.strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def append_bench_json(bench_dir: Path, suite: str, rows: list[dict],
                      commit: str, fast: bool,
                      error: str | None = None) -> Path:
    """Append one run's rows to BENCH_<suite>.json (created on first use).

    A suite that raised mid-run still lands (its partial rows are real
    measurements) but carries an "error" field, so trajectory consumers
    can tell truncated entries from complete ones.
    """
    path = bench_dir / f"BENCH_{suite}.json"
    entries = []
    if path.exists():
        try:
            entries = json.loads(path.read_text())
        except json.JSONDecodeError as e:
            # a truncated file from an interrupted run must not take the
            # whole harness down — start the trajectory over, loudly
            print(f"{suite}/json-reset,0.0,corrupt {path.name}: {e!r}",
                  file=sys.stderr)
    entry = {"commit": commit, "timestamp": int(time.time()),
             "fast": fast, "rows": rows}
    if error is not None:
        entry["error"] = error
    entries.append(entry)
    path.write_text(json.dumps(entries, indent=1) + "\n")
    return path


def main() -> None:
    p = argparse.ArgumentParser("benchmarks.run")
    p.add_argument("--only", default="",
                   help="comma-separated subset (table1,fig11,...)")
    p.add_argument("--fast", action="store_true")
    p.add_argument("--bench-dir", default=str(REPO_ROOT),
                   help="where BENCH_<suite>.json trajectories live")
    args = p.parse_args()
    if args.fast:
        os.environ["REPRO_BENCH_FAST"] = "1"

    from . import (common, fig7_distributions, fig8_batchsize, fig9_10_e3,
                   fig11_cost, roofline_bench, serve_bench, table1_accuracy,
                   table2_sensitivity, train_bench)
    benches = {
        "table1": table1_accuracy.main,
        "table2": table2_sensitivity.main,
        "fig7": fig7_distributions.main,
        "fig8": fig8_batchsize.main,
        "fig9_10": fig9_10_e3.main,
        "fig11": fig11_cost.main,
        "roofline": roofline_bench.main,
        "serve": serve_bench.main,
        "train": train_bench.main,
    }
    only = [s.strip() for s in args.only.split(",") if s.strip()]
    commit = git_commit()
    bench_dir = Path(args.bench_dir)
    print("name,us_per_call,derived")
    failures = []
    for name, fn in benches.items():
        if only and name not in only:
            continue
        t0 = time.time()
        common.take_records()                   # drop any stale rows
        error = None
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            error = repr(e)
            failures.append((name, error))
            print(f"{name}/ERROR,0.0,{error}")
        rows = common.take_records()
        if rows or error is not None:   # errored zero-row runs land too
            try:
                path = append_bench_json(bench_dir, name, rows, commit,
                                         args.fast, error=error)
                print(f"{name}/json,0.0,{path.name}", file=sys.stderr)
            except OSError as e:        # unwritable dir: keep benching
                print(f"{name}/json-error,0.0,{e!r}", file=sys.stderr)
        print(f"{name}/total,{(time.time() - t0) * 1e6:.0f},done",
              file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
