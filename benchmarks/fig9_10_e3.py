"""Paper Fig. 9/10: the error between matmul and norm (e3) under 8-bit
Q_E2 vs 8-bit Flag-Q_E2 vs full precision.

Fig. 9: distribution fidelity (flag ~= fp; plain sq8 flushes the center).
Fig. 10: data ratio (fraction of non-zero values surviving quantization)
per layer — flag8 must cover far more than sq8 (the paper's explanation of
full-8-bit convergence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import preset
from repro.core import qfuncs as qf

from .common import emit, steps_default, train_lm


def main() -> dict:
    r = train_lm(preset("fp32"), steps_default(20))
    model, params = r["model"], r["params"]
    from repro.data import TokenTask
    task = TokenTask(vocab=64, seq_len=32, global_batch=8)
    batch = jax.tree.map(jnp.asarray, task.batch(1234))

    # capture e3 per layer = cotangent entering each projection matmul
    captured = {}

    def capture_loss(p):
        loss, _ = model.loss(p, batch)
        return loss

    grads = jax.grad(capture_loss)(params)
    # proxy for per-layer e3: gradients at layer inputs across depth —
    # use per-layer weight grads (e3 x0^T) as the observable error signal
    out = {}
    for li in range(model.a.n_layers):
        e3 = np.asarray(grads["layers"]["wq"][li]).ravel()
        e3 = e3[e3 != 0]
        if e3.size == 0:
            continue
        sq8 = np.asarray(qf.sq(jnp.asarray(e3), 8))
        fl8 = np.asarray(qf.flag_qe2(jnp.asarray(e3), 8))
        ratio_sq = float(np.mean(sq8 != 0))
        ratio_fl = float(np.mean(fl8 != 0))
        rel_sq = float(np.abs(sq8 - e3).mean() / (np.abs(e3).mean() + 1e-12))
        rel_fl = float(np.abs(fl8 - e3).mean() / (np.abs(e3).mean() + 1e-12))
        out[f"layer{li}"] = (ratio_sq, ratio_fl)
        emit(f"fig10/layer{li}", 0.0,
             f"data_ratio_sq8={ratio_sq:.3f} data_ratio_flag8={ratio_fl:.3f}"
             f" relerr_sq8={rel_sq:.3f} relerr_flag8={rel_fl:.3f}")
    # synthetic wide-dynamic-range errors (the regime of paper Fig. 9)
    rng = np.random.RandomState(0)
    e = rng.randn(1 << 16) * np.exp(rng.randn(1 << 16) * 2.5)
    sq8 = np.asarray(qf.sq(jnp.asarray(e, jnp.float32), 8))
    fl8 = np.asarray(qf.flag_qe2(jnp.asarray(e, jnp.float32), 8))
    emit("fig9/wide-range", 0.0,
         f"data_ratio_sq8={np.mean(sq8 != 0):.3f} "
         f"data_ratio_flag8={np.mean(fl8 != 0):.3f}")
    return out


if __name__ == "__main__":
    main()
