"""Shared benchmark helpers: small-scale training harnesses + CSV output.

The paper's experiments are ResNet/ImageNet-scale; this container is one
CPU core, so every accuracy benchmark runs the same *protocol* at reduced
scale (reduced ResNet on a learnable synthetic image task / tiny LM on the
arithmetic token task).  Scale knobs: REPRO_BENCH_STEPS / REPRO_BENCH_FAST.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import preset
from repro.core.qconfig import QConfig
from repro.data import ImageTask, TokenTask, resolve_image_task
from repro.launch.train import make_sharded_train_step, make_train_step
from repro.models import build_model
from repro.optim import dr_bits_schedule, init_momentum


def steps_default(n: int) -> int:
    if os.environ.get("REPRO_BENCH_FAST"):
        return max(8, n // 8)
    return int(os.environ.get("REPRO_BENCH_STEPS", n))


RESNET_BENCH = ArchConfig(name="resnet-bench", family="resnet",
                          block="basic", stage_sizes=(1, 1),
                          num_classes=8, img_size=16)

LM_BENCH = ArchConfig(name="lm-bench", family="lm", n_layers=2, d_model=64,
                      n_heads=4, n_kv=2, d_ff=128, vocab=64, head_dim=16,
                      q_chunk=32, kv_chunk=32)


def image_task(batch: int = 64, seed: int = 1):
    """Benchmark image source: the real npz pipeline when REPRO_DATA_DIR is
    set (synthetic fallback behind REPRO_SYNTHETIC_DATA=1), the synthetic
    blob task otherwise.  Returns (task, tag) — stamp `data=tag` into rows.
    """
    return resolve_image_task(
        batch, synthetic=bool(os.environ.get("REPRO_SYNTHETIC_DATA")),
        img_size=RESNET_BENCH.img_size,
        num_classes=RESNET_BENCH.num_classes, seed=seed)


def resnet_arch_for(task) -> ArchConfig:
    """RESNET_BENCH re-shaped to the task's geometry (real datasets may
    differ from the 16px/8-class synthetic default)."""
    return dataclasses.replace(RESNET_BENCH, num_classes=task.num_classes,
                               img_size=task.img_size)


def train_resnet(qcfg: QConfig, steps: int, batch: int = 64, lr: float = 0.05,
                 seed: int = 0, eval_batches: int = 4, task=None,
                 dr_boundaries: tuple = ()):
    task, tag = (task, "caller") if task is not None else image_task(batch)
    model = build_model(resnet_arch_for(task), qcfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt = init_momentum(params)
    labels = model.labels(params)
    # one jitted step per scheduled CQ dr width (static trace constant)
    step_fns = {}

    def fn_for(bits):
        if bits not in step_fns:
            step_fns[bits] = jax.jit(
                make_train_step(model, qcfg, labels, lr=lr, dr_bits=bits))
        return step_fns[bits]

    losses = []
    t0 = time.time()
    for s in range(steps):
        b = jax.tree.map(jnp.asarray, task.batch(s))
        fn = fn_for(dr_bits_schedule(s, dr_boundaries, base_bits=qcfg.k_gw))
        params, opt, m = fn(params, opt, b, jnp.int32(s))
        losses.append(float(m["loss"]))
    # held-out accuracy (val split / fresh synthetic steps)
    accs = []
    fwd = jax.jit(lambda p, b: model.loss(p, b)[1]["acc"])
    for i in range(eval_batches):
        b = jax.tree.map(jnp.asarray, task.holdout_batch(i))
        accs.append(float(fwd(params, b)))
    return {"losses": losses, "acc": float(np.mean(accs)),
            "wall_s": time.time() - t0, "params": params, "model": model,
            "data": tag, "task": task}


def train_resnet_sharded(qcfg: QConfig, steps: int, *, wire_bits: int,
                         n_shards: int = 2, batch: int = 64,
                         lr: float = 0.05, seed: int = 0,
                         eval_batches: int = 4, task=None):
    """train_resnet through the sharded step on a dp=1 mesh: the integer
    wire's quantization numerics (per-virtual-shard rounding against the
    pmax'ed scale at `wire_bits`, staged widening for sub-8 fan-ins) are
    fully engaged without needing multiple devices — the wire-bits
    sensitivity axis of table2."""
    from repro.launch import shard as S
    from repro.launch.mesh import make_cpu_mesh
    from repro.launch.shard import put_batch

    task, tag = (task, "caller") if task is not None else image_task(batch)
    model = build_model(resnet_arch_for(task), qcfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt = init_momentum(params)
    labels = model.labels(params)
    mesh = make_cpu_mesh(1, 1)
    raw, specs = make_sharded_train_step(
        model, qcfg, labels, mesh, params, lr=lr, n_shards=n_shards,
        wire_bits=wire_bits, wire_codec="auto")
    step_fn = jax.jit(raw)
    params = S.shard_arrays(mesh, params, specs["params"])
    opt = S.shard_arrays(mesh, opt, specs["opt"])
    losses = []
    t0 = time.time()
    for s in range(steps):
        b = put_batch(mesh, task.batch(s))
        params, opt, m = step_fn(params, opt, b, jnp.int32(s))
        losses.append(float(m["loss"]))
    accs = []
    fwd = jax.jit(lambda p, b: model.loss(p, b)[1]["acc"])
    for i in range(eval_batches):
        b = jax.tree.map(jnp.asarray, task.holdout_batch(i))
        accs.append(float(fwd(params, b)))
    return {"losses": losses, "acc": float(np.mean(accs)),
            "wall_s": time.time() - t0, "params": params, "model": model,
            "data": tag, "task": task}


def train_lm(qcfg: QConfig, steps: int, batch: int = 8, seq: int = 32,
             lr: float = 0.05, seed: int = 0):
    model = build_model(LM_BENCH, qcfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt = init_momentum(params)
    labels = model.labels(params)
    step_fn = jax.jit(make_train_step(model, qcfg, labels, lr=lr))
    task = TokenTask(vocab=LM_BENCH.vocab, seq_len=seq, global_batch=batch)
    losses = []
    t0 = time.time()
    for s in range(steps):
        b = jax.tree.map(jnp.asarray, task.batch(s))
        params, opt, m = step_fn(params, opt, b, jnp.int32(s))
        losses.append(float(m["loss"]))
    return {"losses": losses, "final_loss": float(np.mean(losses[-5:])),
            "wall_s": time.time() - t0, "params": params, "model": model}


def measure(call, *, warmup: int = 2, min_steps: int | None = None,
            max_steps: int | None = None, target_cv: float = 0.10):
    """Warmup-corrected, CV-guarded wall-clock of a nullary `call`.

    `warmup` untimed calls absorb compile + first-dispatch cost (the old
    steps=2-3 timings charged them to the measurement, which is why
    fused-vs-unfused ratios oscillated 0.80x-1.11x between commits).  Then
    timed calls accumulate until the coefficient of variation of the
    per-call samples drops under `target_cv` — or `max_steps` caps the
    spend (REPRO_BENCH_FAST shrinks both bounds).  The mean discards the
    single slowest sample once there are enough (one GC pause or page-in
    shouldn't own the number).

    Returns (mean_s, cv, n_samples).
    """
    fast = bool(os.environ.get("REPRO_BENCH_FAST"))
    if min_steps is None:
        min_steps = 3 if fast else 6
    if max_steps is None:
        max_steps = 8 if fast else 32
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(call())
    ts: list[float] = []
    while True:
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        ts.append(time.perf_counter() - t0)
        if len(ts) < min_steps:
            continue
        kept = sorted(ts)[:-1] if len(ts) >= 5 else ts
        mu = float(np.mean(kept))
        cv = float(np.std(kept) / mu) if mu > 0 else 0.0
        if cv <= target_cv or len(ts) >= max_steps:
            return mu, cv, len(ts)


def step_cost(jitted, *args) -> dict:
    """flops + HBM bytes of a jitted callable at `args`, from the compiled
    computation's cost_analysis (per device under SPMD).  Older jax returns
    a list of dicts, newer a dict — both handled; missing analysis (some
    backends) degrades to zeros, never raises.
    """
    try:
        ca = jitted.lower(*args).compile().cost_analysis() or {}
    except Exception:
        ca = {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def roofline_derived(cost: dict, dt_s: float, coll_bytes: float = 0.0) -> str:
    """`derived`-field fragment: %-of-roofline at the bf16 AND int8 peaks
    (launch/roofline.measured_fraction) for a timed row."""
    from repro.launch.roofline import measured_fraction

    fr = measured_fraction(cost.get("flops", 0.0), cost.get("bytes", 0.0),
                           dt_s, coll_bytes)
    return (f"%_of_roofline_bf16={fr['pct_bf16'] * 100:.4f};"
            f"%_of_roofline_int8={fr['pct_int8'] * 100:.4f}")


# rows emitted since the last take_records() — benchmarks.run snapshots
# these into the append-style BENCH_<suite>.json trajectory files
RECORDS: list[dict] = []


def emit(name: str, us_per_call: float, derived: str):
    """The harness CSV contract: name,us_per_call,derived."""
    RECORDS.append({"name": name, "us_per_call": round(us_per_call, 1),
                    "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}")


def take_records() -> list[dict]:
    """Drain the emitted-row buffer (one suite's worth when called by the
    benchmarks.run harness between suites)."""
    out, RECORDS[:] = list(RECORDS), []
    return out
