"""Shared benchmark helpers: small-scale training harnesses + CSV output.

The paper's experiments are ResNet/ImageNet-scale; this container is one
CPU core, so every accuracy benchmark runs the same *protocol* at reduced
scale (reduced ResNet on a learnable synthetic image task / tiny LM on the
arithmetic token task).  Scale knobs: REPRO_BENCH_STEPS / REPRO_BENCH_FAST.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import preset
from repro.core.qconfig import QConfig
from repro.data import ImageTask, TokenTask
from repro.launch.train import make_train_step
from repro.models import build_model
from repro.optim import init_momentum


def steps_default(n: int) -> int:
    if os.environ.get("REPRO_BENCH_FAST"):
        return max(8, n // 8)
    return int(os.environ.get("REPRO_BENCH_STEPS", n))


RESNET_BENCH = ArchConfig(name="resnet-bench", family="resnet",
                          block="basic", stage_sizes=(1, 1),
                          num_classes=8, img_size=16)

LM_BENCH = ArchConfig(name="lm-bench", family="lm", n_layers=2, d_model=64,
                      n_heads=4, n_kv=2, d_ff=128, vocab=64, head_dim=16,
                      q_chunk=32, kv_chunk=32)


def train_resnet(qcfg: QConfig, steps: int, batch: int = 64, lr: float = 0.05,
                 seed: int = 0, eval_batches: int = 4):
    model = build_model(RESNET_BENCH, qcfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt = init_momentum(params)
    labels = model.labels(params)
    step_fn = jax.jit(make_train_step(model, qcfg, labels, lr=lr))
    task = ImageTask(img_size=16, num_classes=8, global_batch=batch, seed=1)
    losses = []
    t0 = time.time()
    for s in range(steps):
        b = jax.tree.map(jnp.asarray, task.batch(s))
        params, opt, m = step_fn(params, opt, b, jnp.int32(s))
        losses.append(float(m["loss"]))
    # held-out accuracy (fresh steps the model never trained on)
    accs = []
    fwd = jax.jit(lambda p, b: model.loss(p, b)[1]["acc"])
    for s in range(10_000, 10_000 + eval_batches):
        b = jax.tree.map(jnp.asarray, task.batch(s))
        accs.append(float(fwd(params, b)))
    return {"losses": losses, "acc": float(np.mean(accs)),
            "wall_s": time.time() - t0, "params": params, "model": model}


def train_lm(qcfg: QConfig, steps: int, batch: int = 8, seq: int = 32,
             lr: float = 0.05, seed: int = 0):
    model = build_model(LM_BENCH, qcfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt = init_momentum(params)
    labels = model.labels(params)
    step_fn = jax.jit(make_train_step(model, qcfg, labels, lr=lr))
    task = TokenTask(vocab=LM_BENCH.vocab, seq_len=seq, global_batch=batch)
    losses = []
    t0 = time.time()
    for s in range(steps):
        b = jax.tree.map(jnp.asarray, task.batch(s))
        params, opt, m = step_fn(params, opt, b, jnp.int32(s))
        losses.append(float(m["loss"]))
    return {"losses": losses, "final_loss": float(np.mean(losses[-5:])),
            "wall_s": time.time() - t0, "params": params, "model": model}


# rows emitted since the last take_records() — benchmarks.run snapshots
# these into the append-style BENCH_<suite>.json trajectory files
RECORDS: list[dict] = []


def emit(name: str, us_per_call: float, derived: str):
    """The harness CSV contract: name,us_per_call,derived."""
    RECORDS.append({"name": name, "us_per_call": round(us_per_call, 1),
                    "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}")


def take_records() -> list[dict]:
    """Drain the emitted-row buffer (one suite's worth when called by the
    benchmarks.run harness between suites)."""
    out, RECORDS[:] = list(RECORDS), []
    return out
