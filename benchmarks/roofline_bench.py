"""Roofline benchmark: reads the dry-run artifacts and emits the per-cell
terms (compute/memory/collective seconds, dominant bottleneck, roofline
fraction, useful-FLOP ratio) as CSV rows."""
from __future__ import annotations

import os

from repro.launch.roofline import load_artifacts, terms

from .common import emit

ART_DIR = os.environ.get("REPRO_ART_DIR", "artifacts/dryrun")


def main() -> dict:
    arts = load_artifacts(ART_DIR)
    if not arts:
        emit("roofline/none", 0.0, "no dry-run artifacts yet")
        return {}
    out = {}
    for a in arts:
        t = terms(a)
        key = f"{a['arch']}/{a['shape']}/{a['mesh']}"
        out[key] = t
        emit(f"roofline/{key}", t["step_lower_bound_s"] * 1e6,
             f"dominant={t['dominant']} compute_s={t['compute_s']:.3e} "
             f"memory_s={t['memory_s']:.3e} "
             f"collective_s={t['collective_s']:.3e} "
             f"frac={t['roofline_fraction']:.3f} "
             f"useful={t['useful_ratio']:.3f}")
    return out


if __name__ == "__main__":
    main()
