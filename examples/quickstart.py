"""Quickstart: train a tiny LM fully in 8-bit integers (WAGEUBN) on CPU.

    PYTHONPATH=src python examples/quickstart.py

Shows the public API end to end: config -> model -> quantized train step ->
losses under FP32 vs full-INT8 side by side.
"""
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import preset
from repro.data import TokenTask
from repro.launch.train import make_train_step
from repro.models import build_model
from repro.optim import init_momentum

ARCH = ArchConfig(name="quickstart", family="lm", n_layers=2, d_model=64,
                  n_heads=4, n_kv=2, d_ff=128, vocab=64, head_dim=16,
                  q_chunk=32, kv_chunk=32)


def train(qcfg, steps=60):
    model = build_model(ARCH, qcfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_momentum(params)
    step_fn = jax.jit(make_train_step(model, qcfg, model.labels(params)))
    task = TokenTask(vocab=ARCH.vocab, seq_len=32, global_batch=8)
    hist = []
    for s in range(steps):
        batch = jax.tree.map(jnp.asarray, task.batch(s))
        params, opt, m = step_fn(params, opt, batch, jnp.int32(s))
        hist.append(float(m["loss"]))
    return hist


if __name__ == "__main__":
    from repro.core import registered_quantizers
    from repro.kernels.ops import dispatch_banner
    print(dispatch_banner())
    print("registered quantizers:", ", ".join(registered_quantizers()))
    print("training the same tiny LM under four numeric configs...")
    for name, mode in (("fp32", None), ("e2_16", "sim"), ("full8", "sim"),
                       ("full8", "native")):
        qcfg = preset(name, mode)
        hist = train(qcfg)
        label = name if mode in (None, "sim") else f"{name}/{mode}"
        print(f"{label:12s} loss: {hist[0]:.3f} -> {hist[-1]:.3f} "
              f"(min {min(hist):.3f})")
    print("\nWAGEUBN full-INT8 training tracks FP32 — the paper's core claim."
          "\n(native mode carries int8 QTensor payloads end to end; sim mode"
          "\ncarries the same grid values in fp32 — bit-identical forward.)")
