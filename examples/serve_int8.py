"""Serving example: continuous-batching int8 engine over a paged KV pool.

    PYTHONPATH=src python examples/serve_int8.py [--arch granite-3-8b]

Usage (engine path, the default):
  * builds the reduced config of the assigned arch at CPU scale and wraps
    it in `repro.serving.Engine` — a paged int8 QTensor KV-cache pool, a
    QUEUED->PREFILL->DECODE->DONE scheduler with admission control and
    recompute preemption, and one fused jit decode step over padded lanes;
  * replays staggered Poisson arrivals with mixed prompt/generation
    lengths through `run_load` (open loop, `--rate` req/s);
  * prints per-request metrics (TTFT, tokens), engine aggregates (decode
    tok/s, preemptions, stragglers) and the pool's int8-vs-fp32 byte
    report (~4x footprint ratio => ~4x more resident sequences).

Flags:
  --arch / --mode       model family + numeric mode (native: the int8 KV
                        pages feed the decode matmuls as QTensor payloads)
  --batch / --prompt-len / --gen / --rate
                        traffic shape: number of requests, prompt length
                        set base, generation length, arrival rate
  --lanes / --page-size / --max-ctx
                        engine geometry (decode batch width, KV page size)
  --legacy              the PR-1 path: one fixed batch, raw serve_step
                        loop on a contiguous int8 cache (no engine)
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.core import preset
from repro.models import build_model
from repro.serving import Engine, greedy_token, poisson_traffic, run_load


def legacy_main(args, acfg, model, params):
    """Raw serve_step loop: batched prefill + greedy decode, no engine."""
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, acfg.vocab)
    t0 = time.time()
    if acfg.family == "ssm":
        cache, logits = model.prefill(params, prompts)
    else:
        cache, logits = model.prefill(params, prompts,
                                      args.prompt_len + args.gen)
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s")

    step = jax.jit(model.serve_step)
    toks = greedy_token(logits, acfg.vocab)
    out = [toks]
    t0 = time.time()
    for _ in range(args.gen - 1):
        cache, logits = step(params, cache, toks)
        toks = greedy_token(logits, acfg.vocab)
        out.append(toks)
    dt = time.time() - t0
    gen = jnp.stack(out, axis=1)
    print(f"decoded {args.gen - 1} steps x {args.batch} seqs in {dt:.2f}s "
          f"({(args.gen - 1) * args.batch / dt:.1f} tok/s, int8 KV cache)")
    print("sample generation (token ids):", gen[0].tolist())


def engine_main(args, acfg, model, params):
    engine = Engine(model, params, max_lanes=args.lanes,
                    page_size=args.page_size, max_ctx=args.max_ctx)
    traffic = poisson_traffic(
        rate=args.rate, n_requests=args.batch,
        prompt_lens=(args.prompt_len, args.prompt_len + 8),
        gen_lens=(args.gen, max(2, args.gen // 2)), vocab=acfg.vocab)
    t0 = time.time()
    results, metrics = run_load(engine, traffic)
    wall = time.time() - t0

    for req in sorted(engine.scheduler.requests.values(),
                      key=lambda r: r.rid):
        print(f"req {req.rid}: prompt {len(req.prompt) - req.n_folded:3d} "
              f"gen {len(req.generated):3d} ttft {req.ttft * 1e3:7.1f}ms "
              f"preempts {req.preemptions}")
    print(f"served {metrics['completed']} requests in {wall:.2f}s: "
          f"{metrics['generated_tokens']} tokens, "
          f"{metrics['decode_tok_s']:.1f} decode tok/s, "
          f"{metrics['decode_steps']} fused steps, "
          f"{metrics['preemptions']} preemptions, "
          f"{metrics['straggler_steps']} stragglers")
    if "pool" in metrics:
        p = metrics["pool"]
        print(f"pool: {p['n_pages']} pages x {p['page_size']} tok, "
              f"peak {p['peak_in_use']} in use, int8 "
              f"{p['pool_bytes_int8']} B vs fp32 "
              f"{p['pool_bytes_fp32_equiv']} B "
              f"({p['footprint_ratio']:.2f}x => "
              f"{p['capacity_seqs_int8']} resident seqs vs "
              f"{p['capacity_seqs_fp32']} at the same budget)")
    sample = results[min(results)]
    print("sample generation (token ids):", sample)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="granite-3-8b")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=24)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--mode", default="native", choices=["sim", "native"],
                   help="native: the int8 KV cache is consumed as QTensors —"
                        " decode matmuls run on the cache payloads directly")
    p.add_argument("--rate", type=float, default=16.0,
                   help="Poisson arrival rate (req/s) for the engine path")
    p.add_argument("--lanes", type=int, default=4)
    p.add_argument("--page-size", type=int, default=8)
    p.add_argument("--max-ctx", type=int, default=0,
                   help="0: sized from prompt-len + gen")
    p.add_argument("--legacy", action="store_true",
                   help="raw serve_step loop instead of the engine")
    args = p.parse_args()
    if not args.max_ctx:
        args.max_ctx = args.prompt_len + 8 + args.gen

    acfg = get(args.arch).reduced()
    qcfg = preset("full8", args.mode)
    from repro.kernels.ops import dispatch_banner
    print(dispatch_banner(qcfg))
    model = build_model(acfg, qcfg)
    params = model.init(jax.random.PRNGKey(0))

    if args.legacy:
        legacy_main(args, acfg, model, params)
    else:
        engine_main(args, acfg, model, params)


if __name__ == "__main__":
    main()
