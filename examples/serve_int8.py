"""Serving example: batched prefill + decode with an int8 KV cache.

    PYTHONPATH=src python examples/serve_int8.py [--arch granite-3-8b]

Uses the reduced config of an assigned arch (CPU scale), runs a batch of
prompts through prefill, then greedy-decodes tokens step by step — the same
serve_step the decode_32k / long_500k dry-run cells lower at full scale.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.core import preset
from repro.models import build_model


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="granite-3-8b")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=24)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--mode", default="native", choices=["sim", "native"],
                   help="native: the int8 KV cache is consumed as QTensors —"
                        " decode matmuls run on the cache payloads directly")
    args = p.parse_args()

    acfg = get(args.arch).reduced()
    qcfg = preset("full8", args.mode)
    model = build_model(acfg, qcfg)
    params = model.init(jax.random.PRNGKey(0))

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, acfg.vocab)
    t0 = time.time()
    if acfg.family == "ssm":
        cache, logits = model.prefill(params, prompts)
    else:
        cache, logits = model.prefill(params, prompts,
                                      args.prompt_len + args.gen)
    print(f"prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s")

    step = jax.jit(model.serve_step)
    toks = jnp.argmax(logits[:, : acfg.vocab], axis=-1)
    out = [toks]
    t0 = time.time()
    for _ in range(args.gen - 1):
        cache, logits = step(params, cache, toks)
        toks = jnp.argmax(logits[:, : acfg.vocab], axis=-1)
        out.append(toks)
    dt = time.time() - t0
    gen = jnp.stack(out, axis=1)
    print(f"decoded {args.gen - 1} steps x {args.batch} seqs in {dt:.2f}s "
          f"({(args.gen - 1) * args.batch / dt:.1f} tok/s, int8 KV cache)")
    print("sample generation (token ids):", gen[0].tolist())


if __name__ == "__main__":
    main()
