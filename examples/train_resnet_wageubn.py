"""The paper's own experiment at CPU scale: ResNet under WAGEUBN.

    PYTHONPATH=src python examples/train_resnet_wageubn.py [--steps 120]

Trains the reduced ResNet on the learnable synthetic image task under the
paper's three numeric configs and prints the Table-I-style comparison.
"""
import argparse
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import train_resnet  # noqa: E402
from repro.core import preset  # noqa: E402
from repro.kernels.ops import dispatch_banner, dispatch_report  # noqa: E402


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=120)
    args = p.parse_args()
    print(dispatch_banner())
    print(f"{'config':15s} {'path':15s} {'holdout acc':12s} {'us/step':10s}")
    for name, mode in (("fp32", None), ("e2_16", "sim"), ("full8", "sim"),
                       ("full8", "native")):
        qcfg = preset(name, mode)
        r = train_resnet(qcfg, args.steps)
        label = name if mode in (None, "sim") else f"{name}/{mode}"
        rep = dispatch_report(qcfg)
        path = f"{rep['route']}/" + ("fused" if rep["fused"] else "unfused")
        print(f"{label:15s} {path:15s} {r['acc']:<12.4f} "
              f"{r['wall_s'] / args.steps * 1e6:<10.0f}")


if __name__ == "__main__":
    main()
