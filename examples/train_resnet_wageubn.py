"""The paper's own experiment at CPU scale: ResNet under WAGEUBN.

    PYTHONPATH=src python examples/train_resnet_wageubn.py [--steps 120]

Trains the reduced ResNet on the resolved image task (the real npz
pipeline when REPRO_DATA_DIR / --data-dir points at shards, the learnable
synthetic task otherwise) under the paper's numeric configs plus the
sub-8 / wide-gradient lanes (DESIGN.md §14), and prints the Table-I-style
comparison.  --dr-boundaries drives the paper's CQ dr shrink schedule
(k_gw -> k_gw-1 -> ... at the listed steps).
"""
import argparse
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import image_task, train_resnet  # noqa: E402
from repro.core import preset  # noqa: E402
from repro.data import resolve_image_task  # noqa: E402
from repro.kernels.ops import dispatch_banner, dispatch_report  # noqa: E402
from repro.optim import parse_boundaries  # noqa: E402


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=120)
    p.add_argument("--data-dir", default="",
                   help="npz shard directory (default: $REPRO_DATA_DIR, "
                        "else the synthetic task)")
    p.add_argument("--dr-boundaries", default="",
                   help="comma-separated steps where the CQ dr width "
                        "shrinks by one bit (e.g. '60,90'); empty = flat "
                        "at k_gw")
    args = p.parse_args()
    bounds = parse_boundaries(args.dr_boundaries)
    if args.data_dir:
        task, data = resolve_image_task(64, data_dir=args.data_dir)
    else:
        task, data = image_task(64)
    print(dispatch_banner())
    print(f"[data] {data}  dr_boundaries={bounds or '(none)'}")
    print(f"{'config':15s} {'path':15s} {'holdout acc':12s} {'us/step':10s}")
    for name, mode in (("fp32", None), ("e2_16", "sim"), ("full8", "sim"),
                       ("w4a8", "sim"), ("a4", "sim"), ("g16", "sim"),
                       ("full8", "native")):
        qcfg = preset(name, mode)
        r = train_resnet(qcfg, args.steps, task=task, dr_boundaries=bounds)
        label = name if mode in (None, "sim") else f"{name}/{mode}"
        rep = dispatch_report(qcfg)
        path = f"{rep['route']}/" + ("fused" if rep["fused"] else "unfused")
        print(f"{label:15s} {path:15s} {r['acc']:<12.4f} "
              f"{r['wall_s'] / args.steps * 1e6:<10.0f}")


if __name__ == "__main__":
    main()
