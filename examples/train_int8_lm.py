"""End-to-end driver: train a ~small LM for a few hundred steps under full
INT8 WAGEUBN with the whole production substrate engaged — deterministic
sharded data pipeline with background prefetch, async atomic checkpoints,
fault-tolerant runner (auto-restores on crash), straggler watchdog, and the
quantized Momentum optimizer with the dr-shrink schedule.

    PYTHONPATH=src python examples/train_int8_lm.py \
        --steps 300 --d-model 256 --layers 4 [--fail-at 120]

With --elastic the run goes through the ElasticRunner instead (DESIGN.md
§11): the sharded DP step, packed QTensor checkpoints, restore-on-failure
and bit-exact resume across DP membership changes — e.g. train under
--dp 4, kill it, then resume the SAME trajectory under --dp 2:

    PYTHONPATH=src python examples/train_int8_lm.py \
        --elastic --dp 4 --n-shards 4 --steps 300 [--fail-at 120]
    PYTHONPATH=src python examples/train_int8_lm.py \
        --elastic --dp 2 --n-shards 4 --steps 300 --resume

(The elastic path feeds batches straight from TokenTask — deterministic
in the step index, which the bit-exact-resume contract requires; the
background Prefetcher of the classic path is NOT resume-deterministic.)

At the default size this is a ~10M-parameter model; scale --d-model /
--layers / --seq up to the ~100M regime on a bigger host (the code path is
identical — the assigned full-scale configs run through the same builders).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig
from repro.core import preset
from repro.core.qconfig import PRESETS
from repro.data import TokenTask
from repro.data.synthetic import Prefetcher
from repro.launch.train import make_train_step
from repro.models import build_model
from repro.optim import dr_bits_schedule, init_momentum, parse_boundaries
from repro.runtime import StepWatchdog, TrainRunner


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--layers", type=int, default=4)
    p.add_argument("--d-model", type=int, default=256)
    p.add_argument("--d-ff", type=int, default=512)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--vocab", type=int, default=512)
    p.add_argument("--preset", default="full8",
                   choices=sorted(PRESETS))
    p.add_argument("--dr-boundaries", default="",
                   help="comma-separated steps where the CQ dr width "
                        "shrinks by one bit (paper's epoch schedule); "
                        "default: steps/2,3*steps/4")
    p.add_argument("--mode", default="sim", choices=["sim", "native"],
                   help="native: activations/weights flow as int8 QTensors "
                        "into the integer matmul kernels")
    p.add_argument("--ckpt-dir", default="/tmp/int8_lm_ckpt")
    p.add_argument("--fail-at", type=int, default=None,
                   help="inject a crash at this step (fault-tolerance demo)")
    p.add_argument("--elastic", action="store_true",
                   help="drive the run through the ElasticRunner "
                        "(sharded step + packed QTensor checkpoints + "
                        "bit-exact DP reshard)")
    p.add_argument("--dp", type=int, default=1,
                   help="elastic: data-parallel mesh size")
    p.add_argument("--n-shards", type=int, default=0,
                   help="elastic: virtual batch shards (quantization "
                        "granularity; fixed across resumes); 0 = dp")
    p.add_argument("--resume", action="store_true",
                   help="elastic: resume from the latest checkpoint in "
                        "--ckpt-dir (any dp dividing --n-shards)")
    p.add_argument("--save-every", type=int, default=50)
    args = p.parse_args()

    arch = ArchConfig(name="int8-lm", family="lm", n_layers=args.layers,
                      d_model=args.d_model, n_heads=args.d_model // 64 or 2,
                      n_kv=max((args.d_model // 64) // 2, 1),
                      d_ff=args.d_ff, vocab=args.vocab, head_dim=64,
                      q_chunk=128, kv_chunk=128)
    qcfg = preset(args.preset, args.mode if args.preset != "fp32" else None)
    model = build_model(arch, qcfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params, preset={args.preset}")
    from repro.kernels.ops import dispatch_banner
    print(dispatch_banner(qcfg))

    labels = model.labels(params)
    task = TokenTask(vocab=arch.vocab, seq_len=args.seq,
                     global_batch=args.batch)

    if args.elastic:
        from repro.runtime import ElasticRunner
        n_shards = args.n_shards or args.dp
        ckpt = CheckpointManager(args.ckpt_dir, keep=2)
        runner = ElasticRunner(model, qcfg, labels, ckpt, task.batch,
                               dp=args.dp, n_shards=n_shards, dr_bits=8,
                               save_every=args.save_every,
                               watchdog=StepWatchdog())
        print(f"[elastic] dp={args.dp} n_shards={n_shards} "
              f"save_every={args.save_every} resume={args.resume}")
        t0 = time.time()
        params, opt, m = runner.run(params, init_momentum(params),
                                    args.steps, resume=args.resume,
                                    fail_at=args.fail_at)
        rep = ckpt.size_report()
        print(f"done in {time.time()-t0:.1f}s; final loss "
              f"{float(m['loss']):.4f}; restarts={runner.restarts}; "
              f"reshards={len(runner.reshards)}")
        print(f"[ckpt] {rep['ckpt_bytes_q']} B packed vs "
              f"{rep['ckpt_bytes_f32_dense']} B dense-f32 "
              f"({rep['ratio']:.2f}x)")
        return

    opt = init_momentum(params)
    # dr shrinks like the paper's epoch schedule (k_gw -> k_gw-1 -> ...)
    boundaries = (parse_boundaries(args.dr_boundaries)
                  or (args.steps // 2, 3 * args.steps // 4))
    step_fns = {b: jax.jit(make_train_step(
        model, qcfg, labels,
        dr_bits=dr_bits_schedule(b, boundaries, base_bits=qcfg.k_gw)))
        for b in (0,) + boundaries}

    prefetch = Prefetcher(lambda s: task.batch(s), depth=2)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    def one_step(state, step):
        params, opt = state
        _, host_batch = prefetch.get()
        batch = jax.tree.map(jnp.asarray, host_batch)
        fn = step_fns[max(b for b in step_fns if b <= step)]
        params, opt, m = fn(params, opt, batch, jnp.int32(step))
        if step % 20 == 0:
            print(f"  step {step:4d} loss {float(m['loss']):.4f}")
        return (params, opt), m

    runner = TrainRunner(one_step, ckpt, save_every=50,
                         watchdog=StepWatchdog())
    t0 = time.time()
    (params, opt), m = runner.run((params, opt), args.steps,
                                  fail_at=args.fail_at)
    prefetch.close()
    print(f"done in {time.time()-t0:.1f}s; final loss "
          f"{float(m['loss']):.4f}; restarts={runner.restarts}; "
          f"stragglers flagged={len(runner.watchdog.flags)}")


if __name__ == "__main__":
    main()
