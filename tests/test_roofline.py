"""Roofline: collective parser on canned HLO + term arithmetic."""
from repro.launch.roofline import parse_collectives, terms

CANNED = """
HloModule jit_f, num_partitions=8
%all-reduce = f32[32,32]{1,0} all-reduce(%dot), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add
%wrapped = f32[] fusion(%all-reduce, %c), kind=kLoop, calls=%wc
%ag = bf16[64,128]{1,0} all-gather(%p0), channel_id=2, replica_groups=[4,2]<=[8], dimensions={0}
%rs = f32[16,32]{1,0} reduce-scatter(%p1), channel_id=3, replica_groups=[2,4]<=[8], to_apply=%add
%cp = s8[1024]{0} collective-permute(%p2), channel_id=4, source_target_pairs={{0,1},{1,0}}
%a2a = f32[8,16]{1,0} all-to-all(%p3), channel_id=5, replica_groups={{0,1,2,3}}
ROOT %all-reduce.1 = f32[] all-reduce(%w), channel_id=6, replica_groups=[4,2]<=[2,4]T(1,0), to_apply=%r
"""


def test_parse_collectives_ops_and_bytes():
    c = parse_collectives(CANNED)
    assert c["all-reduce"]["count"] == 2
    assert c["all-reduce"]["bytes"] == 32 * 32 * 4 + 4
    # all-gather: result 64*128*2 bytes bf16, group 2 -> operand = result/2
    assert c["all-gather"]["bytes"] == 64 * 128 * 2 // 2
    # reduce-scatter: result 16*32*4, group 4 -> operand = result*4
    assert c["reduce-scatter"]["bytes"] == 16 * 32 * 4 * 4
    assert c["collective-permute"]["bytes"] == 1024
    assert c["all-to-all"]["bytes"] == 8 * 16 * 4
    assert c["all-to-all"]["count"] == 1


def test_parse_ignores_operand_name_mentions():
    c = parse_collectives("%x = f32[] fusion(%all-reduce, %c), calls=%wc\n")
    assert c == {}


def test_terms_dominance():
    art = {
        "flops_per_device": 197e12,      # exactly 1 s of bf16 compute
        "bytes_per_device": 819e9 / 2,   # 0.5 s of HBM
        "collective_bytes_per_device": 50e9 / 4,  # 0.25 s of ICI
        "devices": 256,
        "model_flops_global": 197e12 * 256 * 0.8,
    }
    t = terms(art)
    assert t["dominant"] == "compute"
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 0.5) < 1e-9
    assert abs(t["collective_s"] - 0.25) < 1e-9
    assert abs(t["roofline_fraction"] - 1.0) < 1e-9
    assert abs(t["useful_ratio"] - 0.8) < 1e-9
    assert abs(t["compute_int8_s"] - 0.5) < 1e-9


def test_terms_memory_bound():
    art = {"flops_per_device": 1e9, "bytes_per_device": 819e9,
           "collective_bytes_per_device": 0, "devices": 2,
           "model_flops_global": 2e9}
    t = terms(art)
    assert t["dominant"] == "memory"
    assert t["roofline_fraction"] < 0.01
