"""Autotuner cache + integer wire-codec unit tests (DESIGN.md §13).

Two contracts under test:

  * the block-shape autotuner is an ACCELERATOR, never a dependency — a
    missing, corrupt, truncated, or wrong-schema cache entry degrades to
    the op's shipped defaults silently, and a tuned entry can change
    wall-clock but not one output bit (tiles are blocking-only knobs);

  * the wire codec's int8 pair packing is a lossless bit-pattern
    transform — every int8 value (including -128) round-trips exactly
    through the two-per-int16 wire format.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops
from repro.runtime.compress import pack_int8_pairs, unpack_int16_pairs


@pytest.fixture()
def tuned_dir(tmp_path, monkeypatch):
    """Point the cache at a throwaway dir; memo cleared on both sides so a
    test can simulate a fresh process by calling clear_memo itself."""
    monkeypatch.setenv("REPRO_AUTOTUNE_DIR", str(tmp_path))
    autotune.clear_memo()
    yield str(tmp_path)
    autotune.clear_memo()


# --------------------------------------------------------------------------
# cache mechanics
# --------------------------------------------------------------------------


def test_cache_roundtrip_survives_restart(tuned_dir):
    sig = ((64, 64), "int8", (64, 64), "int8", False)
    tiles = {"bm": 256, "bn": 128, "bk": 256}
    autotune.store("qmatmul", sig, tiles, 12.5)
    assert autotune.lookup("qmatmul", sig) == tiles
    # new process == empty memo; the entry must come back from disk
    autotune.clear_memo()
    assert autotune.lookup("qmatmul", sig) == tiles
    assert autotune.tiles_for("qmatmul", sig,
                              {"bm": 128, "bn": 128, "bk": 256}) == tiles
    es = autotune.entries()
    assert len(es) == 1 and es[0]["op"] == "qmatmul"
    assert es[0]["us"] == 12.5


def test_corrupt_or_truncated_entry_falls_back_to_defaults(tuned_dir):
    sig = ((64, 64), "int8", (64, 64), "int8", False)
    defaults = {"bm": 128, "bn": 128, "bk": 256}
    key = autotune.cache_key("qmatmul", sig)
    path = os.path.join(tuned_dir, key + ".json")

    for garbage in ("not json at all", '{"schema": 1, "op": "qmatm',
                    '{"schema": 99, "op": "qmatmul", "tiles": {"bm": 1}}',
                    '{"schema": 1, "op": "qmatmul", "tiles": [1, 2]}'):
        with open(path, "w") as f:
            f.write(garbage)
        autotune.clear_memo()
        assert autotune.lookup("qmatmul", sig) is None
        assert autotune.tiles_for("qmatmul", sig, dict(defaults)) == defaults
    # entries() skips the broken file rather than raising
    assert autotune.entries() == []
    # and a missing cache dir is also just a miss
    autotune.clear_memo()
    os.remove(path)
    assert autotune.tiles_for("qmatmul", sig, dict(defaults)) == defaults


def test_cache_key_sensitivity(tuned_dir, monkeypatch):
    sig = ((64, 64), "int8", (64, 64), "int8", False)
    base = autotune.cache_key("qmatmul", sig)
    # shape, dtype, static flag, and op all invalidate
    assert autotune.cache_key("qmatmul",
                              ((64, 128), "int8", (64, 64), "int8",
                               False)) != base
    assert autotune.cache_key("qmatmul",
                              ((64, 64), "int16", (64, 64), "int8",
                               False)) != base
    assert autotune.cache_key("qmatmul",
                              ((64, 64), "int8", (64, 64), "int8",
                               True)) != base
    assert autotune.cache_key("dgrad", sig) != base
    # a different backend never reads this backend's timings
    monkeypatch.setattr(autotune.jax, "default_backend", lambda: "not-cpu")
    assert autotune.cache_key("qmatmul", sig) != base
    # tuple/list spelling of a shape is the same key (JSON canonical form)
    monkeypatch.undo()
    assert autotune.cache_key(
        "qmatmul", ([64, 64], "int8", [64, 64], "int8", False)) == base


def test_stale_entry_cannot_inject_unknown_kwargs(tuned_dir):
    sig = ((32, 32), "rms")
    autotune.store("ubn_norm", sig, {"bt": 64, "legacy_knob": 7}, 1.0)
    autotune.clear_memo()
    got = autotune.tiles_for("ubn_norm", sig, {"bt": 128})
    assert got == {"bt": 64}  # only knobs the defaults name come through


def test_tune_skips_failing_candidates_and_persists_winner(tuned_dir):
    calls = []

    def call(tiles):
        calls.append(dict(tiles))
        if tiles.get("explode"):
            raise RuntimeError("tile too large for shape")
        return jnp.zeros((4,))

    won = autotune.tune("qmatmul", ("sig",), call,
                        candidates=({"explode": True}, {"bm": 64}), reps=1)
    assert won == {"bm": 64}
    assert {"explode": True} in calls          # it was attempted
    autotune.clear_memo()
    assert autotune.lookup("qmatmul", ("sig",)) == {"bm": 64}
    with pytest.raises(RuntimeError):
        autotune.tune("qmatmul", ("s2",), call,
                      candidates=({"explode": True},), reps=1)


def test_ds_tuple_round_trips_through_json(tuned_dir):
    autotune.store("flash_attention", ("warm", "default"),
                   {"ds": ("arbitrary", "arbitrary")}, 0.0)
    autotune.clear_memo()
    got = autotune.tiles_for("flash_attention", ("warm", "default"),
                             {"ds": ("parallel", "arbitrary")})
    assert got == {"ds": ("arbitrary", "arbitrary")}
    assert isinstance(got["ds"], tuple)  # pallas wants a tuple, not a list


def test_banner_and_report_surface(tuned_dir):
    assert autotune.banner_fragment() == "tiles=defaults"
    assert autotune.report_rows() == []
    autotune.store("qmatmul", ("s",), {"bm": 256, "bn": 128, "bk": 256}, 3.0)
    autotune.store("ubn_norm", ("s",), {"bt": 64}, 2.0)
    frag = autotune.banner_fragment()
    assert frag.startswith("tiles=") and "qmatmul:" in frag
    assert "bm=256" in frag and "ubn_norm:bt=64" in frag
    ops_listed = [r[0] for r in autotune.report_rows()]
    assert ops_listed == ["qmatmul", "ubn_norm"]


# --------------------------------------------------------------------------
# tuned tiles are numerics-neutral (bit-identity through the dispatch)
# --------------------------------------------------------------------------


def _store_all(op, sig, tiles):
    autotune.store(op, sig, tiles, 1.0)
    autotune.clear_memo()


def test_tuned_qmatmul_bit_identical_to_defaults(tuned_dir):
    rng = np.random.default_rng(0)
    a8 = jnp.asarray(rng.integers(-127, 128, (160, 96)), jnp.int8)
    b8 = jnp.asarray(rng.integers(-127, 128, (96, 80)), jnp.int8)
    want = np.asarray(ops.qmatmul_op(a8, b8, force_kernel=True))  # defaults
    sig = (a8.shape, "int8", b8.shape, "int8", False)
    for tiles in ({"bm": 64, "bn": 32, "bk": 32},
                  {"bm": 256, "bn": 256, "bk": 128}):
        _store_all("qmatmul", sig, tiles)
        got = np.asarray(ops.qmatmul_op(a8, b8, force_kernel=True))
        np.testing.assert_array_equal(got, want)
    # and the oracle route (what CPU actually executes) agrees too
    np.testing.assert_array_equal(np.asarray(ops.qmatmul_op(a8, b8)), want)


def test_tuned_ubn_bit_identical_and_clamped_to_fit(tuned_dir):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((96, 64)), jnp.float32)
    gamma = jnp.asarray(rng.standard_normal((64,)), jnp.float32)
    want = [np.asarray(o) for o in
            ops.ubn_norm_op(x, gamma, kind="rms", force_kernel=True)]
    # a tuned bt beyond the VMEM-fit heuristic must clamp, not crash: store
    # an absurd tile and a small one, both must reproduce the default bits
    for bt in (8192, 16):
        _store_all("ubn_norm", (x.shape, "rms"), {"bt": bt})
        got = ops.ubn_norm_op(x, gamma, kind="rms", force_kernel=True)
        for w, g in zip(want, got):
            np.testing.assert_array_equal(np.asarray(g), w)


def test_warm_fast_populates_every_op(tuned_dir):
    won = autotune.warm(fast=True, verbose=False)
    assert set(won) == set(autotune.CANDIDATES)
    es = autotune.entries()
    assert {e["op"] for e in es} == set(autotune.CANDIDATES)
    # a fresh process resolves the warmed qmatmul entry through tiles_for
    autotune.clear_memo()
    sig = ((128, 128), "int8", (128, 128), "int8", False)
    tuned = autotune.tiles_for("qmatmul", sig,
                               {"bm": 128, "bn": 128, "bk": 256})
    assert tuned in autotune.CANDIDATES["qmatmul"][:2]
    assert autotune.banner_fragment() != "tiles=defaults"


# --------------------------------------------------------------------------
# wire codec: int8 pair packing
# --------------------------------------------------------------------------


def test_pack_roundtrip_every_int8_value():
    x = jnp.asarray(np.arange(-128, 128, dtype=np.int8))
    p = pack_int8_pairs(x)
    assert p.dtype == jnp.int16 and p.shape == (128,)
    np.testing.assert_array_equal(np.asarray(unpack_int16_pairs(p)),
                                  np.asarray(x))


def test_pack_roundtrip_random_batched():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.integers(-128, 128, (3, 5, 64)), jnp.int8)
    p = pack_int8_pairs(x)
    assert p.shape == (3, 5, 32)
    np.testing.assert_array_equal(np.asarray(unpack_int16_pairs(p)),
                                  np.asarray(x))


def test_pack_layout_is_little_endian_pairs():
    # element i of the wire word carries (x[2i] low byte, x[2i+1] high)
    x = jnp.asarray([1, 2, -128, 127], jnp.int8)
    p = np.asarray(pack_int8_pairs(x))
    assert p[0] == (2 << 8) | 1
    assert np.int16(p[1]) == np.int16((127 << 8) | 0x80)
