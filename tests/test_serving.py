"""Serving engine: page-pool invariants, paged-vs-contiguous bit-exactness,
engine-vs-naive greedy equivalence, preemption correctness, continuous
batching beating sequential serving on step count, watchdog wiring."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.core import preset
from repro.models import build_model
from repro.runtime.fault import StepWatchdog
from repro.serving import (Engine, PagePool, RequestState,
                           fused_decode_active, greedy_token, make_engine,
                           make_sampler, poisson_traffic)


# --------------------------------------------------------------------------
# PagePool
# --------------------------------------------------------------------------


def _pool(n_pages=9, page_size=4):
    return PagePool(n_pages, page_size, kv_layers=2, n_kv=2, dh=4)


def test_pool_alloc_free_reuse_invariants():
    pool = _pool()
    assert pool.usable == 8 and pool.free_count == 8
    a = pool.alloc(3, owner="a")
    b = pool.alloc(5, owner="b")
    assert len(a) == 3 and len(b) == 5
    assert 0 not in a + b                       # trash page never handed out
    assert len(set(a + b)) == 8                 # no double allocation
    assert pool.in_use == 8 and pool.free_count == 0
    assert pool.alloc(1) is None                # exhausted: no partial grant
    assert pool.failed_allocs == 1
    pool.free(a)
    assert pool.free_count == 3
    c = pool.alloc(3, owner="c")
    assert set(c) == set(a)                     # freed pages are reused
    with pytest.raises(ValueError):
        pool.free([b[0], b[0]])                 # double free detected
    assert pool.peak_in_use == 8
    assert pool.allocs == 11 and pool.frees >= 3


def test_pool_pages_for_and_report_ratio():
    pool = _pool(n_pages=17, page_size=4)
    assert pool.pages_for(1) == 1 and pool.pages_for(4) == 1
    assert pool.pages_for(5) == 2
    rep = pool.report(ctx_len=16)
    # int8 payloads + tiny scale overhead vs fp32 of the same geometry
    assert rep["footprint_ratio"] > 3.9
    assert rep["capacity_seqs_int8"] >= 4 * max(1, rep["capacity_seqs_fp32"])


def test_pool_defrag_compacts_and_preserves_payloads():
    pool = _pool(n_pages=9, page_size=4)
    a = pool.alloc(2, owner="a")
    b = pool.alloc(2, owner="b")
    c = pool.alloc(2, owner="c")
    for pid in a + b + c:
        pool.k = pool.k.at[:, pid].set(jnp.int8(pid))
    pool.free(b)
    mapping = pool.defrag()
    new_a = [mapping.get(p, p) for p in a]
    new_c = [mapping.get(p, p) for p in c]
    assert sorted(new_a + new_c) == [1, 2, 3, 4]   # compacted to the front
    for old, new in zip(a + c, new_a + new_c):
        np.testing.assert_array_equal(np.asarray(pool.k[:, new]),
                                      np.full((2, 4, 2, 4), old, np.int8))
    assert pool.free_count == 4
    d = pool.alloc(4, owner="d")
    assert d is not None and len(set(d) & {1, 2, 3, 4}) == 0


# --------------------------------------------------------------------------
# paged cache == contiguous cache, engine == naive batched decode
# --------------------------------------------------------------------------


def _naive_batched(model, params, prompts, max_new, T):
    """What the engine computes, minus paging: per-request prefill, stacked
    contiguous int8 cache, jointly batched greedy serve_step loop."""
    a = model.a
    toks = []
    if a.family == "ssm":
        parts = []
        for p in prompts:
            st, logits = model.prefill(params, jnp.asarray(p)[None])
            parts.append(st)
            toks.append(int(greedy_token(logits, a.vocab)[0]))
        cache = {k: jnp.concatenate([c[k] for c in parts],
                                    axis=0 if k == "pos" else 1)
                 for k in parts[0]}
    else:
        cache = model.init_cache(len(prompts), T)
        for b, p in enumerate(prompts):
            c, logits = model.prefill(params, jnp.asarray(p)[None], T)
            for k in ("k", "v", "m_conv", "m_h"):
                if k in cache:
                    cache[k] = cache[k].at[:, b].set(c[k][:, 0])
            cache["pos"] = cache["pos"].at[b].set(len(p))
            toks.append(int(greedy_token(logits, a.vocab)[0]))
    gens = [[t] for t in toks]
    step = jax.jit(model.serve_step)
    tok = jnp.asarray(toks, jnp.int32)
    for _ in range(max_new - 1):
        cache, logits = step(params, cache, tok)
        tok = greedy_token(logits, a.vocab)
        for b in range(len(prompts)):
            gens[b].append(int(tok[b]))
    return gens


PROMPTS = [np.arange(1, 9), np.arange(3, 15)]


@pytest.mark.parametrize("arch,mode", [("granite-3-8b", "native"),
                                       ("granite-3-8b", "sim"),
                                       ("granite-moe-1b-a400m", "native"),
                                       ("zamba2-7b", "native"),
                                       ("falcon-mamba-7b", "native")])
def test_engine_matches_naive_batched_decode(arch, mode):
    """Same-arrival batch: the continuous-batching engine greedy-decodes
    EXACTLY the tokens of the naive contiguous-cache serve_step loop."""
    eng = make_engine(arch, mode=mode, max_lanes=2, page_size=4, max_ctx=32)
    rids = [eng.submit(p, 6) for p in PROMPTS]
    out = eng.drain()
    naive = _naive_batched(eng.model, eng.params, PROMPTS, 6, 32)
    for b, rid in enumerate(rids):
        assert out[rid] == naive[b], (arch, mode, b)


def test_qtensor_pages_roundtrip_contiguous_cache():
    """Prefill KV written through the pool and gathered back is bit-exact
    against the contiguous int8 cache it came from."""
    from repro.kernels.ops import page_gather_op
    eng = make_engine("granite-3-8b", mode="native", max_lanes=2,
                      page_size=4, max_ctx=32)
    prompt = np.arange(1, 12)
    model, params = eng.model, eng.params
    nb = len(prompt) // 4 + 1
    cache, _ = model.prefill(params, jnp.asarray(prompt)[None], nb * 4)
    rid = eng.submit(prompt, 4)          # stays live after one step
    eng.step()
    req = eng.scheduler.requests[rid]
    assert req.state is RequestState.DECODE
    table = jnp.asarray(eng.table[req.lane][None, :])
    # pool pages are (L, P, page, KV, dh): gather each layer's arena
    gathered = jax.vmap(lambda pages: page_gather_op(pages, table))(
        eng.pool.k)                              # (L, 1, NB, page, KV, dh)
    ln, _, nb_all, pg = gathered.shape[:4]
    flat = gathered.reshape(ln, nb_all * pg, *gathered.shape[4:])
    s = len(prompt)
    np.testing.assert_array_equal(np.asarray(flat[:, :s]),
                                  np.asarray(cache["k"][:, 0, :s]))


def test_preemption_page_table_correctness():
    """Pool too small for three long generations: the engine preempts,
    requeues, and still completes everything with exact token counts and
    clean page accounting."""
    eng = make_engine("granite-3-8b", mode="native", max_lanes=3,
                      page_size=4, max_ctx=40, n_pages=11)
    rids = [eng.submit(np.arange(1 + i, 9 + i), 18) for i in range(3)]
    for _ in range(200):
        if (not eng.scheduler.queue
                and all(r is None for r in eng.lane_req)):
            break
        eng.step()
        # invariant: live lanes' tables list distinct non-trash pages
        live_pids = []
        for req in eng.lane_req:
            if req is None:
                continue
            nb = len(req.page_ids)
            row = eng.table[req.lane]
            assert list(row[:nb]) == req.page_ids
            assert all(p != 0 for p in req.page_ids)
            assert (row[nb:] == 0).all()
            live_pids += req.page_ids
        assert len(live_pids) == len(set(live_pids))     # no page shared
        assert len(live_pids) == eng.pool.in_use         # no leaks
    m = eng.metrics()
    assert m["completed"] == 3
    assert m["preemptions"] > 0                          # policy did fire
    assert eng.pool.in_use == 0                          # all freed
    for rid in rids:
        req = eng.scheduler.requests[rid]
        assert req.state is RequestState.DONE
        assert len(req.generated) == 18


def test_admission_wave_reserves_pool_capacity():
    """Two requests each needing 5 pages, 8 usable: one admission wave must
    not over-commit the pool (the second request waits its turn)."""
    eng = make_engine("granite-3-8b", mode="native", max_lanes=2,
                      page_size=4, max_ctx=32, n_pages=9)
    r0 = eng.submit(np.arange(1, 17), 4)
    r1 = eng.submit(np.arange(2, 18), 4)
    eng.step()
    states = {rid: eng.scheduler.requests[rid].state for rid in (r0, r1)}
    assert states[r0] is RequestState.DECODE
    assert states[r1] is RequestState.QUEUED
    out = eng.drain()
    assert len(out[r0]) == 4 and len(out[r1]) == 4


def test_engine_beats_sequential_on_step_count():
    """Staggered arrivals: continuous batching overlaps decode work, so the
    engine needs strictly fewer fused steps than sequential serving needs
    serve_step calls (the deterministic core of the throughput claim)."""
    eng = make_engine("granite-3-8b", mode="native", max_lanes=3,
                      page_size=4, max_ctx=32)
    eng.submit(np.arange(1, 9), 10)
    eng.step(); eng.step()
    eng.submit(np.arange(2, 10), 10)
    eng.step(); eng.step()
    eng.submit(np.arange(3, 11), 10)
    eng.drain()
    naive_steps = 3 * (10 - 1)
    assert eng.metrics()["completed"] == 3
    assert eng.decode_steps < naive_steps


def test_engine_watchdog_surfaces_stragglers():
    """Every fused decode step is timed; with a zero-tolerance deadline the
    post-warmup steps all flag and surface in the metrics."""
    wd = StepWatchdog(factor=0.0, warmup=1)
    eng = make_engine("granite-3-8b", mode="native", max_lanes=2,
                      page_size=4, max_ctx=32, watchdog=wd)
    eng.submit(np.arange(1, 9), 6)
    eng.drain()
    assert len(wd.times) == eng.decode_steps == 5
    assert eng.metrics()["straggler_steps"] == len(wd.flags) > 0


@pytest.mark.parametrize("arch", ["granite-3-8b", "granite-moe-1b-a400m",
                                  "zamba2-7b"])
def test_fused_decode_bitexact_vs_unfused(arch):
    """Acceptance: the fused paged-attention decode greedy-decodes EXACTLY
    the tokens of the gather-then-attend route, per model family, and the
    jaxpr-level route check agrees with the QConfig toggle."""
    outs = {}
    for fused in (True, False):
        eng = make_engine(arch, mode="native", fuse_kernels=fused,
                          max_lanes=2, page_size=4, max_ctx=32)
        assert fused_decode_active(eng) is fused
        rids = [eng.submit(p, 6) for p in PROMPTS]
        res = eng.drain()
        outs[fused] = [res[r] for r in rids]
    assert outs[True] == outs[False], arch


def test_fresh_trace_keeps_live_decode_route_unpoisoned():
    """Inspection traces under a patched kernel dispatch must run through
    jaxpr_utils.fresh_trace: a throwaway wrapper keeps the trace out of the
    live _decode_jit's cache, so after tracing the TPU route the engine
    still decodes on the CPU-compilable one."""
    from jaxpr_utils import fresh_trace
    from repro.kernels import ops
    eng = make_engine("granite-3-8b", mode="native", max_lanes=2,
                      page_size=4, max_ctx=32)
    slots = dict(eng.slots, pos=jnp.zeros((eng.max_lanes,), jnp.int32))
    orig = ops._on_tpu
    ops._on_tpu = lambda: True
    try:
        jaxpr = fresh_trace(eng._decode_step, eng.params, slots, eng.pool.k,
                            eng.pool.v, jnp.asarray(eng.table),
                            jnp.asarray(eng.h_tokens), np.int32(0))
    finally:
        ops._on_tpu = orig
    assert any(e[0] == "pallas_call"
               for e in ops.eqns_outside_pallas(jaxpr.jaxpr))
    r = eng.submit(np.arange(1, 9), 4)     # live route still compiles
    assert len(eng.drain()[r]) == 4


def test_decode_loop_single_fused_computation_per_step():
    """The decode hot loop is one jitted computation per step: a single
    trace overall (jit-stable across occupancy changes) and exactly one
    _decode_jit call per engine step; prefill-time sampling never runs
    inside the decode loop."""
    eng = make_engine("granite-3-8b", mode="native", max_lanes=2,
                      page_size=4, max_ctx=32)
    decode_calls = []
    sample_calls = []
    real_decode, real_sample = eng._decode_jit, eng._sample_jit
    eng._decode_jit = lambda *a, **k: (decode_calls.append(1)
                                       or real_decode(*a, **k))
    eng._sample_jit = lambda *a, **k: (sample_calls.append(1)
                                       or real_sample(*a, **k))
    eng.submit(np.arange(1, 9), 8)
    eng.step(); eng.step()
    eng.submit(np.arange(2, 12), 6)          # occupancy changes mid-run
    eng.drain()
    decode_steps = eng.decode_steps
    assert len(decode_calls) == decode_steps      # one call per step
    assert len(sample_calls) == 2                 # one per ADMISSION only
    assert real_decode._cache_size() == 1         # one trace overall


def test_engine_table_mirror_invalidation():
    """The device page-table mirror re-uploads only when the host table
    changes (admission, page growth, release, defrag)."""
    eng = make_engine("granite-3-8b", mode="native", max_lanes=2,
                      page_size=4, max_ctx=32)
    eng.submit(np.arange(1, 9), 8)
    eng.step()
    dev = eng._table_dev
    assert dev is not None
    eng.step()                    # no table change: same device buffer
    assert eng._table_dev is dev
    for _ in range(20):
        if not any(eng.lane_req):
            break
        eng.step()
    assert eng.pool.in_use == 0   # released => mirror invalidated
    assert eng._table_dev is None or eng._table_dev is not dev


def test_sampler_temperature_topk():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (3, 32))
    greedy = make_sampler(16)(logits, key)
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.asarray(greedy_token(logits, 16)))
    toks = make_sampler(16, temperature=0.8, top_k=4)(logits, key)
    assert toks.shape == (3,)
    top4 = jnp.argsort(logits[:, :16], axis=-1)[:, -4:]
    for b in range(3):
        assert int(toks[b]) in set(np.asarray(top4[b]).tolist())


def test_engine_sampled_mode_runs():
    eng = make_engine("granite-3-8b", mode="native", max_lanes=2,
                      page_size=4, max_ctx=32, temperature=0.7, top_k=8)
    rid = eng.submit(np.arange(1, 9), 5)
    out = eng.drain()
    assert len(out[rid]) == 5
    assert all(0 <= t < eng.model.a.vocab for t in out[rid])


def test_engine_submit_validation_and_traffic_shapes():
    eng = make_engine("granite-3-8b", mode="native", max_lanes=2,
                      page_size=4, max_ctx=16)
    with pytest.raises(ValueError):
        eng.submit(np.arange(1, 14), 8)          # exceeds max_ctx
    with pytest.raises(ValueError):
        eng.submit(np.asarray([], np.int32), 2)
    traffic = poisson_traffic(rate=10.0, n_requests=8, prompt_lens=(4, 8),
                              gen_lens=(2, 4), vocab=64, seed=3)
    assert len(traffic) == 8
    arr = [t["arrival"] for t in traffic]
    assert arr == sorted(arr) and arr[0] > 0
    assert all(len(t["prompt"]) in (4, 8) and t["max_new"] in (2, 4)
               for t in traffic)
