"""Elastic runtime chaos suite: bit-exact resume, reshard, rebalance.

The contract under test (DESIGN.md §11): an ElasticRunner run that is
killed at randomized steps — including mid-async-save — and restored from
its packed QTensor checkpoints finishes with BITWISE the same parameters
and Momentum accumulator as an uninterrupted run.  And because the sharded
step is parameterized by `n_shards` (not devices), a single clean dp=1
run is the golden reference for EVERY chaos layout: dp ∈ {1, 2, 8} ×
{replicated, zero1}, checkpoint reshards across dp, live resizes, and
watchdog-driven rebalances all land on the same bits.

All multi-device programs run in subprocesses (the virtual device count
must be set before jax initializes).  `python tests/test_elastic.py` runs
the three programs directly and prints the CI grep markers.
"""
import os
import subprocess
import sys
import textwrap

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(prog: str, timeout: int = 1500) -> str:
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=timeout, env=env, cwd=_ROOT)
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-4000:]
    return r.stdout


_PRELUDE = textwrap.dedent("""
    import tempfile
    import jax, jax.numpy as jnp, numpy as np
    from repro.checkpoint import CheckpointManager
    from repro.configs.base import ArchConfig
    from repro.core import preset
    from repro.data import TokenTask
    from repro.launch import shard as S
    from repro.models import build_model
    from repro.optim import init_momentum
    from repro.runtime import ElasticRunner, StepWatchdog

    ARCH = ArchConfig(name="t-lm", family="lm", n_layers=2, d_model=32,
                      n_heads=2, n_kv=2, d_ff=64, vocab=64, head_dim=16,
                      q_chunk=16, kv_chunk=16)
    QCFG = preset("full8", "native")
    MODEL = build_model(ARCH, QCFG)
    PARAMS0 = MODEL.init(jax.random.PRNGKey(0))
    LABELS = MODEL.labels(PARAMS0)
    TASK = TokenTask(vocab=ARCH.vocab, seq_len=16, global_batch=8)
    N_SHARDS, STEPS, SAVE_EVERY = 8, 6, 2

    def runner(dp, opt_shard, **kw):
        ckpt = CheckpointManager(tempfile.mkdtemp(prefix="elastic_"))
        r = ElasticRunner(MODEL, QCFG, LABELS, ckpt, TASK.batch, dp=dp,
                          n_shards=N_SHARDS, opt_shard=opt_shard,
                          save_every=SAVE_EVERY, **kw)
        return r, ckpt

    def elastic(dp, opt_shard, steps=STEPS, **runkw):
        r, _ = runner(dp, opt_shard)
        p, o, _ = r.run(jax.tree.map(np.asarray, PARAMS0),
                        S.zero_init_momentum(PARAMS0, dp)
                        if opt_shard == "zero1" else init_momentum(PARAMS0),
                        steps, **runkw)
        return p, o, r

    def diff(pa, pb):
        return [jax.tree_util.keystr(p) for (p, a), (_, b) in
                zip(jax.tree_util.tree_leaves_with_path(pa),
                    jax.tree_util.tree_leaves_with_path(pb))
                if not np.array_equal(np.asarray(a), np.asarray(b))]

    def acc_diff(golden_acc, opt, opt_shard):
        # ZeRO-1 accumulators are flat padded chunks: compare the logical
        # (unpadded) prefix against the golden replicated leaf
        if opt_shard != "zero1":
            return diff(golden_acc, opt.acc)
        bad = []
        for (path, g), a in zip(
                jax.tree_util.tree_leaves_with_path(golden_acc),
                jax.tree.leaves(opt.acc)):
            flat = np.asarray(a).reshape(-1)
            if not np.array_equal(np.asarray(g).reshape(-1),
                                  flat[: np.asarray(g).size]):
                bad.append(jax.tree_util.keystr(path))
            if flat[np.asarray(g).size:].any():
                bad.append(jax.tree_util.keystr(path) + "/padding")
        return bad

    # the golden reference for EVERY layout: one clean dp=1 run
    GP, GO, _ = elastic(1, "replicated")
""")


_CHAOS_PROG = _PRELUDE + textwrap.dedent("""
    # Randomized kill-and-resume over every layout.  The failure step comes
    # from a seeded rng so runs are reproducible but not hand-picked; each
    # layout also exercises a DIFFERENT phase of the save cadence.
    rng = np.random.default_rng(1909)
    for dp in (1, 2, 8):
        for opt_shard in ("replicated", "zero1"):
            fail = int(rng.integers(1, STEPS))
            p, o, r = elastic(dp, opt_shard, fail_at=fail)
            assert r.restarts == 1, (dp, opt_shard, r.restarts)
            bad = diff(GP, p) + acc_diff(GO.acc, o, opt_shard)
            assert not bad, (dp, opt_shard, fail, bad)
            print("OK chaos", dp, opt_shard, "fail_at", fail)

    # kill -9 mid-async-save: the writer of the step-4 checkpoint dies
    # AFTER staging tmp-4 but BEFORE the atomic publish, then the step-5
    # crash forces recovery from the last PUBLISHED checkpoint (step 2)
    p, o, r = elastic(2, "zero1", fail_save_at=4, fail_at=5)
    assert r.restarts == 1, r.restarts
    bad = diff(GP, p) + acc_diff(GO.acc, o, "zero1")
    assert not bad, bad
    print("OK chaos mid-save writer death")

    # crash BEFORE the first checkpoint exists -> cold restart, same bits
    p, o, r = elastic(2, "replicated", fail_at=1)
    assert r.restarts == 1 and not (diff(GP, p) + diff(GO.acc, o.acc))
    print("OK chaos cold restart")
    print("RESUME_BITEXACT_OK")
""")


_RESHARD_PROG = _PRELUDE + textwrap.dedent("""
    # Checkpoint reshard: train under dp=2 ZeRO-1, stop, resume the SAME
    # trajectory under dp=4 — the flat Momentum chunks re-chunk
    # (unpad + repad) through launch/shard.zero_reshard.
    r2, ckpt = runner(2, "zero1")
    r2.run(jax.tree.map(np.asarray, PARAMS0),
           S.zero_init_momentum(PARAMS0, 2), 4)

    r4 = ElasticRunner(MODEL, QCFG, LABELS, ckpt, TASK.batch, dp=4,
                       n_shards=N_SHARDS, opt_shard="zero1",
                       save_every=SAVE_EVERY)
    p, o, _ = r4.run(jax.tree.map(np.asarray, PARAMS0),
                     S.zero_init_momentum(PARAMS0, 4), STEPS, resume=True)
    bad = diff(GP, p) + acc_diff(GO.acc, o, "zero1")
    assert not bad, bad
    print("OK reshard dp2->dp4 checkpoint resume")

    # Live resize mid-run: dp=8 shrinks to dp=2 at step 3 without a crash
    p, o, r = elastic(8, "zero1", resize_at={3: 2})
    assert r.reshards == [(3, 8, 2)], r.reshards
    assert r.dp == 2
    bad = diff(GP, p) + acc_diff(GO.acc, o, "zero1")
    assert not bad, bad
    print("OK live resize 8->2")
    print("RESHARD_BITEXACT_OK")
""")


_REBALANCE_PROG = _PRELUDE + textwrap.dedent("""
    # Watchdog-driven rebalance: a straggler flag at step 2 shrinks dp=8 to
    # the next divisor of n_shards (4); the trajectory must not move a bit.
    class FlagAt(StepWatchdog):
        def __init__(self, at):
            super().__init__()
            self.at = at
        def observe(self, step, dt):
            super().observe(step, dt)
            return step == self.at

    ckpt = CheckpointManager(tempfile.mkdtemp(prefix="elastic_"))
    r = ElasticRunner(MODEL, QCFG, LABELS, ckpt, TASK.batch, dp=8,
                      n_shards=N_SHARDS, opt_shard="replicated",
                      save_every=SAVE_EVERY, watchdog=FlagAt(2),
                      rebalance_flags=1)
    p, o, _ = r.run(jax.tree.map(np.asarray, PARAMS0),
                    init_momentum(PARAMS0), STEPS)
    assert r.dp == 4 and len(r.reshards) == 1, (r.dp, r.reshards)
    bad = diff(GP, p) + diff(GO.acc, o.acc)
    assert not bad, bad
    print("OK rebalance 8->4")
    print("REBALANCE_BITEXACT_OK")
""")


def test_chaos_resume_bitexact():
    """Kill-and-resume at seeded-random steps (incl. mid-async-save and
    pre-first-checkpoint) across dp x opt_shard == clean dp=1, bitwise."""
    out = _run(_CHAOS_PROG)
    assert "RESUME_BITEXACT_OK" in out, out


def test_reshard_bitexact():
    """dp=2 -> dp=4 ZeRO-1 checkpoint resume and a live dp=8 -> dp=2
    resize both land on the clean-run bits."""
    out = _run(_RESHARD_PROG)
    assert "RESHARD_BITEXACT_OK" in out, out


def test_watchdog_rebalance_bitexact():
    out = _run(_REBALANCE_PROG)
    assert "REBALANCE_BITEXACT_OK" in out, out


def test_next_divisor_down():
    from repro.runtime import next_divisor_down
    assert next_divisor_down(8, 8) == 4
    assert next_divisor_down(8, 4) == 2
    assert next_divisor_down(12, 4) == 3
    assert next_divisor_down(7, 7) == 1
    assert next_divisor_down(8, 1) == 1


def test_granularity_mismatch_refused(tmp_path):
    """A checkpoint written under one n_shards must refuse to resume under
    another — that would silently change the quantization math."""
    import jax
    import numpy as np
    import pytest

    from repro.checkpoint import CheckpointManager
    from repro.configs.base import ArchConfig
    from repro.core import preset
    from repro.data import TokenTask
    from repro.models import build_model
    from repro.runtime import ElasticRunner
    from repro.runtime.elastic import _sds

    arch = ArchConfig(name="t-lm", family="lm", n_layers=1, d_model=32,
                      n_heads=2, n_kv=2, d_ff=64, vocab=64, head_dim=16,
                      q_chunk=16, kv_chunk=16)
    qcfg = preset("full8", "native")
    model = build_model(arch, qcfg)
    params = model.init(jax.random.PRNGKey(0))
    ckpt = CheckpointManager(str(tmp_path), async_write=False)
    ckpt.save(2, {"x": np.zeros(3)},
              aux={"dp": 1, "tp": 1, "n_shards": 4,
                   "opt_shard": "replicated"})
    r = ElasticRunner(model, qcfg, model.labels(params), ckpt,
                      lambda s: None, dp=1, n_shards=8)
    r._ptmpl = _sds(params)
    with pytest.raises(ValueError, match="n_shards"):
        r.restore()
    ckpt.save(3, {"x": np.zeros(3)},
              aux={"dp": 1, "tp": 1, "n_shards": 8, "opt_shard": "zero1"})
    with pytest.raises(ValueError, match="opt_shard"):
        r.restore()


if __name__ == "__main__":
    # CI entry: run the chaos programs under 8 virtual devices and print
    # the markers the workflow greps for.
    for prog in (_CHAOS_PROG, _RESHARD_PROG, _REBALANCE_PROG):
        sys.stdout.write(_run(prog))
