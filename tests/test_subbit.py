"""Sub-8 bit-width lanes (DESIGN.md §14): preset spec points, the staged
integer wire, fused-kernel bit-exactness at k < 8, the backend-aware wire
codec default, and the real-data npz input pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import preset
from repro.core.qconfig import PRESETS


# --------------------------------------------------------------------------
# lane presets: spec points through the width<->spec reconciliation
# --------------------------------------------------------------------------


def test_lane_presets_resolve():
    w4a8 = preset("w4a8")
    assert w4a8.k_w == 4 and w4a8.w.kind == "clip" and w4a8.w.k == 4
    assert w4a8.k_a == 8 and w4a8.a.k == 8
    a4 = preset("a4")
    assert a4.k_a == 4 and a4.a.kind == "scaled" and a4.a.k == 4
    assert a4.k_w == 8
    g16 = preset("g16")
    assert g16.k_gw == 16 and g16.k_w == 8
    for name in PRESETS:          # every preset passes Eq. 22/24 closure
        preset(name).validate()


# --------------------------------------------------------------------------
# wire_plan: classic clip vs staged int16 widening
# --------------------------------------------------------------------------


def test_wire_plan_units():
    from repro.runtime.compress import wire_plan

    # classic: the payload clip absorbs the whole shift, hops ride the
    # payload width itself
    assert wire_plan(16, 4) == (4, 16)
    assert wire_plan(8, 6) == (6, 8)
    assert wire_plan(32, 10) == (10, 32)
    assert wire_plan(4, 2) == (2, 4)
    # staged: narrow payloads keep (nearly) full resolution, sums widen
    # onto int16 hops
    assert wire_plan(4, 3) == (0, 16)
    assert wire_plan(4, 12) == (0, 16)
    assert wire_plan(8, 7) == (0, 16)
    assert wire_plan(4, 13) == (1, 16)    # int16 can't absorb it all
    assert wire_plan(4, 14) == (2, 16)
    # refuse only when int16 hops can't carry the fan-in either
    with pytest.raises(ValueError):
        wire_plan(4, 15)
    with pytest.raises(ValueError):
        wire_plan(16, 15)


def test_staged_wire_exact_sum():
    """bits=4 at an 8-way fan-in (the case the classic bound rejects):
    payloads keep full 4-bit resolution (|n| <= 7) in int8 storage, every
    partial sum fits int16, and the fused pre-sum equals the materialized
    payload sum bit for bit."""
    from repro.runtime import wire_quantize
    from repro.runtime.compress import wire_presum

    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(8, 33)) * 1e-3, jnp.float32)
    amax = jnp.max(jnp.abs(g))
    qt = wire_quantize(g, amax, 4, 3)
    data = np.asarray(qt.data)
    assert data.dtype == np.int8
    assert np.abs(data).max() <= 7              # full 4-bit resolution
    assert np.abs(data.astype(np.int64).sum(0)).max() < 2 ** 15
    ps, scale = wire_presum(g, amax, 4, 3)
    np.testing.assert_array_equal(np.asarray(ps),
                                  data.astype(np.int64).sum(0))
    assert float(scale) == float(qt.scale)


def test_default_wire_codec_backend_aware():
    from repro.runtime.compress import default_wire_codec

    codec, why = default_wire_codec("tpu")
    assert codec == "packed" and "tpu" in why
    codec, why = default_wire_codec("cpu")
    assert codec == "leaf" and "cpu" in why
    codec, _ = default_wire_codec()             # current backend resolves
    assert codec in ("packed", "leaf")


def test_banner_and_report_surface_codec():
    from repro.kernels.ops import dispatch_banner, dispatch_report
    from repro.launch.report import kernel_table

    rep = dispatch_report()
    assert rep["wire_codec"]["default"] in ("packed", "leaf")
    assert rep["wire_codec"]["why"]
    assert "wire_codec=" in dispatch_banner()
    assert "wire codec default:" in kernel_table()


# --------------------------------------------------------------------------
# fused-kernel bit-exactness at k < 8 / k > 8
# --------------------------------------------------------------------------

_RN = ArchConfig(name="t-rn-lane", family="resnet", block="basic",
                 stage_sizes=(1,), num_classes=8, img_size=16)


@pytest.mark.parametrize("pname", ["w4a8", "a4", "g16"])
def test_lane_fused_matches_unfused_train_step(pname):
    """Two native train steps, fused vs unfused kernels: bitwise on every
    param leaf and the Momentum accumulator (resnet — the whole tree rides
    the quantized path)."""
    from repro.data import ImageTask
    from repro.launch.train import make_train_step
    from repro.models import build_model
    from repro.optim import init_momentum

    outs = []
    for fused in (True, False):
        qcfg = preset(pname, "native").replace(fuse_kernels=fused)
        model = build_model(_RN, qcfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = init_momentum(params)
        step = jax.jit(make_train_step(model, qcfg, model.labels(params)))
        task = ImageTask(img_size=16, num_classes=8, global_batch=8)
        for s in range(2):
            b = jax.tree.map(jnp.asarray, task.batch(s))
            params, opt, _ = step(params, opt, b, jnp.int32(s))
        outs.append((jax.device_get(params), jax.device_get(opt.acc)))
    for tree_f, tree_u in zip(outs[0], outs[1]):
        for (path, a), (_, b) in zip(
                jax.tree_util.tree_leaves_with_path(tree_f),
                jax.tree_util.tree_leaves_with_path(tree_u)):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"{pname}: {jax.tree_util.keystr(path)}")


# --------------------------------------------------------------------------
# real-data npz pipeline
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def demo_dir(tmp_path_factory):
    from repro.data import write_demo_dataset

    d = str(tmp_path_factory.mktemp("npz_demo"))
    info = write_demo_dataset(d, n=512, img_size=8, num_classes=4, seed=3)
    assert info["n_train"] == 448 and info["n_val"] == 64
    return d


def test_npz_task_grid_and_shapes(demo_dir):
    from repro.data import NpzImageTask

    t = NpzImageTask(demo_dir, global_batch=16, seed=5)
    assert t.img_size == 8 and t.num_classes == 4 and t.n_train == 448
    b = t.batch(0)
    assert b["images"].shape == (16, 8, 8, 3)
    assert b["labels"].dtype == np.int32
    # pixels land EXACTLY on the signed 2^(1-8) grid in [-1, 1)
    n = b["images"] * 128.0
    np.testing.assert_array_equal(n, np.round(n))
    assert n.min() >= -128 and n.max() <= 127


def test_npz_task_shard_composition(demo_dir):
    from repro.data import NpzImageTask

    t = NpzImageTask(demo_dir, global_batch=16, seed=5)
    full = t.batch(7)
    parts = [t.batch(7, i, 4) for i in range(4)]
    np.testing.assert_array_equal(
        full["images"], np.concatenate([p["images"] for p in parts]))
    np.testing.assert_array_equal(
        full["labels"], np.concatenate([p["labels"] for p in parts]))
    again = t.batch(7)                          # determinism
    np.testing.assert_array_equal(full["images"], again["images"])


def test_npz_task_epoch_permutation(demo_dir):
    from repro.data import NpzImageTask

    t = NpzImageTask(demo_dir, global_batch=16, seed=5, augment=False)
    steps = t.n_train // 16
    flat = np.concatenate([t.batch(s)["images"] for s in range(steps)]
                          ).reshape(t.n_train, -1)
    assert len(np.unique(flat, axis=0)) == t.n_train  # each sample once
    # epoch 2: same sample set, different seed-fixed order
    flat2 = np.concatenate([t.batch(steps + s)["images"]
                            for s in range(steps)]).reshape(t.n_train, -1)
    assert not np.array_equal(flat, flat2)
    np.testing.assert_array_equal(flat[np.lexsort(flat.T)],
                                  flat2[np.lexsort(flat2.T)])


def test_npz_holdout_deterministic(demo_dir):
    from repro.data import NpzImageTask

    t = NpzImageTask(demo_dir, global_batch=16, seed=5)
    a, b = t.holdout_batch(0), t.holdout_batch(0)
    np.testing.assert_array_equal(a["images"], b["images"])
    assert not np.array_equal(a["images"], t.holdout_batch(1)["images"])


def test_npz_chw_layout(tmp_path):
    """The downsampled-ImageNet/CIFAR batch layout: row-major CHW uint8
    rows + 1-based labels load as NHWC with 0-based labels."""
    from repro.data.imagenet import _load_npz

    rng = np.random.default_rng(0)
    imgs = rng.integers(0, 256, (10, 3, 6, 6), dtype=np.uint8)
    labels = rng.integers(1, 5, 10)
    p = str(tmp_path / "train_000.npz")
    np.savez(p, data=imgs.reshape(10, -1), labels=labels)
    out, lab = _load_npz(p)
    np.testing.assert_array_equal(out, imgs.transpose(0, 2, 3, 1))
    np.testing.assert_array_equal(lab, labels - 1)
    assert lab.dtype == np.int32


def test_npz_missing_dir_raises(tmp_path):
    from repro.data import NpzImageTask

    with pytest.raises(FileNotFoundError):
        NpzImageTask(str(tmp_path / "nope"), global_batch=8)


def test_resolve_image_task(demo_dir, monkeypatch):
    from repro.data import NpzImageTask, resolve_image_task
    from repro.data.synthetic import ImageTask

    monkeypatch.delenv("REPRO_DATA_DIR", raising=False)
    t, tag = resolve_image_task(8)
    assert isinstance(t, ImageTask) and tag == "synthetic"
    t, tag = resolve_image_task(8, data_dir=demo_dir)
    assert isinstance(t, NpzImageTask) and tag.startswith("real:")
    monkeypatch.setenv("REPRO_DATA_DIR", demo_dir)
    t, tag = resolve_image_task(8)
    assert isinstance(t, NpzImageTask)
    t, tag = resolve_image_task(8, synthetic=True)  # forced fallback
    assert isinstance(t, ImageTask) and tag == "synthetic"
