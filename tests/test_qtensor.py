"""QTensor pytree + quantizer registry: round-trip exactness vs the legacy
qfuncs free functions, multi-plane recomposition, pytree transparency under
jit/grad/scan, registry/alias dispatch, and the zero-redundant-decomposition
guarantee of native qeinsum on pre-quantized operands."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (QTensor, QuantSpec, get_quantizer, preset, qact,
                        qdense, qeinsum, qweight, quantize_ste,
                        registered_quantizers, resolve_quantizer)
from repro.core import qfuncs as qf
from repro.core.qtensor import legacy_kind, spec_from_alias


@pytest.fixture(scope="module")
def x():
    return jax.random.normal(jax.random.PRNGKey(0), (7, 33)) * 0.7


# --------------------------------------------------------------------------
# round-trips: dequantize(quantize(x)) == legacy function output, bit-exact
# --------------------------------------------------------------------------


@pytest.mark.parametrize("kind,k,legacy", [
    ("clip", 8, lambda x: qf.q_clip(x, 8)),
    ("clip", 4, lambda x: qf.q_clip(x, 4)),
    ("scaled", 8, lambda x: qf.q_scaled(x, 8)),
    ("sq", 8, lambda x: qf.sq(x, 8)),
    ("sq", 16, lambda x: qf.sq(x, 16)),
    ("flag", 8, lambda x: qf.flag_qe2(x, 8)),
    ("flag", 16, lambda x: qf.flag_qe2(x, 16)),
])
def test_quantizer_roundtrip_bitexact(x, kind, k, legacy):
    q = get_quantizer(kind, k)
    qt = q.quantize(x)
    np.testing.assert_array_equal(np.asarray(q.dequantize(qt)),
                                  np.asarray(legacy(x)))
    # __call__ IS the legacy function
    np.testing.assert_array_equal(np.asarray(q(x)), np.asarray(legacy(x)))


def test_direct_roundtrip_in_range():
    """Direct quantization payload round-trip is exact on the representable
    range |x| <= 1 - 2^(1-k) (q_direct itself never clips)."""
    for k in (4, 8, 16):
        lim = (2.0 ** (k - 1) - 1.0) / 2.0 ** (k - 1)
        x = jnp.linspace(-lim, lim, 257)
        q = get_quantizer("direct", k)
        np.testing.assert_array_equal(
            np.asarray(q.dequantize(q.quantize(x))),
            np.asarray(qf.q_direct(x, k)))


def test_cq_roundtrip_bitexact(x):
    q = get_quantizer("cq", 15, (("dr_bits", 8), ("stochastic", True)))
    key = jax.random.PRNGKey(3)
    got = q.dequantize(q.quantize(x, key=key))
    want = qf.cq(x, key, 8, 15, stochastic=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    qd = get_quantizer("cq", 15, (("dr_bits", 6), ("stochastic", False)))
    np.testing.assert_array_equal(
        np.asarray(qd.dequantize(qd.quantize(x))),
        np.asarray(qf.cq(x, None, 6, 15, stochastic=False)))


def test_flag8_planes_disjoint_and_recompose(x):
    """Σ planes == flag_qe2(x) bit-exactly, planes have disjoint support,
    both payloads are true int8."""
    q = get_quantizer("flag", 8)
    qt = q.quantize(x * 3.0)
    (hi, s_hi), (lo, s_lo) = qt.planes()
    assert hi.dtype == jnp.int8 and lo.dtype == jnp.int8
    assert not np.any((np.asarray(hi) != 0) & (np.asarray(lo) != 0))
    recomposed = hi.astype(jnp.float32) * s_hi + lo.astype(jnp.float32) * s_lo
    np.testing.assert_array_equal(np.asarray(recomposed),
                                  np.asarray(qf.flag_qe2(x * 3.0, 8)))


def test_flag8_boundary_values_exact():
    """Payloads just below the regime boundary (|n| in [127.5/128, 1)) must
    recompose to the same value the scalar flag_qe2 formula produces."""
    # amax 1.0 -> sc = 2^-7; values near (but below) sc*... boundary
    x = jnp.asarray([1.0, 2.0 ** -7 * 0.999, -2.0 ** -7 * 0.997,
                     2.0 ** -7 * 127.7 / 128.0, 0.0], jnp.float32)
    q = get_quantizer("flag", 8)
    np.testing.assert_array_equal(
        np.asarray(q.dequantize(q.quantize(x))),
        np.asarray(qf.flag_qe2(x, 8)))


def test_grid_lossless_on_grid_tensors(x):
    for k, fn in ((8, lambda t: qf.q_scaled(t, 8)), (16, lambda t: qf.sq(t, 16))):
        xg = fn(x)
        q = get_quantizer("grid", k)
        np.testing.assert_array_equal(np.asarray(q.dequantize(q.quantize(xg))),
                                      np.asarray(xg))


# --------------------------------------------------------------------------
# registry + aliases
# --------------------------------------------------------------------------


def test_registry_contains_core_kinds():
    names = registered_quantizers()
    for n in ("clip", "scaled", "sq", "flag", "cq", "direct", "grid", "none"):
        assert n in names


def test_legacy_aliases_resolve(x):
    np.testing.assert_array_equal(
        np.asarray(resolve_quantizer("flag8")(x)),
        np.asarray(qf.flag_qe2(x, 8)))
    np.testing.assert_array_equal(
        np.asarray(resolve_quantizer("sq16")(x)), np.asarray(qf.sq(x, 16)))
    # bare "sq" takes the default k from its context
    np.testing.assert_array_equal(
        np.asarray(resolve_quantizer("sq", 12)(x)), np.asarray(qf.sq(x, 12)))
    assert spec_from_alias("sq16").k == 16
    assert spec_from_alias("dec_int8_fixed").kind == "clip"
    assert legacy_kind(QuantSpec("flag", 8)) == "flag8"
    with pytest.raises(ValueError):
        resolve_quantizer("no_such_quantizer")


def test_legacy_shims_delegate_to_registry(x):
    """quant_error/dec_error are registry-backed; outputs stay bit-exact."""
    np.testing.assert_array_equal(np.asarray(qf.quant_error(x, "flag8", 8)),
                                  np.asarray(qf.flag_qe2(x, 8)))
    planes = qf.dec_error(x, "flag8", 8)
    assert len(planes) == 2 and planes[0][0].dtype == jnp.int8
    d8, s8 = qf.dec_int8(qf.q_scaled(x, 8), 8)
    np.testing.assert_array_equal(
        np.asarray(d8.astype(jnp.float32) * s8), np.asarray(qf.q_scaled(x, 8)))
    df, sf = qf.dec_int8_fixed(qf.q_clip(x, 8), 8)
    assert float(sf) == 2.0 ** -7


def test_qconfig_string_alias_equivalence():
    """Deprecated string fields and structured specs build identical cfgs."""
    a = preset("full8").replace(e2_kind="sq16")
    b = preset("full8").replace(e2=QuantSpec("sq", 16))
    assert a.e2 == b.e2 == QuantSpec("sq", 16)
    assert a.e2_kind == b.e2_kind == "sq16"
    assert a.k_e2 == b.k_e2 == 16
    cfg = preset("e2_16")
    assert cfg.e2 == QuantSpec("sq", 16) and cfg.e2_kind == "sq16"
    assert preset("full8").e_attn == QuantSpec("sq", 8)
    assert preset("full8").e_attn_kind == "sq8"


def test_qconfig_spec_survives_replace_roundtrip():
    """Specs with non-alias widths or custom params must survive replace()
    (the deprecated canonical strings carried through must not win)."""
    from repro.core import QConfig
    c = QConfig(e_attn=QuantSpec("sq", 12)).replace(mode="native")
    assert c.e_attn == QuantSpec("sq", 12)
    c2 = preset("full8").replace(e2=QuantSpec("sq", 16)).replace(mode="native")
    assert c2.e2 == QuantSpec("sq", 16) and c2.k_e2 == 16


def test_qconfig_spec_width_wins_over_legacy_field():
    """Structured specs are authoritative for k; legacy width fields sync
    from them (and still work as constructor/replace conveniences)."""
    c = preset("full8").replace(a=QuantSpec("scaled", 4))
    assert c.a == QuantSpec("scaled", 4) and c.k_a == 4
    c2 = preset("full8").replace(k_a=4).replace(mode="native")
    assert c2.a == QuantSpec("scaled", 4) and c2.k_a == 4
    from repro.core import QConfig
    c3 = QConfig(k_w=6)
    assert c3.w == QuantSpec("clip", 6)


def test_momentum_pluggable_gradient_quantizer(x):
    """cfg.g resolves through the registry for ANY registered kind; the dr
    schedule/stochastic knobs are injected only where the quantizer has
    those fields."""
    from repro.optim.momentum import _grad_quantizer
    q = _grad_quantizer(preset("full8").replace(g=QuantSpec("direct", 15)), 8)
    np.testing.assert_array_equal(np.asarray(q(x * 0.25)),
                                  np.asarray(qf.q_direct(x * 0.25, 15)))
    qc = _grad_quantizer(preset("full8"), 6)
    assert qc.dr_bits == 6 and qc.stochastic
    # explicit spec params are authoritative over the legacy knobs/schedule
    pinned = preset("full8").replace(
        g=QuantSpec("cq", 15, (("stochastic", False), ("dr_bits", 4))))
    qp = _grad_quantizer(pinned, 8)
    assert not qp.stochastic and qp.dr_bits == 4


def test_qconfig_alias_pins_width_over_stale_field():
    """A width-suffixed alias is authoritative even when a wider legacy
    width field is carried along: 'flag8' must stay flag@8."""
    from repro.core import QConfig
    c = preset("e2_16").replace(e2_kind="flag8")
    assert c.e2 == QuantSpec("flag", 8) and c.k_e2 == 8
    c2 = QConfig(e2_kind="flag8", k_e2=16)
    assert c2.e2 == QuantSpec("flag", 8) and c2.k_e2 == 8


def test_qconfig_width_only_construction_survives_replace():
    """QConfig(k_e2=16) re-widths the default spec AND keeps a canonical
    alias consistent with the FINAL spec, so later replace() round-trips."""
    from repro.core import QConfig
    c = QConfig(k_e2=16)
    assert c.e2 == QuantSpec("flag", 16)
    c2 = c.replace(mode="native")
    assert c2.e2 == QuantSpec("flag", 16) and c2.k_e2 == 16


def test_requantize_saturates_to_target_width(x):
    """Writing a 16-bit payload into the int8 KV cache saturates instead of
    wrapping on the dtype cast."""
    from repro.models.layers import kv_quantize
    qt = get_quantizer("sq", 16).quantize(x * 10.0)   # int16 payload
    out = kv_quantize(qt, jnp.float32(2.0 ** -7))
    assert out.dtype == jnp.int8
    assert int(jnp.max(out)) <= 127 and int(jnp.min(out)) >= -127
    # and it agrees with the legacy array path on the same values
    legacy = kv_quantize(qt.dequantize(), jnp.float32(2.0 ** -7))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(legacy))


def test_explicit_none_error_kind_stays_16bit(x):
    """'none' means NO error quantization — whether passed as the qeinsum
    e_kind, configured as the e2 spec, or via the quant_e2 switch — and the
    native backward falls back to the lossless 16-bit grid decomposition,
    never a k_e2-wide one."""
    from repro.core.qdense import _error_quantizer
    cfg = preset("full8", "native")
    for q in (_error_quantizer(cfg, "none"),
              _error_quantizer(cfg.replace(e2_kind="none"), "default"),
              _error_quantizer(cfg.replace(quant_e2=False), "default")):
        assert q.quantize(x).data.dtype == jnp.int16
        np.testing.assert_array_equal(np.asarray(q(x)), np.asarray(x))


def test_register_override_takes_effect_immediately(x):
    """Re-registering a name invalidates cached instances, so plugins can
    override builtin kinds even after presets warmed the cache."""
    import dataclasses
    from repro.core.qtensor import ShiftQuantizer, _REGISTRY
    from repro.core import register_quantizer
    get_quantizer("sq", 8)(x)                  # warm the cache

    @dataclasses.dataclass(frozen=True)
    class NegSQ(ShiftQuantizer):
        name = "sq"

        def __call__(self, t, *, key=None):
            return -qf.sq(t, self.k)

    orig = _REGISTRY["sq"]
    register_quantizer("sq", NegSQ)
    try:
        np.testing.assert_array_equal(np.asarray(get_quantizer("sq", 8)(x)),
                                      np.asarray(-qf.sq(x, 8)))
    finally:
        register_quantizer("sq", orig)


def test_custom_quantizer_registration(x):
    """Third-party quantizers plug in without touching core dispatch."""
    import dataclasses
    from repro.core.qtensor import ShiftQuantizer, register_quantizer, \
        _REGISTRY

    @dataclasses.dataclass(frozen=True)
    class DoubleShift(ShiftQuantizer):
        name = "sq_double"

        def __call__(self, t, *, key=None):
            return qf.sq(t, self.k) * 1.0  # same math, distinct identity

    register_quantizer("sq_double", DoubleShift)
    try:
        assert "sq_double" in registered_quantizers()
        q = get_quantizer("sq_double", 8)
        np.testing.assert_array_equal(np.asarray(q(x)),
                                      np.asarray(qf.sq(x, 8)))
    finally:
        del _REGISTRY["sq_double"]
        get_quantizer.cache_clear()


# --------------------------------------------------------------------------
# pytree transparency: jit / grad / scan
# --------------------------------------------------------------------------


def test_qtensor_survives_jit(x):
    q = get_quantizer("scaled", 8)

    @jax.jit
    def f(t):
        qt = q.quantize(t)
        return qt, qt.dequantize()

    qt, y = f(x)
    assert isinstance(qt, QTensor) and qt.data.dtype == jnp.int8 and qt.k == 8
    np.testing.assert_array_equal(np.asarray(y), np.asarray(qf.q_scaled(x, 8)))


def test_qtensor_survives_grad(x):
    """quantize_ste: QTensor-valued output, straight-through gradient."""
    q = get_quantizer("clip", 8)

    def f(t):
        qt = quantize_ste(q, t)
        return jnp.sum(qt.to_array() ** 2)

    g = jax.grad(f)(x)
    np.testing.assert_allclose(np.asarray(g),
                               np.asarray(2.0 * qf.q_clip(x, 8)), rtol=1e-6)


def test_qtensor_survives_scan(x):
    q = get_quantizer("clip", 8)
    qt = q.quantize(x)

    def body(c, _):
        return c + 1, qt

    n, stacked = jax.lax.scan(body, 0, None, length=3)
    assert isinstance(stacked, QTensor)
    assert stacked.data.shape == (3,) + x.shape and stacked.k == 8
    np.testing.assert_array_equal(
        np.asarray(stacked.data[0].astype(jnp.float32) * stacked.scale[0]),
        np.asarray(qt.dequantize()))


def test_qtensor_array_surface(x):
    qt = get_quantizer("scaled", 8).quantize(x)
    assert qt.shape == x.shape and qt.ndim == x.ndim
    assert qt.reshape(-1).shape == (x.size,)
    assert qt.transpose(1, 0).shape == x.shape[::-1]
    assert qt[0].shape == x.shape[1:]
    # arithmetic degrades to the fp32 value
    np.testing.assert_allclose(np.asarray(qt * 2.0),
                               np.asarray(qt.dequantize() * 2.0))
    np.testing.assert_allclose(np.asarray(jnp.ones_like(x) + qt),
                               np.asarray(1.0 + qt.dequantize()))


# --------------------------------------------------------------------------
# acceptance: zero redundant decompositions on pre-quantized operands
# --------------------------------------------------------------------------


def _count_amax_ops(jaxpr) -> int:
    return str(jaxpr).count("reduce_max")


def test_native_qeinsum_no_amax_on_qtensor_operands():
    """Forward native qeinsum with QTensor W and A operands must contain NO
    amax pass (reduce_max) anywhere in its jaxpr — payloads are consumed
    as-is.  The seed implementation re-derived both payloads per call."""
    cfg = preset("full8", "native")
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16)) * 0.4
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 8)) * 0.1
    xq = cfg.a.make().quantize(x)
    wq = cfg.w.make().quantize(w)
    jaxpr = jax.make_jaxpr(
        lambda a, b: qeinsum(cfg, "mk,kn->mn", "default", True, a, b))(xq, wq)
    assert _count_amax_ops(jaxpr) == 0, jaxpr


def test_native_fwd_bwd_single_amax_total():
    """Full forward+backward of qdense on a pre-quantized activation: the
    ONLY amax is the error quantizer's (on the fresh cotangent).  Weights
    quantize through the fixed-scale clip quantizer (amax-free)."""
    cfg = preset("full8", "native")
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16)) * 0.4
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 8)) * 0.1
    xq = cfg.a.make().quantize(x)

    def f(data, scale, w):
        qa = QTensor(data, scale, 8)
        return jnp.sum(qeinsum(cfg, "mk,kn->mn", "default", True, qa,
                               qweight(cfg, w)))

    jaxpr = jax.make_jaxpr(jax.grad(f, argnums=2))(xq.data, xq.scale, w)
    assert _count_amax_ops(jaxpr) == 1, jaxpr


def test_native_qact_into_qdense_decomposes_once():
    """qact -> qdense: exactly one activation amax (inside qact's quantizer)
    and zero weight amaxes for the whole forward."""
    cfg = preset("full8", "native")
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16)) * 0.4
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 8)) * 0.1
    jaxpr = jax.make_jaxpr(
        lambda x, w: qdense(cfg, qact(cfg, "relu", x), w))(x, w)
    assert _count_amax_ops(jaxpr) == 1, jaxpr


def test_native_qtensor_operand_matches_array_operand():
    """Consuming a pre-quantized QTensor gives the SAME numbers as the
    legacy re-decomposition of its grid carrier."""
    cfg = preset("full8", "native")
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16)) * 0.4
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 8)) * 0.1
    xq = cfg.a.make().quantize(x)
    y_qt = qeinsum(cfg, "mk,kn->mn", "default", True, xq, qweight(cfg, w))
    y_arr = qeinsum(cfg, "mk,kn->mn", "default", True, xq.dequantize(),
                    qweight(cfg, w))
    np.testing.assert_array_equal(np.asarray(y_qt), np.asarray(y_arr))


# --------------------------------------------------------------------------
# quantizer algebra: property-based invariants
# --------------------------------------------------------------------------
#
# Each invariant is a plain checker; a deterministic seeded sweep (plus the
# known adversarial corners) ALWAYS runs, and when the optional `hypothesis`
# extra is installed the same checkers also run under generated inputs.
# Scope of the idempotence law: quantizers with a FIXED grid (direct, clip)
# are projections — Q(Q(x)) == Q(x) unconditionally.  amax-scaled kinds
# (scaled/sq/grid/flag) re-derive their pow2 scale from the output, and at
# the saturate-at-pow2-amax corner the re-derived scale can shrink a notch
# and clip the top value (the same corner DESIGN.md §8 documents for the
# flash kernel's in-register decompositions) — for those the law holds
# exactly whenever the re-derived scale is unchanged, which the checkers
# condition on.

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # optional dev extra; sweeps below still run
    HAVE_HYPOTHESIS = False

_FIXED_GRID = [("direct", 8), ("direct", 4), ("clip", 8), ("clip", 6)]
_AMAX_SCALED = [("scaled", 8), ("sq", 8), ("sq", 16), ("grid", 8),
                ("flag", 8)]


def _scale_of(qt):
    return float(qt.scale) if qt.lo is None else (float(qt.scale),
                                                  float(qt.lo_scale))


def check_idempotent_fixed(kind, k, x):
    q = get_quantizer(kind, k)
    y = q(x)
    np.testing.assert_array_equal(np.asarray(q(y)), np.asarray(y))


def check_idempotent_scaled(kind, k, x):
    """Q(Q(x)) == Q(x) whenever the re-derived pow2 scale is unchanged."""
    q = get_quantizer(kind, k)
    y = q.dequantize(q.quantize(x))
    if _scale_of(q.quantize(y)) != _scale_of(q.quantize(x)):
        return False               # saturate-at-pow2-amax corner: excluded
    np.testing.assert_array_equal(np.asarray(q(y)), np.asarray(y))
    return True


def check_pow2_closure(kind, k, x):
    """Every scale a quantizer emits is an exact power of two."""
    key = jax.random.PRNGKey(5) if kind == "cq" else None
    qt = get_quantizer(kind, k).quantize(x, key=key)
    for s in ([qt.scale] if qt.lo is None else [qt.scale, qt.lo_scale]):
        m, _ = np.frexp(np.float32(s))
        assert m == 0.5, (kind, k, float(s))


def check_wire_overflow(n, bits, x):
    """n-way partial sums of wire payloads never exceed the HOP width.

    wire_quantize clips payloads to wire_limit(bits, clip_shift) where
    (clip_shift, hop_bits) = wire_plan(bits, ceil(log2 n)): on the classic
    path the clip absorbs the whole shift and hops ride the payload width;
    past the classic bound (e.g. 4-bit wires at n >= 8) the payload keeps
    (nearly) full k-bit resolution and the sums ride int16 hops instead —
    either way ANY subset sum of n contributions fits the signed
    `hop_bits`-wide dtype the ring casts to (runtime/compress.py).  Only
    fan-ins even int16 cannot carry (shift > 14) refuse loudly.
    """
    from repro.runtime import wire_limit, wire_quantize, wire_shift
    from repro.runtime.compress import wire_plan
    shift = wire_shift(n)
    if shift > bits - 2:
        with pytest.raises(ValueError):       # classic bound still refuses
            wire_limit(bits, shift)
    try:
        clip_shift, hop_bits = wire_plan(bits, shift)
    except ValueError:
        assert shift > 14                     # > 16384-way: no staging left
        return
    chunks = jnp.stack([x * (i + 1) / n for i in range(n)])
    qt = wire_quantize(chunks, jnp.max(jnp.abs(chunks)), bits, shift)
    lim = wire_limit(bits, clip_shift)
    assert n * lim < 2.0 ** (hop_bits - 1)      # static bound
    peak = np.abs(np.asarray(qt.data, np.int64)).max() if x.size else 0
    assert peak <= lim
    total = np.abs(np.asarray(qt.data, np.int64).sum(0)).max() \
        if x.size else 0
    assert total < 2.0 ** (hop_bits - 1)
    assert np.asarray(qt.data).dtype == (np.int8 if bits <= 8 else np.int16
                                         if bits <= 16 else np.int32)


def _sweep_arrays():
    corners = [
        jnp.asarray([0.2500001, -0.125], jnp.float32),   # pow2-amax corner
        jnp.asarray([1.0, 0.5, 2.0 ** -7], jnp.float32),
        jnp.asarray([0.0, 0.0], jnp.float32),
        jnp.asarray([2.0000001], jnp.float32),
    ]
    rng = np.random.default_rng(11)
    rand = [jnp.asarray(rng.normal(size=17) * 10.0 ** rng.uniform(-3, 1),
                        jnp.float32) for _ in range(12)]
    return corners + rand


def test_fixed_grid_quantizers_idempotent_sweep():
    for kind, k in _FIXED_GRID:
        for x in _sweep_arrays():
            check_idempotent_fixed(kind, k, x)


def test_amax_scaled_quantizers_idempotent_sweep():
    hits = 0
    for kind, k in _AMAX_SCALED:
        for x in _sweep_arrays():
            hits += bool(check_idempotent_scaled(kind, k, x))
    assert hits > len(_AMAX_SCALED)     # the law must actually be exercised


def test_pow2_scale_closure_sweep():
    for kind, k in _FIXED_GRID + _AMAX_SCALED + [("none", 16), ("cq", 15)]:
        for x in _sweep_arrays():
            check_pow2_closure(kind, k, x)


def test_wire_overflow_bound_sweep():
    for n in (1, 2, 3, 8, 17, 64, 256, 40000):
        for bits in (4, 8, 16, 32):
            for x in _sweep_arrays()[:6]:
                check_wire_overflow(n, bits, x)


# Sub-8 lanes (DESIGN.md §14): every registered kind upholds its algebra at
# k in {2, 4} — pow2 scale closure, projection/idempotence by family, and
# int8 storage whose payloads respect the k-bit clip.

_SUB8_KS = (2, 4)


def check_payload_k_clip(kind, k, x):
    """Sub-8 payloads live in int8 storage clipped to the k-bit range."""
    q = get_quantizer(kind, k)
    qt = q.quantize(x)
    for plane, _ in qt.planes() if qt.lo is not None else [(qt.data, None)]:
        assert np.asarray(plane).dtype == np.int8, (kind, k)
        assert np.abs(np.asarray(plane, np.int64)).max(initial=0) \
            <= 2 ** (k - 1) - 1, (kind, k)


def test_registered_kinds_cover_sub8_sweep():
    """The families swept below must cover the whole registry — a newly
    registered kind fails here until it joins a sub-8 sweep."""
    swept = {"direct", "clip", "scaled", "sq", "grid", "flag", "cq", "none"}
    assert set(registered_quantizers()) <= swept


def test_fixed_grid_kinds_sub8():
    for kind in ("direct", "clip"):
        for k in _SUB8_KS:
            for x in _sweep_arrays():
                check_idempotent_fixed(kind, k, x)
                check_pow2_closure(kind, k, x)
                check_payload_k_clip(kind, k, x)


def test_amax_scaled_kinds_sub8():
    hits = 0
    for kind in ("scaled", "sq", "grid", "flag"):
        for k in _SUB8_KS:
            for x in _sweep_arrays():
                hits += bool(check_idempotent_scaled(kind, k, x))
                check_pow2_closure(kind, k, x)
                check_payload_k_clip(kind, k, x)
    assert hits > 8           # the law must actually be exercised


def test_cq_sub8_dr_bits():
    """CQ with a sub-8 dr: deterministic roundtrip matches qf.cq bit-exactly,
    payloads bounded by dr-1 = 2^(dr_bits-1)-1 in int8 storage, scale stays
    the constant 2^(1-k_gc)."""
    for dr_bits in _SUB8_KS:
        q = get_quantizer("cq", 15,
                          (("dr_bits", dr_bits), ("stochastic", False)))
        for x in _sweep_arrays():
            qt = q.quantize(x)
            assert np.asarray(qt.data).dtype == np.int8
            assert np.abs(np.asarray(qt.data, np.int64)).max(initial=0) \
                <= 2 ** (dr_bits - 1) - 1
            assert float(qt.scale) == 2.0 ** (1 - 15)
            np.testing.assert_array_equal(
                np.asarray(q.dequantize(qt)),
                np.asarray(qf.cq(x, None, dr_bits, 15, stochastic=False)))


def test_none_kind_sub8_is_identity():
    for k in _SUB8_KS:
        q = get_quantizer("none", k)
        for x in _sweep_arrays():
            np.testing.assert_array_equal(np.asarray(q(x)), np.asarray(x))


if HAVE_HYPOTHESIS:
    settings.register_profile("qt_fast", max_examples=25, deadline=None)
    settings.load_profile("qt_fast")

    def _h_arrays():
        return st.lists(st.floats(-8.0, 8.0, allow_nan=False, width=32),
                        min_size=1, max_size=64).map(
            lambda v: jnp.asarray(v, jnp.float32))

    @given(_h_arrays(), st.sampled_from(_FIXED_GRID))
    def test_hyp_fixed_grid_idempotent(x, kk):
        check_idempotent_fixed(*kk, x)

    @given(_h_arrays(), st.sampled_from(_AMAX_SCALED))
    def test_hyp_amax_scaled_idempotent(x, kk):
        check_idempotent_scaled(*kk, x)

    @given(_h_arrays(),
           st.sampled_from(_FIXED_GRID + _AMAX_SCALED + [("cq", 15)]))
    def test_hyp_pow2_closure(x, kk):
        check_pow2_closure(*kk, x)

    @given(_h_arrays(), st.integers(1, 256), st.sampled_from([4, 8, 16, 32]))
    def test_hyp_wire_overflow(x, n, bits):
        check_wire_overflow(n, bits, x)


def test_frozen_qtensor_gets_no_gradient():
    """QTensors without a carrier (the int8 KV cache) are consumed but
    non-differentiable; gradients still flow to the other operand."""
    cfg = preset("full8", "native")
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16)) * 0.4
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 8)) * 0.1
    frozen = cfg.w.make().quantize(w)          # no carrier
    assert frozen.carrier is None

    def f(x):
        xq = qact(cfg, "relu", x)
        return jnp.sum(qeinsum(cfg, "mk,kn->mn", "default", True, xq, frozen))

    g = jax.grad(f)(x)
    assert g.shape == x.shape and bool(jnp.any(g != 0))
