"""Checkpoint manager: roundtrip, atomicity, retention, async, elastic."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "opt": {"acc": jnp.ones((3, 4)) * 0.5,
                    "step": jnp.int32(7)},
            "cache": jnp.zeros((2, 2), jnp.int8)}


def test_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_write=False)
    t = _tree()
    cm.save(10, t, aux={"loss": 1.25})
    got, step, aux = cm.restore(t)
    assert step == 10 and aux["loss"] == 1.25
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_async_write_and_retention(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2, async_write=True)
    t = _tree()
    for s in (1, 2, 3, 4):
        cm.save(s, t)
    cm.wait()
    assert cm.all_steps() == [3, 4]


def test_latest_and_specific_step(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=5, async_write=False)
    t = _tree()
    cm.save(1, jax.tree.map(lambda x: x * 1, t))
    cm.save(2, jax.tree.map(lambda x: x * 2, t))
    got, step, _ = cm.restore(t)               # latest
    assert step == 2
    got1, step1, _ = cm.restore(t, step=1)
    np.testing.assert_array_equal(np.asarray(got1["w"]), np.asarray(t["w"]))


def test_no_partial_checkpoint_visible(tmp_path):
    """tmp-* staging dirs must never be listed as restorable steps."""
    cm = CheckpointManager(str(tmp_path), async_write=False)
    os.makedirs(tmp_path / "tmp-99")           # simulated crash mid-write
    assert cm.all_steps() == []
    with pytest.raises(FileNotFoundError):
        cm.restore(_tree())


def test_elastic_restore_new_sharding(tmp_path):
    """Checkpoint written without a mesh restores under a mesh+pspec."""
    from jax.sharding import PartitionSpec as P
    cm = CheckpointManager(str(tmp_path), async_write=False)
    t = {"w": jnp.arange(8, dtype=jnp.float32).reshape(2, 4)}
    cm.save(5, t)
    mesh = jax.make_mesh((1,), ("data",))
    got, step, _ = cm.restore(t, mesh=mesh,
                              pspec_tree={"w": P("data", None)})
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))
    assert got["w"].sharding.spec == P("data", None)


def test_qtensor_leaves_roundtrip(tmp_path):
    """QTensor pytrees (int8 KV caches, wire payloads) checkpoint and
    restore through the named-path keys (GetAttrKey -> 'cache/k/data')."""
    from repro.core import QTensor, get_quantizer

    x = jnp.arange(24, dtype=jnp.float32).reshape(4, 6) / 32.0
    qt = get_quantizer("scaled", 8).quantize(x)
    tree = {"cache": {"k": qt}, "step": jnp.int32(3)}
    cm = CheckpointManager(str(tmp_path), async_write=False)
    cm.save(1, tree)
    got, step, _ = cm.restore(tree)
    assert isinstance(got["cache"]["k"], QTensor)
    assert got["cache"]["k"].data.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got["cache"]["k"].dequantize()),
                                  np.asarray(qt.dequantize()))
