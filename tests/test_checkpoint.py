"""Checkpoint manager: roundtrip, atomicity, retention, async, elastic."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "opt": {"acc": jnp.ones((3, 4)) * 0.5,
                    "step": jnp.int32(7)},
            "cache": jnp.zeros((2, 2), jnp.int8)}


def test_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_write=False)
    t = _tree()
    cm.save(10, t, aux={"loss": 1.25})
    got, step, aux = cm.restore(t)
    assert step == 10 and aux["loss"] == 1.25
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_async_write_and_retention(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2, async_write=True)
    t = _tree()
    for s in (1, 2, 3, 4):
        cm.save(s, t)
    cm.wait()
    assert cm.all_steps() == [3, 4]


def test_latest_and_specific_step(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=5, async_write=False)
    t = _tree()
    cm.save(1, jax.tree.map(lambda x: x * 1, t))
    cm.save(2, jax.tree.map(lambda x: x * 2, t))
    got, step, _ = cm.restore(t)               # latest
    assert step == 2
    got1, step1, _ = cm.restore(t, step=1)
    np.testing.assert_array_equal(np.asarray(got1["w"]), np.asarray(t["w"]))


def test_no_partial_checkpoint_visible(tmp_path):
    """tmp-* staging dirs must never be listed as restorable steps."""
    cm = CheckpointManager(str(tmp_path), async_write=False)
    os.makedirs(tmp_path / "tmp-99")           # simulated crash mid-write
    assert cm.all_steps() == []
    with pytest.raises(FileNotFoundError):
        cm.restore(_tree())


def test_elastic_restore_new_sharding(tmp_path):
    """Checkpoint written without a mesh restores under a mesh+pspec."""
    from jax.sharding import PartitionSpec as P
    cm = CheckpointManager(str(tmp_path), async_write=False)
    t = {"w": jnp.arange(8, dtype=jnp.float32).reshape(2, 4)}
    cm.save(5, t)
    mesh = jax.make_mesh((1,), ("data",))
    got, step, _ = cm.restore(t, mesh=mesh,
                              pspec_tree={"w": P("data", None)})
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))
    assert got["w"].sharding.spec == P("data", None)


def test_packed_encoding_roundtrip(tmp_path):
    """Grid-structured training state packs to integer containers on disk
    (hilo for k_WU=24 masters, i16 for k_Acc=13 accumulators, raw for int
    payloads) and roundtrips bit-exactly."""
    from repro.checkpoint import qsave

    w = (np.random.default_rng(0).integers(-2**23 + 1, 2**23, (64, 32))
         .astype(np.float32) * 2.0**-23)        # k_WU=24 grid
    acc = (np.random.default_rng(1).integers(-2**12 + 1, 2**12, (64,))
           .astype(np.float32) * 2.0**-12)      # k_Acc=13 grid
    # >31 bits between the smallest lsb and the largest magnitude -> no
    # integer container fits -> raw f32 fallback (e.g. a fresh init)
    off = np.array([1e-20, 1.0 + 2.0**-23] * 4, np.float32)
    tree = {"w": w, "opt": {"acc": acc}, "kv": np.ones((4,), np.int8),
            "off": off}
    cm = CheckpointManager(str(tmp_path), async_write=False)
    cm.save(1, tree)
    fmt = cm.meta(1)["qsave"]
    assert fmt["w"]["enc"] == "hilo"
    assert fmt["opt/acc"]["enc"] == "i16"
    assert fmt["kv"]["enc"] == "raw" and fmt["off"]["enc"] == "raw"
    rep = cm.size_report(1)
    assert rep["ckpt_bytes_q"] < rep["ckpt_bytes_f32_dense"]
    assert qsave.stored_bytes(fmt["w"]) == 3 * w.size
    got, _, _ = cm.restore(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_restore_casts_to_target_dtype_under_mesh(tmp_path):
    """Leaf dtypes follow the TARGET tree on the mesh placement path too
    (a f64-saved leaf restores as the f32 the step function wants)."""
    from jax.sharding import PartitionSpec as P
    cm = CheckpointManager(str(tmp_path), async_write=False)
    cm.save(1, {"w": np.arange(8, dtype=np.float64)})
    target = {"w": jax.ShapeDtypeStruct((8,), jnp.float32)}
    mesh = jax.make_mesh((1,), ("data",))
    got, _, _ = cm.restore(target, mesh=mesh, pspec_tree={"w": P("data")})
    assert got["w"].dtype == jnp.float32
    got2, _, _ = cm.restore(target)             # host path, same rule
    assert got2["w"].dtype == jnp.float32


def test_restore_array_set_mismatch(tmp_path):
    """A target tree whose keys differ from the checkpoint raises a clear
    ValueError naming the missing/unexpected arrays, not a KeyError deep
    in npz."""
    cm = CheckpointManager(str(tmp_path), async_write=False)
    cm.save(1, {"w": np.zeros(3), "b": np.zeros(2)})
    with pytest.raises(ValueError, match="extra"):
        cm.restore({"w": np.zeros(3), "extra": np.zeros(1)})
    with pytest.raises(ValueError, match="b"):
        cm.restore({"w": np.zeros(3)})
    with pytest.raises(ValueError, match="shape"):
        cm.restore({"w": np.zeros(4), "b": np.zeros(2)})


def test_tmp_sweep_and_failed_publish(tmp_path):
    """A writer killed mid-save leaves tmp-<step> but never publishes; the
    failure surfaces at wait(), the latest checkpoint is unchanged, and the
    next manager construction sweeps the staging dir."""
    cm = CheckpointManager(str(tmp_path), async_write=True)
    t = _tree()
    cm.save(1, t)
    cm.wait()
    cm._fail_next_write = True                  # chaos hook: die pre-publish
    cm.save(2, t)
    with pytest.raises(RuntimeError, match="injected"):
        cm.wait()
    assert cm.latest_step() == 1                # step 2 never published
    assert os.path.isdir(tmp_path / "tmp-2")
    cm2 = CheckpointManager(str(tmp_path))
    assert not os.path.isdir(tmp_path / "tmp-2")
    assert cm2.latest_step() == 1


def test_unpacked_mode_back_compat(tmp_path):
    """packed=False writes dense npz (no qsave fmt) and restore handles
    checkpoints without packing metadata."""
    cm = CheckpointManager(str(tmp_path), async_write=False, packed=False)
    t = _tree()
    cm.save(1, t)
    assert "qsave" not in cm.meta(1)
    got, _, _ = cm.restore(t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_export_int8_report_ratio():
    """The lossy serving export packs float leaves to ~1 byte/elem (>=3x
    vs dense f32) while integer leaves pass through."""
    from repro.checkpoint import qsave
    from repro.checkpoint.manager import _flatten_with_paths

    tree = {"w": jnp.asarray(np.random.default_rng(0)
                             .standard_normal((64, 64)), jnp.float32),
            "step": jnp.int32(3)}
    ex = qsave.export_int8(tree)
    _, fmt = qsave.pack_tree(_flatten_with_paths(ex))
    rep = qsave.report(fmt)
    assert rep["ratio"] >= 3.0, rep


def test_qtensor_leaves_roundtrip(tmp_path):
    """QTensor pytrees (int8 KV caches, wire payloads) checkpoint and
    restore through the named-path keys (GetAttrKey -> 'cache/k/data')."""
    from repro.core import QTensor, get_quantizer

    x = jnp.arange(24, dtype=jnp.float32).reshape(4, 6) / 32.0
    qt = get_quantizer("scaled", 8).quantize(x)
    tree = {"cache": {"k": qt}, "step": jnp.int32(3)}
    cm = CheckpointManager(str(tmp_path), async_write=False)
    cm.save(1, tree)
    got, step, _ = cm.restore(tree)
    assert isinstance(got["cache"]["k"], QTensor)
    assert got["cache"]["k"].data.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got["cache"]["k"].dequantize()),
                                  np.asarray(qt.dequantize()))
