"""End-to-end system behaviour: training converges under WAGEUBN, restart
is bit-exact, MoE routing invariants, the dry-run machinery compiles a tiny
multi-pod mesh in a subprocess."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get
from repro.configs.base import ArchConfig
from repro.core import preset
from repro.data import TokenTask
from repro.launch.train import make_train_step
from repro.models import build_model
from repro.optim import init_momentum

TINY = ArchConfig(name="tiny", family="lm", n_layers=2, d_model=64,
                  n_heads=4, n_kv=2, d_ff=128, vocab=64, head_dim=16,
                  q_chunk=32, kv_chunk=32)


def _train(qname, mode, steps=30, seed=0, arch=TINY, lr=0.05):
    qcfg = preset(qname, mode if qname != "fp32" else None)
    model = build_model(arch, qcfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt = init_momentum(params)
    labels = model.labels(params)
    step_fn = jax.jit(make_train_step(model, qcfg, labels, lr=lr))
    task = TokenTask(vocab=arch.vocab, seq_len=32, global_batch=8)
    losses = []
    for s in range(steps):
        batch = jax.tree.map(jnp.asarray, task.batch(s))
        params, opt, m = step_fn(params, opt, batch, jnp.int32(s))
        losses.append(float(m["loss"]))
    return losses, params, opt


def test_wageubn_full8_training_converges():
    losses, _, _ = _train("full8", "sim", steps=40)
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.15, (first, last)


def test_full8_tracks_fp32_early_training():
    """Paper Fig. 6: WAGEUBN curves track FP32 closely early in training."""
    l8, _, _ = _train("full8", "sim", steps=30)
    lf, _, _ = _train("fp32", None, steps=30)
    assert abs(np.mean(l8[-5:]) - np.mean(lf[-5:])) < 0.8


def test_restart_bit_exact(tmp_path):
    """Crash after step 20, restore from step-10 checkpoint -> bit-identical
    params at step 30 (deterministic data + step-derived rounding keys)."""
    qcfg = preset("full8", "sim")
    model = build_model(TINY, qcfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_momentum(params)
    labels = model.labels(params)
    step_fn = jax.jit(make_train_step(model, qcfg, labels))
    task = TokenTask(vocab=TINY.vocab, seq_len=32, global_batch=8)

    def run(params, opt, start, end, cm=None):
        for s in range(start, end):
            batch = jax.tree.map(jnp.asarray, task.batch(s))
            params, opt, _ = step_fn(params, opt, batch, jnp.int32(s))
            if cm and (s + 1) % 10 == 0:
                cm.save(s + 1, (params, opt), block=True)
        return params, opt

    cm = CheckpointManager(str(tmp_path), keep=5, async_write=False)
    p_ref, o_ref = run(params, opt, 0, 30, cm)

    (p_r, o_r), step, _ = cm.restore((params, opt), step=10)
    assert step == 10
    p_got, _ = run(p_r, o_r, 10, 30)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_moe_routing_invariants():
    from repro.models.moe import _moe_local
    acfg = get("granite-moe-1b-a400m").reduced()
    qcfg = preset("fp32")
    d, e = acfg.d_model, acfg.moe_experts
    x = jax.random.normal(jax.random.PRNGKey(0), (32, d))
    rw = jax.random.normal(jax.random.PRNGKey(1), (d, e))
    wg = jax.random.normal(jax.random.PRNGKey(2), (e, d, acfg.d_ff)) * 0.05
    wu = jax.random.normal(jax.random.PRNGKey(3), (e, d, acfg.d_ff)) * 0.05
    wd = jax.random.normal(jax.random.PRNGKey(4), (e, acfg.d_ff, d)) * 0.05
    y = _moe_local(qcfg, acfg, x, rw, wg, wu, wd, e_off=0)
    assert y.shape == x.shape and not bool(jnp.isnan(y).any())
    # splitting experts across two "devices" and summing == single device
    y0 = _moe_local(qcfg, acfg, x, rw, wg[:e // 2], wu[:e // 2],
                    wd[:e // 2], e_off=0)
    y1 = _moe_local(qcfg, acfg, x, rw, wg[e // 2:], wu[e // 2:],
                    wd[e // 2:], e_off=e // 2)
    np.testing.assert_allclose(np.asarray(y0 + y1), np.asarray(y),
                               rtol=2e-4, atol=2e-5)


def test_dryrun_tiny_multipod_subprocess():
    """The dry-run machinery end-to-end on an 8-device (2,2,2) pod mesh."""
    env = dict(os.environ, PYTHONPATH="src", REPRO_DEVICES="8")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "falcon-mamba-7b", "--shape", "decode_32k", "--mesh", "multi",
         "--out-dir", "/tmp/dryrun_test_smoke", "--force"],
        capture_output=True, text=True, timeout=560, env=env, cwd=root)
    assert "all requested dry-run cells compiled OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-2000:]
