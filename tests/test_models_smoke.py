"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
asserting output shapes + no NaNs (assignment requirement (f))."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get
from repro.core import preset
from repro.models import build_model

B, S = 2, 16


def _batch(acfg, key=0):
    v = acfg.vocab
    tok = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0, v)
    lab = jax.random.randint(jax.random.PRNGKey(key + 1), (B, S), 0, v)
    if acfg.family == "encdec":
        st = S // acfg.tgt_ratio
        return {
            "frames": jax.random.normal(jax.random.PRNGKey(key + 2),
                                        (B, S, acfg.d_model)),
            "tokens": tok[:, :st], "labels": lab[:, :st]}
    return {"tokens": tok, "labels": lab}


@pytest.mark.parametrize("name", ASSIGNED)
def test_arch_smoke_train_step(name):
    acfg = get(name).reduced()
    qcfg = preset("full8", "sim")
    model = build_model(acfg, qcfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(acfg)
    (loss, metrics), grads = jax.value_and_grad(
        model.loss, has_aux=True)(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), name
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert not bool(jnp.isnan(g).any()), (name, path)
    # labels tree structurally matches params
    labels = model.labels(params)
    lflat = jax.tree_util.tree_structure(params).flatten_up_to(labels)
    assert all(isinstance(s, str) for s in lflat)


@pytest.mark.parametrize("name", ["granite-3-8b", "falcon-mamba-7b",
                                  "zamba2-7b", "granite-moe-1b-a400m"])
def test_arch_smoke_serve_step(name):
    acfg = get(name).reduced()
    qcfg = preset("full8", "sim")
    model = build_model(acfg, qcfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, acfg.vocab)
    if acfg.family == "ssm":
        cache, logits = model.prefill(params, tok[:, :-1])
    else:
        cache, logits = model.prefill(params, tok[:, :-1], S + 4)
    cache, logits = model.serve_step(params, cache, tok[:, -1])
    assert logits.shape == (B, acfg.vocab_padded)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("name", ["granite-3-8b", "granite-moe-1b-a400m",
                                  "falcon-mamba-7b", "zamba2-7b",
                                  "seamless-m4t-large-v2"])
def test_arch_smoke_native_train_step(name):
    """One representative arch per family under NATIVE mode: activations and
    weights flow as int8 QTensors into the integer matmuls (fwd + bwd)."""
    acfg = get(name).reduced()
    model = build_model(acfg, preset("full8", "native"))
    params = model.init(jax.random.PRNGKey(0))
    (loss, _), grads = jax.value_and_grad(
        model.loss, has_aux=True)(params, _batch(acfg))
    assert not bool(jnp.isnan(loss)), name
    gmax = max(float(jnp.abs(g).max()) for g in jax.tree.leaves(grads))
    assert gmax > 0, name


@pytest.mark.parametrize("name", ["granite-3-8b", "zamba2-7b"])
def test_arch_smoke_native_serve_step(name):
    """Native decode: the int8 KV cache is consumed as QTensors — cache
    payloads feed the attention matmuls with no dequantize round trip."""
    acfg = get(name).reduced()
    model = build_model(acfg, preset("full8", "native"))
    params = model.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, acfg.vocab)
    cache, logits = model.prefill(params, tok[:, :-1], S + 4)
    cache, logits = model.serve_step(params, cache, tok[:, -1])
    assert logits.shape == (B, acfg.vocab_padded)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("name", ["resnet18", "resnet34", "resnet50"])
def test_resnet_smoke(name):
    acfg = get(name).reduced()
    qcfg = preset("full8", "sim")
    model = build_model(acfg, qcfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {
        "images": jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16, 3)),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4,), 0, 10)}
    (loss, metrics), grads = jax.value_and_grad(
        model.loss, has_aux=True)(params, batch)
    assert not bool(jnp.isnan(loss))
    gmax = max(float(jnp.abs(g).max()) for g in jax.tree.leaves(grads))
    assert gmax > 0


def test_full_configs_match_assignment():
    """The exact numbers from the assignment block."""
    c = get("chameleon-34b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (48, 8192, 64, 8, 22016, 65536)
    m = get("moonshot-v1-16b-a3b")
    assert (m.moe_experts, m.moe_topk, m.vocab) == (64, 6, 163840)
    g = get("granite-34b")
    assert (g.n_layers, g.n_kv) == (88, 1)
    f = get("falcon-mamba-7b")
    assert (f.n_layers, f.d_model, f.ssm_state, f.d_ff) == (64, 4096, 16, 0)
    z = get("zamba2-7b")
    assert (z.n_layers, z.d_model, z.ssm_state) == (81, 3584, 64)
    s = get("seamless-m4t-large-v2")
    assert (s.d_model, s.vocab, s.d_ff) == (1024, 256206, 8192)
