"""Sharded int8 serving: TP paged decode + multi-replica routing suite.

The headline contract (DESIGN.md §12): serving is parameterized by the
quantization algorithm, not the device layout — a tp=2 engine (int8 KV
pages head-sharded across model ranks, amax scales pmax-synced) greedy-
decodes bit-identical tokens to the single-device engine, and a replica
tier behind the Router preserves them too as long as the per-step lane
composition matches (§7's amax-composition caveat).  Cross-rank decode
traffic must be integer tensors + scalar floats only.

Multi-device tests run in subprocesses: the virtual device count must be
set via XLA_FLAGS before jax initializes.  Router policy tests are pure
host logic and run in-process on one device.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(prog: str, timeout: int = 1500) -> str:
    env = dict(os.environ, PYTHONPATH="src:tests",
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=timeout, env=env, cwd=_ROOT)
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-4000:]
    return r.stdout


_PRELUDE = textwrap.dedent("""
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.serving import make_engine, make_router, make_sharded_engine

    ARCHS = ["granite-3-8b", "granite-moe-1b-a400m", "zamba2-7b"]
    # chunked prefill everywhere: tp>1 requires it, and the tp=1 baselines
    # must quantize prefill at the same (page-chunk) granularity to compare
    KW = dict(max_lanes=2, page_size=4, max_ctx=32, prefill_mode="chunked")
    PROMPTS = [np.arange(1, 9), np.arange(3, 15)]
    SOLO = [np.arange(1 + i, 9 + i) for i in range(4)]

    def batch_tokens(eng, prompts, max_new=6):
        rids = [eng.submit(p, max_new) for p in prompts]
        out = eng.drain()
        return [out[r] for r in rids]

    def solo_tokens(eng, prompts, max_new=5):
        toks = []
        for p in prompts:
            r = eng.submit(p, max_new)
            toks.append(eng.drain()[r])
        return toks
""")


_EXACT_PROG = _PRELUDE + textwrap.dedent("""
    # tp x dp bit-exactness sweep vs the single-device engine, per family.
    #   tp=2, dp=1: same lane batch -> co-batched submissions compare.
    #   dp=2 (router): placement may split a batch across replicas, which
    #   changes lane composition and therefore amax scales (§7) — so the
    #   replica-tier comparisons run solo-composition (one request in
    #   flight at a time; identical lane batch wherever it lands).
    for arch in ARCHS:
        ref = make_engine(arch, **KW)
        want_batch = batch_tokens(ref, PROMPTS)
        # fresh engine for the solo baseline: retired lanes keep their
        # last slot state, which feeds the shared amax of later steps —
        # composition includes HISTORY, not just live lanes (§7)
        ref2 = make_engine(arch, **KW)
        want_solo = solo_tokens(ref2, SOLO)

        tp2 = make_sharded_engine(arch, tp=2, **KW)
        assert batch_tokens(tp2, PROMPTS) == want_batch, arch
        print("OK", arch, "tp2")

        for tp in (1, 2):
            router = make_router(arch, replicas=2, tp=tp, **KW)
            assert solo_tokens(router, SOLO) == want_solo, (arch, tp)
            m = router.metrics()
            assert m["completed"] == len(SOLO)
            print("OK", arch, f"dp2 tp{tp}")
    print("EXACT_OK")
""")


_PREEMPT_RADIX_PROG = _PRELUDE + textwrap.dedent("""
    # Same scheduling trajectory on both engines (deterministic stepping,
    # identical pool sizes) -> identical tokens THROUGH a recompute
    # preemption and THROUGH a radix-cache hit, tp=1 vs tp=2.
    arch = "granite-3-8b"

    def preempt_run(build):
        eng = build(prefill_mode="chunked", max_lanes=3, page_size=4,
                    max_ctx=40, n_pages=11)
        rids = [eng.submit(np.arange(1 + i, 9 + i), 18) for i in range(3)]
        out = eng.drain()
        m = eng.metrics()
        assert m["preemptions"] > 0, "pool was big enough — no preemption"
        return [out[r] for r in rids], m["preemptions"]

    toks1, n1 = preempt_run(lambda **kw: make_engine(arch, **kw))
    toks2, n2 = preempt_run(lambda **kw: make_sharded_engine(arch, tp=2, **kw))
    assert n1 == n2 and toks1 == toks2, (n1, n2)
    print("OK preempt", n1)

    def radix_run(build):
        eng = build(radix_cache=True, **KW)
        first = solo_tokens(eng, [np.arange(1, 9)], max_new=5)[0]
        again = solo_tokens(eng, [np.arange(1, 9)], max_new=5)[0]
        m = eng.metrics()
        assert m["prefix_hit_rate"] > 0, "second pass missed the radix"
        return first, again, m["prefix_hit_rate"]

    f1, a1, h1 = radix_run(lambda **kw: make_engine(arch, **kw))
    f2, a2, h2 = radix_run(lambda **kw: make_sharded_engine(arch, tp=2, **kw))
    assert f1 == a1, "radix hit changed tokens on the baseline"
    assert (f1, a1, h1) == (f2, a2, h2)
    print("OK radix", h1)
    print("PREEMPT_RADIX_OK")
""")


_WIRE_PROG = _PRELUDE + textwrap.dedent("""
    # Integer-wire acceptance on the tp=2 decode trace, per family: every
    # tensor-shaped collective payload (all_gather / ppermute /all_to_all)
    # is integer dtype; float collectives are scalar-only (the pmax'ed
    # amax scales).  fresh_trace keeps the inspection out of the live
    # _decode_jit's tracing cache (see tests/jaxpr_utils.py).
    from jaxpr_utils import fresh_trace
    from repro.kernels import ops

    for arch in ARCHS + ["falcon-mamba-7b"]:
        eng = make_sharded_engine(arch, tp=2, **KW)
        slots = dict(eng.slots, pos=jnp.zeros((eng.max_lanes,), jnp.int32))
        kp, vp = ((eng.pool.k, eng.pool.v) if eng.paged
                  else (jnp.zeros((0,), jnp.int8),) * 2)
        jaxpr = fresh_trace(eng._decode_step, eng.params, slots, kp, vp,
                            jnp.asarray(eng.table),
                            jnp.asarray(eng.h_tokens), np.int32(0))
        colls = ops.collective_eqns(jaxpr.jaxpr)
        assert colls, (arch, "no collectives — tp=2 trace not sharded?")
        floats = [c for c in colls if c[2] is not None
                  and jnp.issubdtype(c[2], jnp.floating)]
        assert all(c[1] == () for c in floats), \\
            (arch, [c for c in floats if c[1] != ()])
        wires = [c for c in colls
                 if c[0] in ("all_gather", "ppermute", "all_to_all")]
        assert wires and all(jnp.issubdtype(c[2], jnp.integer)
                             for c in wires), (arch, wires)
        print("OK wire", arch)
    print("WIRE_OK")
""")


def test_tp_dp_greedy_bitexact_sweep():
    """tp in {1,2} x dp-replicas in {1,2}: greedy tokens match the
    single-device engine bitwise for lm / moe / hybrid."""
    out = _run(_EXACT_PROG)
    assert "EXACT_OK" in out, out


def test_tp_preemption_and_radix_hit_bitexact():
    """Recompute preemption and radix-hit trajectories replay identically
    under tp=2 (same schedule, same tokens, same hit rate)."""
    out = _run(_PREEMPT_RADIX_PROG)
    assert "PREEMPT_RADIX_OK" in out, out


def test_tp_decode_wire_integer_only():
    """No tensor-shaped float ever crosses ranks during sharded decode."""
    out = _run(_WIRE_PROG)
    assert "WIRE_OK" in out, out


# --------------------------------------------------------------------------
# Router policy (host logic — in-process, single device)
# --------------------------------------------------------------------------


def _fake_clock(dt=0.001):
    """Deterministic time source: advances a fixed dt per call, so TTFT
    accounting and run_load's arrival gating replay identically."""
    state = {"t": 0.0}

    def clock():
        state["t"] += dt
        return state["t"]
    return clock


def _mini_router(replicas=2, **kw):
    from repro.serving import make_router
    kw.setdefault("max_lanes", 2)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_ctx", 32)
    return make_router("granite-3-8b", replicas=replicas,
                       clock=_fake_clock(), **kw)


def test_router_placement_deterministic_under_seeded_load():
    """Same seeded traffic + virtual clock -> identical placement sequence
    and identical tokens on two independent router instances."""
    from repro.serving import poisson_traffic, run_load
    traffic = poisson_traffic(rate=500.0, n_requests=8,
                              prompt_lens=(8, 12), gen_lens=(4, 6), seed=7)
    runs = []
    for _ in range(2):
        router = _mini_router()
        results, m = run_load(router, traffic)
        runs.append((router.placements, results))
    assert runs[0] == runs[1]
    assert len(runs[0][1]) == 8
    assert sum(m["placements"]) == 8


def test_router_affinity_beats_single_replica_hit_rate():
    """sharing=0.9 workload: radix-affinity placement keeps the fleet's
    prefix hit rate at least the single-replica rate (shared-prefix
    traffic lands on the replica that already caches the prefix)."""
    from repro.serving import make_engine, shared_prefix_traffic
    traffic = shared_prefix_traffic(rate=100.0, n_requests=12, sharing=0.9,
                                    prefix_len=16, n_prefixes=2,
                                    tail_lens=(4, 8), gen_lens=(4,), seed=5)
    kw = dict(max_lanes=2, page_size=4, max_ctx=40, prefill_mode="chunked",
              radix_cache=True)

    def hit_rate(target):
        for r in traffic:                 # sequential: deterministic state
            rid = target.submit(r["prompt"], r["max_new"])
            target.drain()
        return target.metrics()["prefix_hit_rate"]

    single = hit_rate(make_engine("granite-3-8b", **kw))
    fleet = hit_rate(_mini_router(**kw))
    assert single > 0.3, single           # the workload does share
    assert fleet >= single, (fleet, single)


def test_router_kill_replica_drains_and_requeues():
    """Chaos hook (`_kill_replica`, the checkpoint-manager pattern): kill a
    replica mid-decode; its in-flight work folds generated tokens into the
    prompt and requeues on the survivor, everything completes with its
    exact token budget, and the dead replica takes no further work."""
    router = _mini_router()
    rids = [router.submit(np.arange(1 + i, 9 + i), 6) for i in range(4)]
    for _ in range(3):
        router.step()
    victim = next(r.replica for r in router.requests.values())
    router._kill_replica = victim
    out = router.drain()
    m = router.metrics()
    assert m["kills"] == 1 and m["replicas_dead"] == 1
    assert m["requeues"] >= 1
    for rid in rids:
        assert len(out[rid]) == 6, (rid, len(out[rid]))
    for req in router.requests.values():
        assert req.replica != victim      # everyone ended on a survivor
    evac = [r for r in router.requests.values() if r.evacuations]
    assert evac and all(r.done for r in evac)
    # a post-kill submission also avoids the corpse
    rid2 = router.submit(np.arange(2, 10), 4)
    assert router.requests[rid2].replica != victim
    assert len(router.drain()[rid2]) == 4


def test_router_rid_spaces_do_not_collide():
    """Two replicas hand out colliding per-engine rids; the router's own
    rid space maps through (replica, engine_rid) without mixing streams."""
    router = _mini_router()
    # force one request onto each replica by loading replica 0 first
    a = router.submit(np.arange(1, 9), 8)
    router.step()
    b = router.submit(np.arange(3, 15), 4)
    keys = set(router._live)
    assert len(keys) == 2
    assert len({k[0] for k in keys}) == 2, keys   # distinct replicas
    out = router.drain()
    assert len(out[a]) == 8 and len(out[b]) == 4


def test_shard_serving_spec_rules_single_process():
    """Spec rules for serving state are pure metadata — no devices needed:
    the recurrent families' registry entries and the page-pool / decode-
    slot specs place the model axis where DESIGN.md §12 says."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.configs import get
    from repro.core import preset
    from repro.launch.shard import (decode_slot_specs, page_pool_spec,
                                    tp_param_specs)
    from repro.models import build_model

    qcfg = preset("full8", "native")

    # ssm (mamba1): d_inner channel split — x_proj/out_proj row, dt col
    m = build_model(get("falcon-mamba-7b").reduced(), qcfg, tp_size=2)
    params = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    specs = tp_param_specs(m, params)
    blk = specs["layers"]
    assert blk["x_proj"][-2] == "model" and blk["out_proj"][-2] == "model"
    assert blk["dt_proj"][-1] == "model" and blk["A_log"][-2] == "model"
    assert blk["in_proj"] == P() and specs["embed"] == P()
    slots = jax.eval_shape(lambda: m.init_slots(2))
    sspec = decode_slot_specs(m, slots)
    assert sspec["h"][2] == "model" and sspec["conv"] == P()
    assert page_pool_spec(m) == P()       # no KV pages in a pure SSM

    # hybrid (zamba2): SSD head split + attention head split, paged KV
    h = build_model(get("zamba2-7b").reduced(), qcfg, tp_size=2)
    hparams = jax.eval_shape(h.init, jax.random.PRNGKey(0))
    hspecs = tp_param_specs(h, hparams)
    mb = hspecs["layers"]
    assert mb["dt_proj"][-1] == "model" and mb["A_log"][-1] == "model"
    assert mb["in_proj"] == P() and mb["out_proj"] == P()
    assert hspecs["shared"]["wq"][-1] == "model"
    assert hspecs["shared"]["wo"][-2] == "model"
    assert page_pool_spec(h) == P(None, None, None, "model", None)
    hslots = jax.eval_shape(lambda: h.init_slots(2))
    hs = decode_slot_specs(h, hslots)
    assert hs["m_h"][2] == "model" and hs["m_conv"] == P()

    # indivisible widths refuse manual TP
    with pytest.raises(ValueError):
        build_model(get("falcon-mamba-7b").reduced(), qcfg, tp_size=3)
