"""Quantized ops: sim/native agreement, backward quantization semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import preset, qact, qdense, qeinsum, qweight
from repro.core import qfuncs as qf


@pytest.fixture(scope="module")
def data():
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (6, 32)) * 0.5
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16)) * 0.15
    return x, w


def test_sim_native_forward_exact(data):
    x, w = data
    xq = qact(preset("full8", "sim"), "relu", x)
    ys = qdense(preset("full8", "sim"), xq, w)
    yn = qdense(preset("full8", "native"), xq, w)
    np.testing.assert_array_equal(np.asarray(ys), np.asarray(yn))


@pytest.mark.parametrize("name", ["full8", "e2_16"])
def test_sim_native_grads_close(data, name):
    x, w = data
    def loss(cfg, w):
        return jnp.sum(qdense(cfg, qact(cfg, "relu", x), w) ** 2)
    gs = jax.grad(lambda w: loss(preset(name, "sim"), w))(w)
    gn = jax.grad(lambda w: loss(preset(name, "native"), w))(w)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(gn),
                               rtol=1e-4, atol=1e-5)


def test_fp32_matches_plain_autodiff(data):
    x, w = data
    cfg = preset("fp32")
    def f(w):
        return jnp.sum(qdense(cfg, jax.nn.relu(x), w) ** 2)
    def ref(w):
        return jnp.sum((jax.nn.relu(x) @ w) ** 2)
    np.testing.assert_allclose(np.asarray(jax.grad(f)(w)),
                               np.asarray(jax.grad(ref)(w)), rtol=1e-6)


def test_backward_errors_are_quantized(data):
    """dL/dx of a sim-mode qdense must lie on the Q_E2 grid composed with
    the weight matmul — check the error entering the matmul was flagged."""
    x, w = data
    cfg = preset("full8", "sim")
    xq = qact(cfg, "relu", x)
    wq = qf.q_clip(w, 8)
    g = jax.random.normal(jax.random.PRNGKey(2), (6, 16))
    # manually: eq = flag_qe2(g); dx = eq @ wq.T
    want = qf.flag_qe2(g, 8) @ wq.T
    _, vjp = jax.vjp(lambda t: qeinsum(cfg, "mk,kn->mn", "default", True, t, wq),
                     xq)
    got = vjp(g)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-6)


def test_qact_backward_applies_qe1(data):
    x, _ = data
    cfg = preset("full8", "sim")
    g = jax.random.normal(jax.random.PRNGKey(3), x.shape) * 1e-3
    _, vjp = jax.vjp(lambda t: qact(cfg, "relu", t), x)
    got = vjp(g)[0]
    want = qf.sq(g, 8) * (x > 0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-9)


def test_qweight_ste(data):
    _, w = data
    cfg = preset("full8", "sim")
    g = jax.grad(lambda t: jnp.sum(qweight(cfg, t)))(w)
    assert jnp.allclose(g, 1.0)


def test_qeinsum_batched_spec():
    cfg = preset("full8", "sim")
    a = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 8, 4)) * 0.3
    b = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 8, 4)) * 0.3
    y = qeinsum(cfg, "bskd,btkd->bskt", "sq8", False, a, b)
    assert y.shape == (2, 3, 8, 5)
    g = jax.grad(lambda a: jnp.sum(
        qeinsum(cfg, "bskd,btkd->bskt", "sq8", False, a, b) ** 2))(a)
    assert g.shape == a.shape and not bool(jnp.isnan(g).any())


def test_native_int8_residuals():
    """Native qeinsum saves int8 QTensor residuals (the 4x memory win)."""
    cfg = preset("full8", "native")
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16)) * 0.3
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 8)) * 0.1
    from repro.core.qdense import _qeinsum_fwd
    _, res = _qeinsum_fwd(cfg, "mk,kn->mn", "default", True, "arr", "arr",
                          x, qf.q_clip(w, 8))
    qa, qb = res
    assert qa.data.dtype == jnp.int8 and qb.data.dtype == jnp.int8
    assert qa.carrier is None and qb.carrier is None


# --------------------------------------------------------------------------
# fused-prologue backward route (DESIGN.md §8)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["full8", "e2_16"])
@pytest.mark.parametrize("e_kind", ["default", "sq8", "sq16", "flag8",
                                    "none"])
def test_native_fused_bwd_bit_exact(data, name, e_kind):
    """Fused dgrad/wgrad (Q_E2 in the kernel prologue) must reproduce the
    legacy quantize-then-contract backward bit-exactly for every e_kind."""
    x, w = data
    cfg_f = preset(name, "native")
    cfg_u = cfg_f.replace(fuse_kernels=False)

    def loss(cfg, x, w):
        y = qeinsum(cfg, "mk,kn->mn", e_kind, True,
                    qact(cfg, "relu", x), qweight(cfg, w))
        return jnp.sum(y ** 2)

    for argnum in (0, 1):
        gf = jax.grad(lambda *a: loss(cfg_f, *a), argnums=argnum)(x, w)
        gu = jax.grad(lambda *a: loss(cfg_u, *a), argnums=argnum)(x, w)
        np.testing.assert_array_equal(np.asarray(gf), np.asarray(gu))


def test_native_fused_bwd_falls_back_on_batched_spec():
    """Non-canonical specs keep the unfused route (and still agree with
    themselves under the fuse_kernels toggle)."""
    cfg = preset("full8", "native")
    a = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 8, 4)) * 0.3
    b = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 8, 4)) * 0.3
    for c in (cfg, cfg.replace(fuse_kernels=False)):
        g = jax.grad(lambda a: jnp.sum(
            qeinsum(c, "bskd,btkd->bskt", "sq8", False, a, b) ** 2))(a)
        assert g.shape == a.shape and not bool(jnp.isnan(g).any())


from jaxpr_utils import collect_outside_pallas as _collect_outside_pallas


def test_native_fused_bwd_jaxpr_no_standalone_quantize(monkeypatch):
    """Acceptance: on the kernel route, the native backward contains NO
    standalone fp32 amax/quantize pass between error quantization and the
    matmuls — the only amax is the error quantizer's scale reduction
    (shared by both dots), every tensor-shaped round/clip lives inside a
    pallas_call, and every integer dot is a kernel (no XLA dot_general)."""
    from repro.core.qtensor import QTensor
    from repro.kernels import ops
    cfg = preset("full8", "native")
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 32)) * 0.4
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16)) * 0.1
    xq = cfg.a.make().quantize(x)          # payload built BEFORE the patch

    monkeypatch.setattr(ops, "_on_tpu", lambda: True)

    def f(data, scale, w):
        qa = QTensor(data, scale, 8).with_carrier()
        y = qeinsum(cfg, "mk,kn->mn", "default", True, qa, qweight(cfg, w))
        return jnp.sum(y)

    jaxpr = jax.make_jaxpr(jax.grad(f, argnums=2))(xq.data, xq.scale, w)
    prims = []
    _collect_outside_pallas(jaxpr.jaxpr, prims)
    names = [n for n, _ in prims]
    # exactly one amax: the error quantizer's pow2 scale on the cotangent
    assert names.count("reduce_max") == 1, names
    # forward qmatmul + weight-payload quantize + fused dgrad + fused wgrad
    assert names.count("pallas_call") >= 4, names
    # no tensor-shaped rounding/saturation outside the kernels (scalar
    # rounds — the pow2 scale — are the only ones allowed)
    offenders = [(n, s) for n, s in prims
                 if n in ("round", "clamp") and s not in (None, ())]
    assert not offenders, offenders
    # every matmul is a Pallas kernel
    assert "dot_general" not in names, names


@pytest.mark.parametrize("e2_kind", ["flag8", "sq8", "sq16"])
def test_native_qconv_bwd_fused_toggle_bit_exact(e2_kind):
    """_qconv_bwd's payload route (and the legacy-formula fallback it keeps
    for multi-plane/wide formats) must match fuse_kernels=False exactly."""
    from repro.core import qconv
    cfg = preset("full8", "native").replace(e2_kind=e2_kind)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 4)) * 0.4
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 4, 6)) * 0.2
    wq = qf.q_clip(w, 8)

    def loss(c, t, v):
        return jnp.sum(qconv(c, t, v, 1, "SAME") ** 2)

    for argnum in (0, 1):
        gf = jax.grad(lambda *a: loss(cfg, *a), argnums=argnum)(x, wq)
        gu = jax.grad(
            lambda *a: loss(cfg.replace(fuse_kernels=False), *a),
            argnums=argnum)(x, wq)
        np.testing.assert_array_equal(np.asarray(gf), np.asarray(gu))


def test_qdense_requant_fused_emits_payload_directly():
    """qdense_requant: the fused epilogue's int8 payload equals the
    carrier-then-quantize fallback bit-exactly, and on the kernel route no
    fp32 carrier or separate quantize exists outside the pallas_call."""
    from repro.core import qdense_requant
    from repro.kernels import ops
    cfg = preset("full8", "native")
    x = jax.random.normal(jax.random.PRNGKey(0), (6, 32)) * 0.5
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16)) * 0.15
    xq = qact(cfg, "relu", x)
    step = 2.0 ** -7
    qt_f = qdense_requant(cfg, xq, w, step)
    qt_u = qdense_requant(cfg.replace(fuse_kernels=False), xq, w, step)
    assert qt_f.data.dtype == jnp.int8 and qt_f.carrier is None
    np.testing.assert_array_equal(np.asarray(qt_f.data),
                                  np.asarray(qt_u.data))
    # sim mode agrees on the represented value's grid too
    qt_s = qdense_requant(preset("full8", "sim"), xq, w, step)
    np.testing.assert_array_equal(np.asarray(qt_f.data),
                                  np.asarray(qt_s.data))


def test_qdense_requant_jaxpr_single_matmul_kernel(monkeypatch):
    from repro.core import qdense_requant
    from repro.core.qtensor import QTensor
    from repro.kernels import ops
    cfg = preset("full8", "native")
    x = jax.random.normal(jax.random.PRNGKey(0), (6, 32)) * 0.5
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16)) * 0.15
    xq = cfg.a.make().quantize(x)
    monkeypatch.setattr(ops, "_on_tpu", lambda: True)
    jaxpr = jax.make_jaxpr(
        lambda a, b: qdense_requant(cfg, a, b, 2.0 ** -7))(xq, w)
    prims = []
    _collect_outside_pallas(jaxpr.jaxpr, prims)
    names = [n for n, _ in prims]
    # weight-payload quantize + ONE fused matmul-with-epilogue kernel
    assert names.count("pallas_call") == 2, names
    assert "reduce_max" not in names, names
    offenders = [(n, s) for n, s in prims
                 if n in ("round", "clamp") and s not in (None, ())]
    assert not offenders, offenders
