"""Quantized ops: sim/native agreement, backward quantization semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import preset, qact, qdense, qeinsum, qweight
from repro.core import qfuncs as qf


@pytest.fixture(scope="module")
def data():
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (6, 32)) * 0.5
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16)) * 0.15
    return x, w


def test_sim_native_forward_exact(data):
    x, w = data
    xq = qact(preset("full8", "sim"), "relu", x)
    ys = qdense(preset("full8", "sim"), xq, w)
    yn = qdense(preset("full8", "native"), xq, w)
    np.testing.assert_array_equal(np.asarray(ys), np.asarray(yn))


@pytest.mark.parametrize("name", ["full8", "e2_16"])
def test_sim_native_grads_close(data, name):
    x, w = data
    def loss(cfg, w):
        return jnp.sum(qdense(cfg, qact(cfg, "relu", x), w) ** 2)
    gs = jax.grad(lambda w: loss(preset(name, "sim"), w))(w)
    gn = jax.grad(lambda w: loss(preset(name, "native"), w))(w)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(gn),
                               rtol=1e-4, atol=1e-5)


def test_fp32_matches_plain_autodiff(data):
    x, w = data
    cfg = preset("fp32")
    def f(w):
        return jnp.sum(qdense(cfg, jax.nn.relu(x), w) ** 2)
    def ref(w):
        return jnp.sum((jax.nn.relu(x) @ w) ** 2)
    np.testing.assert_allclose(np.asarray(jax.grad(f)(w)),
                               np.asarray(jax.grad(ref)(w)), rtol=1e-6)


def test_backward_errors_are_quantized(data):
    """dL/dx of a sim-mode qdense must lie on the Q_E2 grid composed with
    the weight matmul — check the error entering the matmul was flagged."""
    x, w = data
    cfg = preset("full8", "sim")
    xq = qact(cfg, "relu", x)
    wq = qf.q_clip(w, 8)
    g = jax.random.normal(jax.random.PRNGKey(2), (6, 16))
    # manually: eq = flag_qe2(g); dx = eq @ wq.T
    want = qf.flag_qe2(g, 8) @ wq.T
    _, vjp = jax.vjp(lambda t: qeinsum(cfg, "mk,kn->mn", "default", True, t, wq),
                     xq)
    got = vjp(g)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-6)


def test_qact_backward_applies_qe1(data):
    x, _ = data
    cfg = preset("full8", "sim")
    g = jax.random.normal(jax.random.PRNGKey(3), x.shape) * 1e-3
    _, vjp = jax.vjp(lambda t: qact(cfg, "relu", t), x)
    got = vjp(g)[0]
    want = qf.sq(g, 8) * (x > 0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-9)


def test_qweight_ste(data):
    _, w = data
    cfg = preset("full8", "sim")
    g = jax.grad(lambda t: jnp.sum(qweight(cfg, t)))(w)
    assert jnp.allclose(g, 1.0)


def test_qeinsum_batched_spec():
    cfg = preset("full8", "sim")
    a = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 8, 4)) * 0.3
    b = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 8, 4)) * 0.3
    y = qeinsum(cfg, "bskd,btkd->bskt", "sq8", False, a, b)
    assert y.shape == (2, 3, 8, 5)
    g = jax.grad(lambda a: jnp.sum(
        qeinsum(cfg, "bskd,btkd->bskt", "sq8", False, a, b) ** 2))(a)
    assert g.shape == a.shape and not bool(jnp.isnan(g).any())


def test_native_int8_residuals():
    """Native qeinsum saves int8 QTensor residuals (the 4x memory win)."""
    cfg = preset("full8", "native")
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16)) * 0.3
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 8)) * 0.1
    from repro.core.qdense import _qeinsum_fwd
    _, res = _qeinsum_fwd(cfg, "mk,kn->mn", "default", True, "arr", "arr",
                          x, qf.q_clip(w, 8))
    qa, qb = res
    assert qa.data.dtype == jnp.int8 and qb.data.dtype == jnp.int8
    assert qa.carrier is None and qb.carrier is None
