"""Sharded DP×TP training: bit-exactness + integer-wire acceptance suite.

The headline contract (DESIGN.md §9): the sharded step is parameterized by
`n_shards` (quantization granularity), NOT by the device layout — so with
the global batch fixed, training on 1 device and on 8 simulated host
devices produces bit-identical quantized weights, because per-virtual-shard
payload rounding happens against a globally pmax'ed pow2 scale and every
cross-device gradient reduction is an exact integer sum.

All multi-device tests run in subprocesses: the virtual device count must
be set via XLA_FLAGS before jax initializes.
"""
import os
import subprocess
import sys
import textwrap

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(prog: str, timeout: int = 1500) -> str:
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=timeout, env=env, cwd=_ROOT)
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-4000:]
    return r.stdout


_PRELUDE = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ArchConfig
    from repro.core import preset
    from repro.data import TokenTask, ImageTask
    from repro.launch import shard as S
    from repro.launch.mesh import make_cpu_mesh
    from repro.launch.train import make_sharded_train_step, make_train_step
    from repro.models import build_model
    from repro.optim import init_momentum

    ARCHS = {
      "lm": ArchConfig(name="t-lm", family="lm", n_layers=2, d_model=32,
                       n_heads=2, n_kv=2, d_ff=64, vocab=64, head_dim=16,
                       q_chunk=16, kv_chunk=16),
      "moe": ArchConfig(name="t-moe", family="moe", n_layers=2, d_model=32,
                        n_heads=2, n_kv=2, d_ff=48, vocab=64, head_dim=16,
                        q_chunk=16, kv_chunk=16, moe_experts=4, moe_topk=2),
      "resnet": ArchConfig(name="t-rn", family="resnet", block="basic",
                           stage_sizes=(1,), num_classes=10, img_size=16),
    }

    def task_for(name, a, batch=8):
        if name == "resnet":
            return ImageTask(img_size=a.img_size,
                             num_classes=a.num_classes, global_batch=batch)
        return TokenTask(vocab=a.vocab, seq_len=16, global_batch=batch)

    def train(name, pname, dp, tp=1, steps=2, n_shards=8, **kw):
        a = ARCHS[name]
        mesh = make_cpu_mesh(dp, tp)
        qcfg = preset(pname, "native")
        model = build_model(a, qcfg, tp_size=tp)
        params = model.init(jax.random.PRNGKey(0))
        opt = (S.zero_init_momentum(params, dp)
               if kw.get("opt_shard") == "zero1" else init_momentum(params))
        step_raw, specs = make_sharded_train_step(
            model, qcfg, model.labels(params), mesh, params,
            n_shards=n_shards, **kw)
        step = jax.jit(step_raw)
        params = S.shard_arrays(mesh, params, specs["params"])
        opt = S.shard_arrays(mesh, opt, specs["opt"])
        task = task_for(name, a)
        losses = []
        for s in range(steps):
            batch = S.put_batch(mesh, task.batch(s))
            params, opt, m = step(params, opt, batch, jnp.int32(s))
            losses.append(float(m["loss"]))
        return jax.device_get(params), jax.device_get(opt), losses

    def diff(pa, pb):
        return [jax.tree_util.keystr(p) for (p, a), (_, b) in
                zip(jax.tree_util.tree_leaves_with_path(pa),
                    jax.tree_util.tree_leaves_with_path(pb))
                if not np.array_equal(np.asarray(a), np.asarray(b))]
""")


_SWEEP_PROG = _PRELUDE + textwrap.dedent("""
    # DP-invariance sweep: 1 device vs 8 simulated host devices, bitwise on
    # EVERY param leaf AND the Momentum accumulator, per family x preset.
    for name in ("lm", "moe", "resnet"):
        for pname in ("full8", "e2_16"):
            p1, o1, _ = train(name, pname, dp=1)
            p8, o8, _ = train(name, pname, dp=8)
            bad = diff(p1, p8) + diff(o1.acc, o8.acc)
            assert not bad, (name, pname, bad)
            print("OK", name, pname)
    # an intermediate layout (dp=2, 4 virtual shards per device)
    p1, o1, _ = train("lm", "full8", dp=1)
    p2, o2, _ = train("lm", "full8", dp=2)
    assert not (diff(p1, p2) + diff(o1.acc, o2.acc))
    print("OK lm dp2")
    # int8 wire: coarser grid, same invariance
    pa, _, _ = train("lm", "full8", dp=1, wire_bits=8)
    pb, _, _ = train("lm", "full8", dp=8, wire_bits=8)
    assert not diff(pa, pb)
    print("OK lm wire8")
    # packed whole-tree codec == per-leaf codec, bitwise (params AND the
    # Momentum accumulator), at the 16-bit and the packed 8-bit wire
    pc, oc, _ = train("lm", "full8", dp=4)
    pd, od, _ = train("lm", "full8", dp=4, wire_codec="leaf")
    assert not (diff(pc, pd) + diff(oc.acc, od.acc))
    pe, _, _ = train("lm", "full8", dp=2, wire_bits=8)
    pf, _, _ = train("lm", "full8", dp=2, wire_bits=8, wire_codec="leaf")
    assert not diff(pe, pf)
    print("OK codec packed==leaf")
    print("SWEEP_OK")
""")


_TP_ZERO1_PROG = _PRELUDE + textwrap.dedent("""
    # manual TP: same n_shards, dp varies with tp=2 fixed -> still bitwise
    pa, oa, la = train("lm", "full8", dp=1, tp=2)
    pb, ob, lb = train("lm", "full8", dp=4, tp=2)
    assert not (diff(pa, pb) + diff(oa.acc, ob.acc))
    assert np.isfinite(la).all()
    print("OK tp2 dp-invariance")

    # ZeRO-1: accumulator sharded as flat chunks; updates are elementwise,
    # so the result is bitwise identical to the replicated optimizer (the
    # gradient quantization runs on the full leaf before chunking)
    pr, _, _ = train("lm", "full8", dp=1)
    pz, _, _ = train("lm", "full8", dp=2, opt_shard="zero1")
    assert not diff(pr, pz)
    print("OK zero1")
    print("TPZ_OK")
""")


_LOSS_CURVE_PROG = _PRELUDE + textwrap.dedent("""
    # Sharded-vs-unsharded 5-step loss curves.  NOT bitwise: the sharded
    # algorithm quantizes at per-virtual-shard amax granularity and syncs
    # on the integer wire — but the curves must track closely and train.
    a = ARCHS["lm"]
    qcfg = preset("full8", "native")
    model = build_model(a, qcfg)
    params0 = model.init(jax.random.PRNGKey(0))
    labels = model.labels(params0)
    task = task_for("lm", a)

    step_u = jax.jit(make_train_step(model, qcfg, labels))
    p, o = params0, init_momentum(params0)
    unsharded = []
    for s in range(5):
        batch = jax.tree.map(jnp.asarray, task.batch(s))
        p, o, m = step_u(p, o, batch, jnp.int32(s))
        unsharded.append(float(m["loss"]))

    _, _, sharded = train("lm", "full8", dp=4, steps=5, n_shards=4)
    deltas = [abs(x - y) for x, y in zip(unsharded, sharded)]
    assert max(deltas) < 0.15, (unsharded, sharded)
    assert sharded[-1] < sharded[0] + 0.05, sharded
    print("LOSS_OK", max(deltas))
""")


_JAXPR_PROG = _PRELUDE + textwrap.dedent("""
    # Integer-wire acceptance on the traced step: gradients cross devices
    # as integer payloads ONLY.  With the packed codec, float collectives
    # are ONE 1-D pmax (every leaf's wire-scale amax, stacked) plus the
    # scalar loss-metric mean; the leaf codec keeps every float collective
    # scalar.  Everything tensor-shaped on the wire (ppermute hops,
    # all_gathers) must be integer dtype.  The f32 "psum" baseline is the
    # positive control for the detector.
    from repro.kernels import ops

    def trace(grad_sync, wire_codec="packed", wire_bits=16):
        a = ARCHS["lm"]
        mesh = make_cpu_mesh(4, 1)
        qcfg = preset("full8", "native")
        model = build_model(a, qcfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = init_momentum(params)
        step_raw, _ = make_sharded_train_step(
            model, qcfg, model.labels(params), mesh, params, n_shards=8,
            grad_sync=grad_sync, wire_codec=wire_codec,
            wire_bits=wire_bits)
        batch = jax.tree.map(jnp.asarray, task_for("lm", a).batch(0))
        jx = jax.make_jaxpr(step_raw)(params, opt, batch, jnp.int32(0))
        return jx, params

    jx, params = trace("int_ring")
    n_leaves = len(jax.tree.leaves(params))
    colls = ops.collective_eqns(jx.jaxpr)
    assert colls, "no collectives found — detector broken?"
    floats = [c for c in colls if c[2] is not None
              and jnp.issubdtype(c[2], jnp.floating)]
    vec = [c for c in floats if c[1] != ()]
    assert len(vec) == 1 and vec[0][0] == "pmax" \\
        and vec[0][1] == (n_leaves,), vec
    wires = [c for c in colls if c[0] in ("ppermute", "all_gather")]
    assert wires and all(jnp.issubdtype(c[2], jnp.integer) for c in wires), \\
        wires
    assert any(c[0] == "ppermute" and c[2] == jnp.int16 for c in colls)

    # leaf codec: per-leaf sync keeps every float collective SCALAR, and
    # rings once per leaf where the packed codec rings once per step with
    # two double-buffered messages
    jl, _ = trace("int_ring", wire_codec="leaf")
    lc = ops.collective_eqns(jl.jaxpr)
    lf = [c for c in lc if c[2] is not None
          and jnp.issubdtype(c[2], jnp.floating)]
    assert all(c[1] == () for c in lf), [c for c in lf if c[1] != ()]
    pp = sum(1 for c in colls if c[0] == "ppermute")
    pl = sum(1 for c in lc if c[0] == "ppermute")
    assert (pp, pl) == (2, n_leaves), (pp, pl)

    # wire-bits=8: the packed hops ride two-per-int16 — exactly half the
    # on-wire elements of the per-leaf int8 hops — and the fused pre-sum
    # never materializes a per-virtual-shard int8 payload tensor (the
    # leaf codec does: positive control for the detector)
    vs = 8 // 4
    leaf_shapes = {(vs,) + np.shape(l) for l in jax.tree.leaves(params)}
    def int8_vs_tensors(j):
        return [e for e in ops.eqns_outside_pallas(j.jaxpr)
                if e[2] is not None and e[2] == jnp.int8
                and e[1] in leaf_shapes]
    def hop_elems(j):
        return sum(int(np.prod(c[1])) for c in ops.collective_eqns(j.jaxpr)
                   if c[0] == "ppermute")
    j8p, _ = trace("int_ring", wire_bits=8)
    j8l, _ = trace("int_ring", wire_codec="leaf", wire_bits=8)
    assert not int8_vs_tensors(j8p), int8_vs_tensors(j8p)[:4]
    assert int8_vs_tensors(j8l), "positive control lost its payload tensors"
    hp, hl = hop_elems(j8p), hop_elems(j8l)
    assert hp * 2 == hl, (hp, hl)

    # positive control: the f32-wire baseline DOES all-reduce float tensors
    base, _ = trace("psum")
    bc = ops.collective_eqns(base.jaxpr)
    assert any(c[0] == "psum" and c[1] != ()
               and jnp.issubdtype(c[2], jnp.floating) for c in bc)
    print("JAXPR_OK")
""")


_SUBBIT_SWEEP_PROG = _PRELUDE + textwrap.dedent("""
    # Sub-8 lanes (DESIGN.md §14) ride the same DP-invariance contract:
    # W4A8 / A4 / G16 sharded steps are bitwise layout-independent, and the
    # 4-bit wire's staged int16 hops (compress.wire_plan — n_shards=8
    # fan-in past the classic 4-bit bound) keep the exact-integer-sum
    # guarantee.
    for pname in ("w4a8", "a4", "g16"):
        p1, o1, _ = train("lm", pname, dp=1)
        p2, o2, _ = train("lm", pname, dp=2)
        bad = diff(p1, p2) + diff(o1.acc, o2.acc)
        assert not bad, (pname, bad)
        print("OK lm", pname)
    p1, o1, _ = train("resnet", "w4a8", dp=1)
    p2, o2, _ = train("resnet", "w4a8", dp=2)
    assert not (diff(p1, p2) + diff(o1.acc, o2.acc))
    print("OK resnet w4a8")
    # staged 4-bit wire: hops ride int16, payloads keep full 4-bit
    # resolution; packed and leaf codecs stay bitwise-identical to each
    # other AND to the single-device run
    pa, _, _ = train("lm", "full8", dp=1, wire_bits=4)
    pb, _, _ = train("lm", "full8", dp=2, wire_bits=4)
    assert not diff(pa, pb)
    pc, _, _ = train("lm", "w4a8", dp=1, wire_bits=4)
    pd, _, _ = train("lm", "w4a8", dp=2, wire_bits=4, wire_codec="leaf")
    assert not diff(pc, pd)
    print("OK wire4 staged")
    print("SUBBIT_OK")
""")


def test_dp_invariance_sweep():
    """1-dev vs 8-dev bit-exactness: full8 x e2_16 over lm/moe/resnet, plus
    the dp=2 mixed layout and the int8 wire."""
    out = _run(_SWEEP_PROG)
    assert "SWEEP_OK" in out, out


def test_subbit_dp_invariance_sweep():
    """W4A8/A4/G16 bitwise dp in {1,2}; staged 4-bit wire keeps the
    contract under both codecs."""
    out = _run(_SUBBIT_SWEEP_PROG)
    assert "SUBBIT_OK" in out, out


def test_tp_and_zero1_bitexact():
    """Manual TP keeps DP-invariance; ZeRO-1 == replicated optimizer."""
    out = _run(_TP_ZERO1_PROG)
    assert "TPZ_OK" in out, out


def test_sharded_vs_unsharded_loss_curves():
    out = _run(_LOSS_CURVE_PROG)
    assert "LOSS_OK" in out, out


def test_sharded_backward_integer_wire_only():
    out = _run(_JAXPR_PROG)
    assert "JAXPR_OK" in out, out


def test_shard_spec_rules_single_process():
    """Spec rules are pure metadata — no devices needed."""
    import jax
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.configs.base import ArchConfig
    from repro.core import preset
    from repro.launch.shard import (tp_param_specs, zero_chunk_len,
                                    zero_init_momentum)
    from repro.models import build_model

    a = ArchConfig(name="t-lm", family="lm", n_layers=2, d_model=32,
                   n_heads=2, n_kv=2, d_ff=64, vocab=64, head_dim=16,
                   q_chunk=16, kv_chunk=16)
    qcfg = preset("full8", "native")
    model = build_model(a, qcfg, tp_size=2)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = tp_param_specs(model, params)
    assert specs["layers"]["wq"] == P(None, None, "model")
    assert specs["layers"]["wo"] == P(None, "model", None)
    assert specs["layers"]["w_down"] == P(None, "model", None)
    assert specs["embed"] == P() and specs["final_norm"] == P()
    # tp_size=1 -> everything replicated
    m1 = build_model(a, qcfg)
    assert all(s == P() for s in
               jax.tree.leaves(tp_param_specs(m1, params),
                               is_leaf=lambda x: isinstance(x, P)))
    # indivisible heads refuse manual TP
    import pytest
    with pytest.raises(ValueError):
        build_model(a, qcfg, tp_size=3)
    # ZeRO accumulator layout: flat, padded to dp equal chunks
    params_c = jax.tree.map(lambda s: np.zeros(s.shape, s.dtype), params)
    st = zero_init_momentum(params_c, dp=4)
    for p, acc in zip(jax.tree.leaves(params_c), jax.tree.leaves(st.acc)):
        assert acc.shape == (4 * zero_chunk_len(p.size, 4),)
