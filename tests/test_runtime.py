"""Runtime: fault-tolerant runner, watchdog, int16 gradient compression."""
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.runtime import SimulatedFailure, StepWatchdog, TrainRunner


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(factor=3.0, warmup=3)
    for s in range(6):
        wd.observe(s, 0.1)
    assert not wd.flags
    assert wd.observe(6, 1.0)           # 10x median
    assert wd.flags == [6]


def test_watchdog_window_bounds_history_and_reset():
    """The timing history is a rolling window (long runs don't grow memory
    or freeze the median on ancient steps) and reset() clears the stats
    for a legitimately-changed baseline (elastic reshard)."""
    wd = StepWatchdog(factor=3.0, warmup=3, window=8)
    for s in range(100):
        wd.observe(s, 0.1)
    assert len(wd.times) == 8
    # the median follows the window: once half the window runs at the new
    # 1.0s pace it becomes the baseline and stops flagging — an unbounded
    # history would keep judging against the ancient 0.1s median forever
    for s in range(100, 108):
        wd.observe(s, 1.0)
    assert not wd.observe(108, 1.0)
    assert wd.flags == [100, 101, 102, 103]
    wd.reset()
    assert wd.times == [] and wd.flags == []
    assert not wd.observe(0, 50.0)          # back in warmup after reset


def test_runner_restores_after_injected_failure(tmp_path):
    """Crash at step 7 -> restore from step 5 checkpoint -> same final state
    as an uninterrupted run (deterministic resume)."""
    def step_fn(state, step):
        return state + step, {"s": step}

    cm1 = CheckpointManager(str(tmp_path / "a"), async_write=False)
    r1 = TrainRunner(step_fn, cm1, save_every=5)
    ref, _ = r1.run(jnp.float32(0.0), 10)

    cm2 = CheckpointManager(str(tmp_path / "b"), async_write=False)
    r2 = TrainRunner(step_fn, cm2, save_every=5)
    got, _ = r2.run(jnp.float32(0.0), 10, fail_at=7)
    assert r2.restarts == 1
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_runner_gives_up_after_max_restarts(tmp_path):
    def bad(state, step):
        raise SimulatedFailure("always")
    cm = CheckpointManager(str(tmp_path), async_write=False)
    r = TrainRunner(bad, cm, save_every=5, max_restarts=2)
    try:
        r.run(jnp.float32(0.0), 3)
        assert False, "should raise"
    except SimulatedFailure:
        assert r.restarts == 3


_COMPRESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_cpu_mesh
    from repro.runtime import compressed_psum_int, ring_reduce_scatter_int
    mesh = jax.make_mesh((8,), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 1e-3
    got = compressed_psum_int(x, mesh, "data", bits=16)
    # every device contributed the same x -> mean == x (up to int16 grid)
    err = float(jnp.abs(got - x).max() / (jnp.abs(x).max()))
    assert err < 2e-3, err
    rs = ring_reduce_scatter_int(x.reshape(-1), mesh, "data", bits=16)
    assert rs.shape == x.reshape(-1).shape  # global logical shape
    err2 = float(jnp.abs(rs - x.reshape(-1)).max() / jnp.abs(x).max())
    assert err2 < 2e-3, err2
    print("COMPRESS_OK")
""")


def test_compressed_collectives_8dev():
    """int16-wire ring reduce over 8 virtual devices (subprocess: device
    count must be set before jax init)."""
    import os
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", _COMPRESS_PROG],
                       capture_output=True, text=True, timeout=300, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "COMPRESS_OK" in r.stdout, r.stdout + r.stderr
