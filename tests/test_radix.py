"""Prefix-sharing radix cache + chunked prefill (DESIGN.md §10): pool
refcount invariants, radix lookup/insert/eviction/dedup semantics,
bounded-skip admission, bitwise cache-on/off exactness across families
(including after preemption-recompute), and a refcount+defrag chaos run."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import (Engine, PagePool, RadixCache, RequestState,
                           Scheduler, make_engine, shared_prefix_traffic)


def _pool(n_pages=17, page_size=4):
    return PagePool(n_pages, page_size, kv_layers=2, n_kv=2, dh=4)


# --------------------------------------------------------------------------
# PagePool refcounts
# --------------------------------------------------------------------------


def test_pool_refcount_lifecycle():
    pool = _pool()
    (pid,) = pool.alloc(1, owner="a")
    assert pool.refcount(pid) == 1
    pool.ref(pid)
    pool.ref(pid)
    assert pool.refcount(pid) == 3
    with pytest.raises(ValueError, match="shared page"):
        pool.free([pid])                   # strict free refuses shared pages
    assert not pool.unref(pid) and not pool.unref(pid)
    assert pool.refcount(pid) == 1
    assert pool.free_count == pool.usable - 1
    assert pool.unref(pid)                 # last holder frees it
    assert pool.refcount(pid) == 0 and pool.free_count == pool.usable
    with pytest.raises(ValueError):
        pool.unref(pid)                    # already free
    with pytest.raises(ValueError):
        pool.ref(pid)
    with pytest.raises(ValueError):
        pool.ref(0)                        # the trash page is never refable
    b = pool.alloc(2, owner="b")
    pool.ref(b[0])
    assert pool.report()["shared_pages"] == 1


def test_pool_defrag_remaps_shared_pages_exactly_once():
    pool = _pool(n_pages=9)
    a = pool.alloc(2, owner="a")
    b = pool.alloc(2, owner="b")
    c = pool.alloc(2, owner="c")
    pool.ref(c[0])                         # c[0] shared by two holders
    pool.ref(c[0])
    for pid in a + b + c:
        pool.k = pool.k.at[:, pid].set(jnp.int8(pid))
    pool.free(a)
    mapping = pool.defrag()
    # one mapping entry per physical page regardless of holders
    assert len(mapping) == len(set(mapping.values()))
    new_c0 = mapping.get(c[0], c[0])
    assert pool.refcount(new_c0) == 3      # refcounts follow the move
    np.testing.assert_array_equal(np.asarray(pool.k[:, new_c0]),
                                  np.full((2, 4, 2, 4), c[0], np.int8))
    pool.unref(new_c0)
    pool.unref(new_c0)
    assert pool.refcount(new_c0) == 1
    pool.free([new_c0])                    # exclusive again: strict free ok


# --------------------------------------------------------------------------
# RadixCache
# --------------------------------------------------------------------------


def _publish(cache, pool, prompt, owner="pub"):
    """Alloc + insert a prompt's full pages; returns the page ids, with the
    publisher's own holds dropped (tree-only pages, as after release)."""
    nb = len(prompt) // pool.page_size
    pids = pool.alloc(nb, owner=owner)
    cache.insert(prompt, pids)
    for p in pids:
        pool.unref(p)                      # publisher exits; tree ref stays
    return pids


def test_radix_lookup_match_limit_and_hit_accounting():
    pool = _pool(page_size=4)
    cache = RadixCache(pool, quant_key="t")
    prompt = np.arange(12, dtype=np.int32)            # 3 full pages
    pids = _publish(cache, pool, prompt)
    assert cache.n_nodes == 3 and pool.in_use == 3
    # aligned identical prompt: the last page stays uncached (the engine
    # must compute the final prompt token to sample from)
    assert cache.match_pages(prompt) == 2
    hit, dense = cache.lookup(prompt)
    assert hit == pids[:2] and dense is None
    # extension past the prefix may reuse every published page
    ext = np.concatenate([prompt, np.int32([99, 98])])
    assert cache.match_pages(ext) == 3
    assert cache.lookup(ext)[0] == pids
    # divergence in page 2 stops the walk
    div = prompt.copy()
    div[5] = 77
    assert cache.match_pages(div) == 1
    assert 0.0 < cache.hit_rate <= 1.0
    # sub-page prompts never match (page-granular keys)
    assert cache.match_pages(np.arange(3, dtype=np.int32)) == 0


def test_radix_insert_dedup_reports_existing_pages():
    pool = _pool(page_size=4)
    cache = RadixCache(pool, quant_key="t")
    prompt = np.arange(8, dtype=np.int32)
    first = _publish(cache, pool, prompt)
    dup = pool.alloc(2, owner="dup")       # concurrent identical prefill
    dedup = cache.insert(prompt, dup)
    assert dedup == {0: first[0], 1: first[1]}
    assert cache.deduped_pages == 2
    assert cache.n_nodes == 2              # no duplicate nodes


def test_radix_eviction_lru_and_request_pinning():
    pool = _pool(n_pages=17, page_size=4)
    cache = RadixCache(pool, quant_key="t")
    old = _publish(cache, pool, np.arange(0, 8, dtype=np.int32))
    hot = _publish(cache, pool, np.arange(50, 58, dtype=np.int32))
    assert cache.evictable() == 4
    # a request commits to `hot`: its refs pin that chain against eviction
    pids, _ = cache.lookup(np.concatenate(
        [np.arange(50, 58, dtype=np.int32), np.int32([1])]))
    for p in pids:
        pool.ref(p)
    assert pids == hot and cache.evictable() == 2
    assert cache.evict(10) == 2            # only the old chain drains
    assert cache.n_nodes == 2 and pool.in_use == 2
    assert all(pool.refcount(p) == 2 for p in hot)
    for p in pids:                         # request exits; tree-only again
        pool.unref(p)
    assert cache.clear() == 2
    assert pool.in_use == 0 and cache.n_nodes == 0


def test_radix_remap_tracks_pool_defrag():
    pool = _pool(n_pages=17, page_size=4)
    cache = RadixCache(pool, quant_key="t")
    gap = pool.alloc(3, owner="gap")
    prompt = np.arange(8, dtype=np.int32)
    _publish(cache, pool, prompt)
    pool.free(gap)                         # holes below the tree's pages
    mapping = pool.defrag()
    assert mapping
    cache.remap(mapping)
    hit, _ = cache.lookup(np.concatenate([prompt, np.int32([5])]))
    assert hit and all(pool.refcount(p) == 1 for p in hit)


# --------------------------------------------------------------------------
# bounded-skip admission
# --------------------------------------------------------------------------


def test_scheduler_bounded_skip_and_starvation_limit():
    pool = _pool(n_pages=9, page_size=4)   # 8 usable pages
    sched = Scheduler(pool, max_skip=4, starvation_limit=3)
    big = sched.submit(np.arange(28), 2, 0.0)      # needs 8 pages
    small = [sched.submit(np.arange(4), 2, 0.0) for _ in range(6)]
    held = pool.alloc(4, owner="x")        # big can't fit: 4 pages free
    # small requests jump the stuck head, one lane at a time
    for i in range(3):
        wave = sched.admit(1)
        assert [r.rid for r in wave] == [small[i].rid]
        assert big.skipped == i + 1
    # starvation limit reached: the head becomes a barrier
    assert sched.admit(1) == []
    assert big.skipped == 3 and sched.skips == 3
    pool.free(held)                        # capacity appears: head admits
    wave = sched.admit(2)
    assert [r.rid for r in wave] == [big.rid]
    # strict FIFO when max_skip=0
    sched0 = Scheduler(pool, max_skip=0)
    pool2 = pool.alloc(4, owner="y")
    blocked = sched0.submit(np.arange(28), 2, 0.0)
    sched0.submit(np.arange(4), 2, 0.0)
    assert sched0.admit(2) == [] and blocked.skipped == 0
    pool.free(pool2)


def test_scheduler_preempt_resets_chunked_progress():
    sched = Scheduler()
    req = sched.submit(np.arange(8), 4, 0.0)
    req.state = RequestState.DECODE
    req.generated = [1, 2]
    req.pf_pos, req.n_shared, req.page_snaps = 8, 1, [object()]
    sched.preempt(req)
    assert req.pf_pos == 0 and req.n_shared == 0 and req.page_snaps == []
    assert list(req.prompt) == list(np.arange(8)) + [1, 2]


# --------------------------------------------------------------------------
# chunked prefill + radix cache: bitwise exactness
# --------------------------------------------------------------------------


def _chunked(arch, radix, **kw):
    return make_engine(arch, mode="native", max_lanes=1, page_size=4,
                       max_ctx=32, prefill_mode="chunked", prefill_chunk=2,
                       radix_cache=radix, **kw)


def _serve_sequential(eng, prompts, max_new=5):
    out = []
    for p in prompts:
        rid = eng.submit(p, max_new)
        out.append(eng.drain()[rid])
    return out


SHARED = np.arange(20, 29, dtype=np.int32)           # 2 full pages + tail
PROMPTS = [SHARED,
           np.concatenate([SHARED, np.int32([3, 1, 4])]),
           np.concatenate([SHARED[:8], np.int32([9, 9])]),
           np.arange(40, 48, dtype=np.int32)]        # page-aligned


@pytest.mark.parametrize("arch", ["granite-3-8b", "granite-moe-1b-a400m",
                                  "zamba2-7b"])
def test_chunked_radix_cache_bitwise_exact(arch):
    """Acceptance: greedy outputs with the radix cache on are bit-identical
    to cache off, per family — page-scoped quantization makes cached pages
    (and recurrent-state snapshots) exact in their token prefix."""
    on = _serve_sequential(_chunked(arch, radix=True), PROMPTS)
    off = _serve_sequential(_chunked(arch, radix=False), PROMPTS)
    assert on == off, arch
    # and the cache actually served pages (not a trivially-empty tree)


def test_chunked_radix_hits_serve_shared_prefix():
    eng = _chunked("granite-3-8b", radix=True)
    _serve_sequential(eng, PROMPTS)
    m = eng.metrics()
    assert m["radix"]["hit_pages"] > 0
    assert 0.0 < m["prefix_hit_rate"] <= 1.0
    assert m["queue_ms_mean"] >= 0.0 and m["prefill_ms_mean"] > 0.0
    assert eng.pool.in_use == m["radix"]["nodes"]    # only tree holds remain
    assert eng.radix.clear() == m["radix"]["nodes"]
    assert eng.pool.in_use == 0


@pytest.mark.parametrize("arch", ["granite-3-8b", "zamba2-7b"])
def test_chunked_radix_exact_after_preemption_recompute(arch):
    """Preempt mid-generation in both engines at the same step: the cache-on
    engine re-prefills through radix hits on its own published pages, the
    cache-off engine recomputes everything — tokens must stay identical."""
    outs = {}
    for radix in (True, False):
        eng = _chunked(arch, radix=radix)
        rid = eng.submit(PROMPTS[1], 8)
        for _ in range(3):
            eng.step()
        req = eng.scheduler.requests[rid]
        assert req.state is RequestState.DECODE
        eng._preempt(req)                  # forced recompute preemption
        assert req.preemptions == 1
        outs[radix] = eng.drain()[rid]
        assert len(outs[radix]) == 8
    assert outs[True] == outs[False], arch


def test_chunked_matches_itself_across_budgets():
    """Prefill chunking is pure restructuring: any chunk size / budget
    yields the same tokens (page-scoped numerics don't see the batching)."""
    outs = []
    for chunk, budget in ((1, 4), (2, 8), (3, 64)):
        eng = make_engine("granite-3-8b", mode="native", max_lanes=1,
                          page_size=4, max_ctx=32, prefill_mode="chunked",
                          prefill_chunk=chunk, prefill_budget=budget)
        outs.append(_serve_sequential(eng, PROMPTS[:2]))
    assert outs[0] == outs[1] == outs[2]


def test_chunked_ssm_family_runs_without_pool():
    eng = make_engine("falcon-mamba-7b", mode="native", max_lanes=1,
                      page_size=4, max_ctx=32, prefill_mode="chunked",
                      prefill_chunk=2)
    out = _serve_sequential(eng, PROMPTS[:2])
    assert all(len(g) == 5 for g in out)


def test_radix_cache_flag_validation():
    with pytest.raises(ValueError, match="chunked"):
        make_engine("granite-3-8b", mode="native", radix_cache=True)
    with pytest.raises(ValueError, match="paged"):
        make_engine("falcon-mamba-7b", mode="native",
                    prefill_mode="chunked", radix_cache=True)
    with pytest.raises(ValueError, match="prefill_mode"):
        make_engine("granite-3-8b", mode="native", prefill_mode="bogus")


def test_shared_prefix_traffic_shapes():
    traffic = shared_prefix_traffic(rate=8.0, n_requests=16, sharing=1.0,
                                    prefix_len=8, n_prefixes=1,
                                    tail_lens=(2, 4), gen_lens=(2,), seed=1)
    assert len(traffic) == 16
    heads = {t["prompt"][:8].tobytes() for t in traffic}
    assert len(heads) == 1                 # sharing=1: one common prefix
    assert all(len(t["prompt"]) in (10, 12) for t in traffic)
    mixed = shared_prefix_traffic(rate=8.0, n_requests=16, sharing=0.0,
                                  prefix_len=8, seed=1)
    assert len({t["prompt"][:8].tobytes() for t in mixed}) > 8


# --------------------------------------------------------------------------
# refcount + defrag + eviction chaos
# --------------------------------------------------------------------------


def test_refcount_defrag_eviction_chaos():
    """200 random ops over pool + radix + simulated request holds; after
    every op the refcount ledger must equal tree holds + request holds and
    the free list must stay disjoint from live pages."""
    rng = np.random.default_rng(0)
    pool = _pool(n_pages=33, page_size=4)
    cache = RadixCache(pool, quant_key="chaos")
    requests = {}                          # rid -> page ids it holds
    next_rid = 0

    def tree_holds():
        holds = {}
        stack = [cache.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n is not cache.root:
                holds[n.page] = holds.get(n.page, 0) + 1
        return holds

    def check():
        th = tree_holds()
        rh = {}
        for pids in requests.values():
            for p in pids:
                rh[p] = rh.get(p, 0) + 1
        live = set(th) | set(rh)
        assert pool.in_use == len(live)
        for p in live:
            assert pool.refcount(p) == th.get(p, 0) + rh.get(p, 0), p
            assert p not in pool._free and p != 0

    def random_prompt():
        nb = int(rng.integers(1, 4))
        return rng.integers(0, 8, size=nb * 4).astype(np.int32)

    for op in rng.integers(0, 5, size=200):
        if op == 0:                        # a request prefills + publishes
            prompt = random_prompt()
            hit, _ = cache.lookup(prompt)
            for p in hit:
                pool.ref(p)
            need = len(prompt) // 4 - len(hit)
            fresh = pool.alloc(need, owner=next_rid)
            if fresh is None:
                cache.evict(need)
                fresh = pool.alloc(need, owner=next_rid)
            if fresh is None:              # genuinely full: drop the refs
                for p in hit:
                    pool.unref(p)
            else:
                pids = hit + fresh
                dedup = cache.insert(prompt, pids)
                for blk, cached in dedup.items():
                    pool.ref(cached)
                    pool.unref(pids[blk])
                    pids[blk] = cached
                requests[next_rid] = pids
                next_rid += 1
        elif op == 1 and requests:         # release (finish or preempt)
            rid = int(rng.choice(list(requests)))
            for p in requests.pop(rid):
                pool.unref(p)
        elif op == 2:                      # LRU eviction pressure
            cache.evict(int(rng.integers(1, 4)))
        elif op == 3:                      # defrag + remap every holder
            mapping = pool.defrag()
            cache.remap(mapping)
            for rid, pids in requests.items():
                requests[rid] = [mapping.get(p, p) for p in pids]
        else:                              # probe only
            cache.match_pages(random_prompt())
        check()

    for pids in requests.values():
        for p in pids:
            pool.unref(p)
    cache.clear()
    assert pool.in_use == 0
    assert sorted(pool._free) == list(range(1, pool.n_pages))
