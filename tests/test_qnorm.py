"""Quantized norm layers vs the paper's Eq. 12 recipe."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import preset, qbatchnorm, qlayernorm, qrmsnorm
from repro.core import qfuncs as qf
from repro.core.qnorm import EPS_Q


def test_qbatchnorm_matches_eq12():
    cfg = preset("full8", "sim")
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 4, 4, 8)) * 2 + 0.5
    gamma = jnp.ones((8,)) * 1.25
    beta = jnp.ones((8,)) * 0.125
    y = qbatchnorm(cfg, x, gamma, beta)
    mu = jnp.mean(x, (0, 1, 2))
    sig = jnp.sqrt(jnp.mean(x ** 2, (0, 1, 2)) - mu ** 2)
    xhat = qf.q_direct((x - qf.q_direct(mu, 16)) /
                       (qf.q_direct(sig, 16) + EPS_Q), 16)
    want = qf.q_direct(gamma, 8) * xhat + qf.q_direct(beta, 8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-6)


def test_qbatchnorm_fp32_is_plain_bn():
    cfg = preset("fp32")
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 8)) * 3
    y = qbatchnorm(cfg, x, jnp.ones((8,)), jnp.zeros((8,)))
    assert abs(float(jnp.mean(y))) < 1e-4
    assert abs(float(jnp.std(y)) - 1.0) < 0.05


def test_qrmsnorm_quantized_output_grid():
    cfg = preset("full8", "sim")
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    y = qrmsnorm(cfg, x, jnp.ones((64,)))
    sig = qf.q_direct(jnp.sqrt(jnp.mean(x ** 2, -1, keepdims=True)), 16)
    xhat = y  # gamma = 1 exactly on the 8-bit grid
    n = xhat * 2.0 ** 15 * 0 + (x / (sig + EPS_Q))
    # output must equal Q_BN(x / sigma_q) * Q(gamma)
    want = qf.q_direct(x / (sig + EPS_Q), 16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-6)


def test_norm_grads_flow_and_finite():
    for fn, args in [
        (qrmsnorm, (jnp.ones((64,)),)),
        (qlayernorm, (jnp.ones((64,)), jnp.zeros((64,)))),
    ]:
        cfg = preset("full8", "sim")
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
        grads = jax.grad(
            lambda x, *a: jnp.sum(fn(cfg, x, *a) ** 2), argnums=(0,))(
            x, *args)
        assert not bool(jnp.isnan(grads[0]).any())
        assert float(jnp.abs(grads[0]).max()) > 0


def test_norm_simple_bwd_option():
    cfg = preset("full8", "sim").replace(norm_full_bwd=False)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    g = jax.grad(lambda t: jnp.sum(qrmsnorm(cfg, t, jnp.ones((64,)))))(x)
    assert not bool(jnp.isnan(g).any())


# --------------------------------------------------------------------------
# fused UBN route (native mode): bit-exact vs the sim composition
# --------------------------------------------------------------------------


def _norm_cases():
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 5, 24)) * 0.7
    gamma = jax.random.normal(jax.random.PRNGKey(2), (24,)) * 0.2 + 1.0
    beta = jax.random.normal(jax.random.PRNGKey(3), (24,)) * 0.1
    return [(qrmsnorm, (x, gamma)), (qlayernorm, (x, gamma, beta)),
            (qbatchnorm, (x, gamma, beta))]


def test_native_fused_norm_forward_bit_exact():
    """Native mode routes norms through the fused UBN kernel op; its one-
    pass output must equal the sim/unfused five-stage composition exactly."""
    cfg_n, cfg_s = preset("full8", "native"), preset("full8", "sim")
    cfg_u = cfg_n.replace(fuse_kernels=False)
    for fn, args in _norm_cases():
        yn, ys, yu = fn(cfg_n, *args), fn(cfg_s, *args), fn(cfg_u, *args)
        np.testing.assert_array_equal(np.asarray(yn), np.asarray(ys))
        np.testing.assert_array_equal(np.asarray(yn), np.asarray(yu))


def test_native_fused_norm_grads_bit_exact():
    cfg_n, cfg_u = preset("full8", "native"), \
        preset("full8", "native").replace(fuse_kernels=False)
    for fn, args in _norm_cases():
        x, rest = args[0], args[1:]
        gn = jax.grad(lambda t: jnp.sum(fn(cfg_n, t, *rest) ** 2))(x)
        gu = jax.grad(lambda t: jnp.sum(fn(cfg_u, t, *rest) ** 2))(x)
        np.testing.assert_array_equal(np.asarray(gn), np.asarray(gu))
        # gamma grads too (STE through the direct quantizers)
        gg_n = jax.grad(lambda g: jnp.sum(fn(cfg_n, x, g, *rest[1:]) ** 2))(
            rest[0])
        gg_u = jax.grad(lambda g: jnp.sum(fn(cfg_u, x, g, *rest[1:]) ** 2))(
            rest[0])
        np.testing.assert_array_equal(np.asarray(gg_n), np.asarray(gg_u))


def test_native_fused_norm_jaxpr_single_kernel(monkeypatch):
    """On the kernel route the whole forward is ONE pallas_call: no
    standalone quantize (tensor-shaped round) outside it, and no amax at
    all — every UBN quantizer has a fixed pow2 step."""
    from jaxpr_utils import collect_outside_pallas

    from repro.kernels import ops
    cfg = preset("full8", "native")
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 32))
    gamma = jnp.ones((32,))
    monkeypatch.setattr(ops, "_on_tpu", lambda: True)
    jaxpr = jax.make_jaxpr(lambda t: qrmsnorm(cfg, t, gamma))(x)
    s = str(jaxpr)
    assert s.count("pallas_call") >= 1
    assert "reduce_max" not in s
    prims = []
    collect_outside_pallas(jaxpr.jaxpr, prims)
    assert sum(1 for n, _ in prims if n == "pallas_call") == 1
    assert not [n for n, shp in prims if n == "round" and shp != ()], prims
