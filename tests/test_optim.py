"""Quantized Momentum optimizer (paper Eq. 19-24) invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import preset
from repro.core import qfuncs as qf
from repro.optim import (MomentumState, fixed_point_lr, init_momentum,
                         momentum_update)


def _setup():
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 8)) * 0.1,
              "g": jnp.ones((8,)), "b": jnp.zeros((8,)),
              "e": jax.random.normal(jax.random.PRNGKey(1), (4,))}
    labels = {"w": "w", "g": "gamma", "b": "beta", "e": "exempt"}
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(2), p.shape) * 1e-3,
        params)
    return params, labels, grads


def test_bitwidth_closure():
    cfg = preset("full8")
    cfg.validate()  # Eq. 22 and Eq. 24 asserted inside
    assert cfg.k_wu == cfg.k_gc + cfg.k_lr - 1 == 24


def test_paper_lr_grid():
    cfg = preset("full8")
    assert fixed_point_lr(0.05, cfg) == 0.05078125        # 26 * 2^-9 (§IV-B)
    assert fixed_point_lr(0.05, preset("fp32")) == 0.05


def test_update_on_kwu_grid():
    cfg = preset("full8", "sim")
    params, labels, grads = _setup()
    st = init_momentum(params)
    p2, st2 = momentum_update(cfg, params, grads, st, labels,
                              jax.random.PRNGKey(3), fixed_point_lr(0.05, cfg))
    n = p2["w"] * 2.0 ** 23
    assert bool(jnp.allclose(n, jnp.round(n)))
    lim = 1.0 - 2.0 ** -23
    assert bool(jnp.all(jnp.abs(p2["w"]) <= lim))
    assert int(st2.step) == 1


def test_momentum_recurrence_matches_eq20():
    cfg = preset("full8", "sim").replace(stochastic_g=False)
    params, labels, grads = _setup()
    st = init_momentum(params)
    lr = fixed_point_lr(0.05, cfg)
    p2, st2 = momentum_update(cfg, params, grads, st, labels,
                              jax.random.PRNGKey(3), lr, mom=0.75, dr_bits=8)
    gq = qf.cq(grads["w"], None, 8, 15, stochastic=False)
    acc_full = 0.75 * jnp.zeros_like(gq) + gq
    want = jnp.clip(qf.q_direct(params["w"] - lr * acc_full, 24),
                    -(1 - 2.0 ** -23), 1 - 2.0 ** -23)
    np.testing.assert_allclose(np.asarray(p2["w"]), np.asarray(want),
                               atol=1e-9)
    np.testing.assert_allclose(np.asarray(st2.acc["w"]),
                               np.asarray(qf.q_direct(acc_full, 13)),
                               atol=1e-9)


def test_exempt_leaf_is_vanilla_momentum():
    cfg = preset("full8", "sim")
    params, labels, grads = _setup()
    st = init_momentum(params)
    p2, st2 = momentum_update(cfg, params, grads, st, labels,
                              jax.random.PRNGKey(3), 0.1, mom=0.9)
    want = params["e"] - 0.1 * (0.9 * 0 + grads["e"])
    np.testing.assert_allclose(np.asarray(p2["e"]), np.asarray(want),
                               rtol=1e-6)


def test_fp32_mode_is_vanilla_everywhere():
    cfg = preset("fp32")
    params, labels, grads = _setup()
    st = init_momentum(params)
    p2, _ = momentum_update(cfg, params, grads, st, labels,
                            jax.random.PRNGKey(3), 0.05)
    want = params["w"] - 0.05 * grads["w"]
    np.testing.assert_allclose(np.asarray(p2["w"]), np.asarray(want),
                               rtol=1e-6)


def test_deterministic_given_key():
    cfg = preset("full8", "sim")
    params, labels, grads = _setup()
    st = init_momentum(params)
    a = momentum_update(cfg, params, grads, st, labels,
                        jax.random.PRNGKey(7), 0.05)[0]
    b = momentum_update(cfg, params, grads, st, labels,
                        jax.random.PRNGKey(7), 0.05)[0]
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_dr_schedule():
    from repro.optim import dr_bits_schedule
    assert dr_bits_schedule(0, (100, 200)) == 8
    assert dr_bits_schedule(150, (100, 200)) == 7
    assert dr_bits_schedule(250, (100, 200)) == 6


def test_parse_boundaries():
    from repro.optim import parse_boundaries
    assert parse_boundaries("") == ()
    assert parse_boundaries("200,400") == (200, 400)
    assert parse_boundaries(" 60 , 90 ") == (60, 90)
    # the base follows cfg.k_gw — the G16 lane's schedule starts at 16
    from repro.optim import dr_bits_schedule
    assert dr_bits_schedule(0, (100,), base_bits=16) == 16
    assert dr_bits_schedule(150, (100,), base_bits=16) == 15
    assert dr_bits_schedule(10 ** 9, tuple(range(100)), base_bits=8) == 2


def test_dr_schedule_actually_steps():
    """The --dr-boundaries plumbing contract: dr_bits=None resolves to
    cfg.k_gw (NOT a hardcoded 8), and a scheduled width change really
    alters the quantized gradient — the schedule is not a silent no-op."""
    from repro.optim import quantize_grad_leaf

    g = jax.random.normal(jax.random.PRNGKey(4), (64,)) * 1e-3
    key = jax.random.PRNGKey(5)

    # None == explicit cfg.k_gw, bitwise, for both the 8- and 16-bit bases
    for pname in ("full8", "g16"):
        cfg = preset(pname, "sim")
        np.testing.assert_array_equal(
            np.asarray(quantize_grad_leaf(cfg, g, "w", key)),
            np.asarray(quantize_grad_leaf(cfg, g, "w", key,
                                          dr_bits=cfg.k_gw)))

    # a boundary crossing (dr_bits k -> k-1) changes the CQ output
    cfg = preset("full8", "sim")
    before = np.asarray(quantize_grad_leaf(cfg, g, "w", key, dr_bits=8))
    after = np.asarray(quantize_grad_leaf(cfg, g, "w", key, dr_bits=7))
    assert not np.array_equal(before, after)

    # ...and threads through the full optimizer step the same way
    params, labels, grads = _setup()
    st = init_momentum(params)
    p8 = momentum_update(cfg, params, grads, st, labels,
                         jax.random.PRNGKey(6), 0.05, dr_bits=8)[0]
    pn = momentum_update(cfg, params, grads, st, labels,
                         jax.random.PRNGKey(6), 0.05)[0]
    p7 = momentum_update(cfg, params, grads, st, labels,
                         jax.random.PRNGKey(6), 0.05, dr_bits=7)[0]
    np.testing.assert_array_equal(np.asarray(p8["w"]), np.asarray(pn["w"]))
    assert not np.array_equal(np.asarray(p8["w"]), np.asarray(p7["w"]))
