"""Property tests for the WAGEUBN quantization functions (paper §III-C)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# optional dev dependency: a missing extra must never break suite collection
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import qfuncs as qf

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")


def arrays(min_val=-4.0, max_val=4.0):
    return st.lists(st.floats(min_val, max_val, allow_nan=False,
                              width=32), min_size=1, max_size=64).map(
        lambda v: jnp.asarray(v, jnp.float32))


# ------------------------- direct quantization -------------------------


@given(arrays(), st.integers(2, 16))
def test_q_direct_on_grid(x, k):
    y = qf.q_direct(x, k)
    n = y * 2.0 ** (k - 1)
    assert jnp.allclose(n, jnp.round(n))          # grid membership
    assert jnp.max(jnp.abs(y - x)) <= 2.0 ** -k + 1e-6  # nearest rounding


@given(arrays(), st.integers(2, 16))
def test_q_direct_idempotent(x, k):
    y = qf.q_direct(x, k)
    assert jnp.array_equal(qf.q_direct(y, k), y)


@given(arrays(), st.integers(2, 12))
def test_q_clip_range(x, k):
    y = qf.q_clip(x, k)
    lim = 1.0 - qf.d(k)
    assert jnp.all(jnp.abs(y) <= lim + 1e-9)


# ------------------------- shift quantization -------------------------


@given(arrays(-0.0009765625, 0.0009765625), st.integers(4, 12))
def test_sq_preserves_magnitude_order(x, k):
    """The paper's motivation: tiny errors must not vanish (§IV-A)."""
    y = qf.sq(x, k)
    m = jnp.max(jnp.abs(x))
    if float(m) > 1e-6:
        assert float(jnp.max(jnp.abs(y))) >= float(m) / 4.0


@given(arrays(), st.integers(4, 12))
def test_sq_grid(x, k):
    y = qf.sq(x, k)
    r = qf.pow2_round(qf.amax(x))
    n = y / r * 2.0 ** (k - 1)
    assert jnp.allclose(n, jnp.round(n), atol=1e-4)
    assert jnp.all(jnp.abs(y) <= float(r) * (1 - qf.d(k)) + 1e-9)


def test_pow2_round_zeros():
    assert float(qf.pow2_round(jnp.float32(0.0))) == 1.0
    assert float(qf.pow2_round(jnp.float32(3.0))) in (2.0, 4.0)
    assert float(qf.pow2_round(jnp.float32(0.26))) == 0.25


# ------------------------- flag QE2 (Eq. 17) -------------------------


@given(arrays(-2.0, 2.0))
def test_flag_qe2_two_regimes(x):
    y = qf.flag_qe2(x, 8)
    r = qf.pow2_round(qf.amax(x))
    sc = float(r) / 128.0
    n_big = y / sc
    n_small = y / (sc / 128.0)
    on_big = jnp.abs(n_big - jnp.round(n_big)) < 1e-3
    on_small = jnp.abs(n_small - jnp.round(n_small)) < 1e-3
    assert bool(jnp.all(on_big | on_small))


def test_flag_qe2_covers_15bit_range():
    """9-bit flag format covers ~ the range of direct 15-bit (paper Fig.4)."""
    x = jnp.asarray([1.0, 2.0 ** -14, 2.0 ** -7, 0.9], jnp.float32)
    y = qf.flag_qe2(x, 8)
    # smallest magnitude representable: Sc/128 = R/128/128 ~ 2^-14 * R
    assert float(jnp.abs(y[1])) > 0.0          # not flushed to zero
    rel = jnp.abs(y - x) / jnp.maximum(jnp.abs(x), 1e-9)
    assert float(rel.max()) < 0.5


def test_flag_vs_sq8_small_value_coverage():
    """Fig. 10: 8-bit SQ flushes small errors; flag keeps them."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4096,)) * jnp.exp(
        jax.random.normal(jax.random.PRNGKey(1), (4096,)) * 3)
    sq8 = qf.sq(x, 8)
    fl8 = qf.flag_qe2(x, 8)
    ratio_sq = float(jnp.mean(sq8 != 0))
    ratio_fl = float(jnp.mean(fl8 != 0))
    assert ratio_fl > ratio_sq                 # flag covers more data


# ------------------------- constant quantization -------------------------


@given(arrays(), st.integers(3, 8))
def test_cq_range_and_grid(x, dr_bits):
    y = qf.cq(x, jax.random.PRNGKey(0), dr_bits, 15)
    dr = 2.0 ** (dr_bits - 1)
    n = y * 2.0 ** 14
    assert jnp.allclose(n, jnp.round(n), atol=1e-3)
    assert jnp.all(jnp.abs(n) <= dr - 1 + 1e-6)


def test_cq_stochastic_unbiased():
    # pin R(x)=1 with a sentinel so dr*n stays inside the clip range
    x = jnp.full((20001,), 0.3 * 2.0 ** -8).at[0].set(1.0)
    ys = qf.cq(x, jax.random.PRNGKey(3), 8, 15)[1:]
    want = float(x[1] * 128 / 2 ** 14)        # E[y] = x/R * dr / 2^(kgc-1)
    got = float(jnp.mean(ys))
    assert abs(got - want) < 0.1 * abs(want)


def test_stochastic_round_exact_on_integers():
    x = jnp.asarray([1.0, -3.0, 7.0])
    y = qf.stochastic_round(x, jax.random.PRNGKey(0))
    assert jnp.array_equal(x, y)


# ------------------------- STE -------------------------


def test_ste_gradient_is_identity():
    g = jax.grad(lambda x: jnp.sum(qf.ste(lambda t: qf.q_direct(t, 4), x)))(
        jnp.linspace(-1, 1, 16))
    assert jnp.allclose(g, 1.0)


# ------------------------- int decomposition -------------------------


@given(arrays(-0.998046875, 0.998046875), st.integers(4, 8))
def test_dec_int8_lossless_on_grid(x, k):
    """Exact up to one step of the (possibly finer) re-derived grid: when
    the quantized amax falls below half the original scale, dec_int8 picks
    a finer step whose top code saturates by <= 1 ulp."""
    xq = qf.q_scaled(x, k)
    data, step = qf.dec_int8(xq, k)
    assert data.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(data, np.float32) * float(step),
                               np.asarray(xq), atol=float(step) * 1.01)


@given(arrays())
def test_dec_error_flag_planes_disjoint_and_exact(x):
    planes = qf.dec_error(x, "flag8", 8)
    assert len(planes) == 2
    (hi, shi), (lo, slo) = planes
    assert bool(jnp.all((hi == 0) | (lo == 0)))      # disjoint support
    recon = hi.astype(jnp.float32) * shi + lo.astype(jnp.float32) * slo
    want = qf.flag_qe2(x, 8)
    np.testing.assert_allclose(np.asarray(recon), np.asarray(want),
                               rtol=1e-5, atol=1e-7)
