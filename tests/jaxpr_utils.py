"""Shared jaxpr-introspection helpers for the fused-kernel tests."""


def collect_outside_pallas(jaxpr, out):
    """Append (primitive name, out shape) for every eqn reachable from
    `jaxpr`, recursing through sub-jaxprs (pjit, custom_vjp, scan, ...) but
    NOT into pallas_call bodies — those record as ("pallas_call", None).

    The fused-kernel acceptance checks are phrased over this listing: a
    tensor-shaped round/clamp outside a pallas body is a standalone
    quantize pass; a dot_general outside one is an un-kerneled matmul.
    """
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            out.append(("pallas_call", None))
            continue
        subs = []
        for v in eqn.params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for vv in vs:
                if hasattr(vv, "eqns"):
                    subs.append(vv)
                elif hasattr(vv, "jaxpr") and hasattr(vv.jaxpr, "eqns"):
                    subs.append(vv.jaxpr)
        if subs:
            for sub in subs:
                collect_outside_pallas(sub, out)
        else:
            shp = eqn.outvars[0].aval.shape if eqn.outvars else ()
            out.append((eqn.primitive.name, shp))
