"""Shared jaxpr-introspection + numeric-tolerance helpers for the
fused-kernel tests."""
import numpy as np

# Budget for XLA FMA-contraction divergence: interpret-mode Pallas and an
# eagerly-structured oracle may contract the online-rescale mul+add chains
# differently, each contraction worth <= 1 ulp.  16 ulps of headroom covers
# the longest rescale chain in the flash kernel; it is a NAMED constant so
# a tolerance regression is a visible diff, not a silently widened rtol.
FMA_ULPS = 16


def assert_allclose_fma(want, got, ulps: int = FMA_ULPS):
    """allclose with an explicit FMA-contraction tolerance.

    The tolerance is `ulps` last-place units of the comparison's own peak
    magnitude — derived, not hand-tuned, so it cannot silently widen as the
    test suite evolves.  Use ONLY for kernel-vs-oracle compares whose
    divergence is program-structure FMA contraction; bit-exact contracts
    use assert_array_equal (see assert_bitwise_oracle).
    """
    want = np.asarray(want)
    got = np.asarray(got)
    scale = float(np.max(np.abs(want))) or 1.0
    atol = ulps * np.finfo(np.float32).eps * scale
    np.testing.assert_allclose(got, want, rtol=0.0, atol=atol)


def assert_bitwise_oracle(op_fn, ref_fn, *args, **kw):
    """The dispatched op on this (CPU) backend must BE the oracle, bitwise.

    Anchors the model-level route: whatever FMA tolerance the interpreted
    kernel compare needs, the path models actually execute on CPU stays
    bit-exact against the reference — so assert_allclose_fma can never
    silently widen into the numbers training/serving sees.
    """
    np.testing.assert_array_equal(np.asarray(op_fn(*args, **kw)),
                                  np.asarray(ref_fn(*args, **kw)))


def fresh_trace(fn, *args):
    """make_jaxpr through a throwaway wrapper, so the inspection trace
    never shares jax's tracing cache with a live jitted callable of `fn`.

    Tests retrace under patched dispatch (ops._on_tpu, fuse_kernels
    flips); a shared cache entry either hands back the stale pre-patch
    route or poisons the live callable with a route the real backend
    cannot compile (Engine.decode_jaxpr guards the same way internally).
    """
    import jax
    return jax.make_jaxpr(lambda *a: fn(*a))(*args)


def collect_outside_pallas(jaxpr, out):
    """Append (primitive name, out shape) for every eqn reachable from
    `jaxpr`, recursing through sub-jaxprs (pjit, custom_vjp, scan, ...) but
    NOT into pallas_call bodies — those record as ("pallas_call", None).

    The fused-kernel acceptance checks are phrased over this listing: a
    tensor-shaped round/clamp outside a pallas body is a standalone
    quantize pass; a dot_general outside one is an un-kerneled matmul.
    """
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            out.append(("pallas_call", None))
            continue
        subs = []
        for v in eqn.params.values():
            vs = v if isinstance(v, (tuple, list)) else (v,)
            for vv in vs:
                if hasattr(vv, "eqns"):
                    subs.append(vv)
                elif hasattr(vv, "jaxpr") and hasattr(vv.jaxpr, "eqns"):
                    subs.append(vv.jaxpr)
        if subs:
            for sub in subs:
                collect_outside_pallas(sub, out)
        else:
            shp = eqn.outvars[0].aval.shape if eqn.outvars else ()
            out.append((eqn.primitive.name, shp))
