"""Data pipeline: determinism, shard-slicing, learnability, prefetch."""
import numpy as np

from repro.data import TokenTask, ImageTask
from repro.data.synthetic import Prefetcher, host_local_slice


def test_determinism_across_restarts():
    t1 = TokenTask(vocab=97, seq_len=32, global_batch=8, seed=3)
    t2 = TokenTask(vocab=97, seq_len=32, global_batch=8, seed=3)
    a = t1.batch(step=5)
    b = t2.batch(step=5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_shards_partition_global_batch():
    t = TokenTask(vocab=97, seq_len=16, global_batch=8, seed=0,
                  kind="uniform")
    full = [t.batch(3, shard_idx=i, n_shards=4)["tokens"] for i in range(4)]
    assert all(f.shape == (2, 16) for f in full)
    # different shards differ (they are distinct slices)
    assert not np.array_equal(full[0], full[1])


def test_labels_are_shifted_targets():
    t = TokenTask(vocab=97, seq_len=16, global_batch=2)
    b = t.batch(0)
    # arith task: next = (3*prev + 5*prev2 + 7) % V
    tok, lab = b["tokens"], b["labels"]
    np.testing.assert_array_equal(tok[:, 1:], lab[:, :-1])
    want = (3 * tok[:, 2:] + 5 * tok[:, 1:-1] + 7) % 97
    np.testing.assert_array_equal(lab[:, 2:], want)


def test_image_task_learnable_structure():
    t = ImageTask(img_size=8, num_classes=4, global_batch=64, seed=0)
    b = t.batch(0)
    assert b["images"].shape == (64, 8, 8, 3)
    # same-class images correlate more than cross-class
    img = b["images"].reshape(64, -1)
    lab = b["labels"]
    same, diff = [], []
    for i in range(20):
        for j in range(i + 1, 20):
            c = float(np.dot(img[i], img[j]) /
                      (np.linalg.norm(img[i]) * np.linalg.norm(img[j])))
            (same if lab[i] == lab[j] else diff).append(c)
    if same and diff:
        assert np.mean(same) > np.mean(diff)


def test_host_local_slice():
    assert host_local_slice(256, 0, 32) == (0, 8)
    assert host_local_slice(256, 31, 32) == (248, 8)


def test_prefetcher_orders_steps():
    t = TokenTask(vocab=17, seq_len=4, global_batch=2)
    pf = Prefetcher(lambda s: t.batch(s), start_step=0, depth=2)
    s0, b0 = pf.get()
    s1, b1 = pf.get()
    pf.close()
    assert (s0, s1) == (0, 1)
    np.testing.assert_array_equal(b0["tokens"], t.batch(0)["tokens"])
