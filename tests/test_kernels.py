"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(requirement (c): per-kernel allclose against ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.backward import bwd_dgrad, bwd_wgrad
from repro.kernels.page_gather import page_gather
from repro.kernels.qmatmul import qmatmul
from repro.kernels.quantize import cq_stochastic, quantize_fused
from repro.kernels.selective_scan import selective_scan
from repro.kernels.ubn import ubn_norm


@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (128, 128, 128),
                                   (256, 512, 128), (100, 130, 70),
                                   (1, 256, 64), (37, 64, 129)])
@pytest.mark.parametrize("blocks", [(32, 32, 64), (128, 128, 128)])
def test_qmatmul_sweep(m, k, n, blocks):
    bm, bn, bk = blocks
    a = jax.random.randint(jax.random.PRNGKey(0), (m, k), -128, 128,
                           jnp.int8)
    b = jax.random.randint(jax.random.PRNGKey(1), (k, n), -128, 128,
                           jnp.int8)
    got = qmatmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.qmatmul_ref(a, b)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qmatmul_int32_accumulation_no_overflow_in_int8_domain():
    # worst case: K * 127 * 127 must accumulate exactly in int32
    k = 1024
    a = jnp.full((8, k), 127, jnp.int8)
    b = jnp.full((k, 8), 127, jnp.int8)
    got = qmatmul(a, b, interpret=True)
    assert int(got[0, 0]) == k * 127 * 127


@pytest.mark.parametrize("shape", [(16, 16), (100, 70), (256, 300), (1, 8)])
@pytest.mark.parametrize("inv", [128.0, 4.0, 1 / 64.0])
def test_quantize_sweep(shape, inv):
    x = jax.random.normal(jax.random.PRNGKey(0), shape) * 3
    got = quantize_fused(x, jnp.float32(inv), bm=64, bn=64, interpret=True)
    want = ref.quantize_ref(x, jnp.float32(inv), 127.0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", [(32, 32), (100, 70)])
@pytest.mark.parametrize("dr", [128.0, 64.0])
def test_cq_stochastic_sweep(shape, dr):
    x = jax.random.normal(jax.random.PRNGKey(0), shape)
    bits = jax.random.bits(jax.random.PRNGKey(1), shape, jnp.uint32)
    got = cq_stochastic(x, bits, jnp.float32(37.0), dr=dr, bm=64, bn=64,
                        interpret=True)
    want = ref.cq_stochastic_ref(x, bits, jnp.float32(37.0), dr)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b,s,d,n", [(1, 16, 8, 4), (2, 48, 24, 4),
                                     (2, 64, 32, 16), (1, 33, 10, 2)])
def test_selective_scan_sweep(b, s, d, n):
    k = jax.random.PRNGKey(0)
    a = jnp.exp(-jax.random.uniform(k, (b, s, d, n)))
    bb = jax.random.normal(jax.random.PRNGKey(1), (b, s, d, n)) * 0.1
    c = jax.random.normal(jax.random.PRNGKey(2), (b, s, n))
    got = selective_scan(a, bb, c, bd=8, bs=16, interpret=True)
    want = ref.selective_scan_ref(a, bb, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_selective_scan_long_dependency():
    """State must persist across seq blocks (VMEM scratch carry)."""
    b, s, d, n = 1, 64, 4, 2
    a = jnp.ones((b, s, d, n)) * 0.99
    bb = jnp.zeros((b, s, d, n)).at[:, 0].set(1.0)   # impulse at t=0
    c = jnp.ones((b, s, n))
    y = selective_scan(a, bb, c, bd=4, bs=8, interpret=True)
    # response at t is n * 0.99^t — nonzero far beyond the first block
    want = n * 0.99 ** jnp.arange(s)
    np.testing.assert_allclose(np.asarray(y[0, :, 0]), np.asarray(want),
                               rtol=1e-4)


@pytest.mark.parametrize("p,page,d,b,nb", [(8, 4, 16, 2, 3), (32, 8, 64, 4, 4),
                                           (5, 2, 8, 1, 5)])
def test_page_gather_sweep(p, page, d, b, nb):
    pages = jax.random.randint(jax.random.PRNGKey(0), (p, page, d),
                               -128, 128, jnp.int8)
    table = jax.random.randint(jax.random.PRNGKey(1), (b, nb), 0, p,
                               jnp.int32)
    got = page_gather(pages, table, interpret=True)
    want = ref.page_gather_ref(pages, table)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_page_gather_clamps_out_of_range():
    """Dead lanes carry id 0 / garbage ids; both must clamp, not wrap."""
    pages = jnp.arange(4 * 2 * 4, dtype=jnp.int8).reshape(4, 2, 4)
    table = jnp.asarray([[-3, 99]], jnp.int32)
    got = page_gather(pages, table, interpret=True)
    np.testing.assert_array_equal(np.asarray(got[0, 0]),
                                  np.asarray(pages[0]))
    np.testing.assert_array_equal(np.asarray(got[0, 1]),
                                  np.asarray(pages[3]))


def test_page_gather_op_dispatch_trailing_dims():
    from repro.kernels import ops
    pages = jax.random.randint(jax.random.PRNGKey(0), (6, 4, 2, 8),
                               -128, 128, jnp.int8)
    table = jax.random.randint(jax.random.PRNGKey(1), (3, 2), 0, 6,
                               jnp.int32)
    got = ops.page_gather_op(pages, table)
    assert got.shape == (3, 2, 4, 2, 8)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.page_gather_ref(pages, table)))
    got2 = ops.page_gather_op(pages, table, force_kernel=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))


def test_ops_dispatch_cpu_oracle():
    from repro.kernels import ops
    a = jax.random.randint(jax.random.PRNGKey(0), (16, 16), -128, 128,
                           jnp.int8)
    got = ops.qmatmul_op(a, a)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.qmatmul_ref(a, a)))
    got2 = ops.qmatmul_op(a, a, force_kernel=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))


# --------------------------------------------------------------------------
# fused requantize epilogue
# --------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [(16, 32, 16), (37, 70, 19),
                                   (128, 256, 64), (1, 17, 5)])
@pytest.mark.parametrize("inv", [2.0 ** -10, 2.0 ** -6, 2.0 ** -14])
def test_qmatmul_requant_sweep(m, k, n, inv):
    a = jax.random.randint(jax.random.PRNGKey(0), (m, k), -128, 128,
                           jnp.int8)
    b = jax.random.randint(jax.random.PRNGKey(1), (k, n), -128, 128,
                           jnp.int8)
    got = qmatmul(a, b, jnp.float32(inv), bm=32, bn=32, bk=64,
                  interpret=True)
    want = ref.qmatmul_requant_ref(a, b, jnp.float32(inv))
    assert got.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qmatmul_requant_saturates():
    a = jnp.full((8, 64), 127, jnp.int8)
    b = jnp.full((64, 8), 127, jnp.int8)
    got = qmatmul(a, b, jnp.float32(1.0), interpret=True)   # way over range
    assert int(got[0, 0]) == 127 and got.dtype == jnp.int8


# --------------------------------------------------------------------------
# fused-prologue backward kernels (dgrad / wgrad)
# --------------------------------------------------------------------------

_BWD_MODES = [("affine", 8), ("affine", 16), ("flag", 8)]


def _bwd_data(m, k, n, scale=0.3):
    g = jax.random.normal(jax.random.PRNGKey(2), (m, n)) * scale
    w8 = jax.random.randint(jax.random.PRNGKey(3), (k, n), -128, 128,
                            jnp.int8)
    a8 = jax.random.randint(jax.random.PRNGKey(4), (m, k), -128, 128,
                            jnp.int8)
    step = jnp.float32(2.0 ** -9)
    scal = jnp.stack([1.0 / step, step * 2.0 ** -7, step * 2.0 ** -14])
    return g, w8, a8, scal


@pytest.mark.parametrize("m,k,n", [(16, 32, 16), (37, 70, 19), (6, 32, 16),
                                   (128, 128, 128), (1, 13, 33)])
@pytest.mark.parametrize("mode,kb", _BWD_MODES)
def test_bwd_dgrad_sweep(m, k, n, mode, kb):
    g, w8, _, scal = _bwd_data(m, k, n)
    got = bwd_dgrad(g, w8, scal, mode=mode, k=kb, bm=32, bk=32, bn=16,
                    interpret=True)
    want = ref.dgrad_ref(g, w8, scal, mode=mode, k=kb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,k,n", [(16, 32, 16), (37, 70, 19), (6, 32, 16),
                                   (128, 128, 128), (1, 13, 33)])
@pytest.mark.parametrize("mode,kb", _BWD_MODES)
def test_bwd_wgrad_sweep(m, k, n, mode, kb):
    g, _, a8, scal = _bwd_data(m, k, n)
    got = bwd_wgrad(a8, g, scal, mode=mode, k=kb, bm=32, bk=32, bn=16,
                    interpret=True)
    want = ref.wgrad_ref(a8, g, scal, mode=mode, k=kb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("mode,kb", _BWD_MODES)
def test_bwd_prologue_matches_quantizer_payloads(mode, kb):
    """The kernels' in-prologue quantize must equal Quantizer.quantize —
    the contract that makes the fused route bit-exact vs the legacy path."""
    from repro.core.qtensor import get_quantizer
    g = jax.random.normal(jax.random.PRNGKey(5), (24, 40)) * 0.4
    name = "flag" if mode == "flag" else "sq"
    q = get_quantizer(name, kb)
    plan = q.fused_plan(g)
    assert plan is not None and plan[0] == mode
    steps = plan[1]
    planes = ref.bwd_error_planes_ref(g, 1.0 / steps[0], mode=mode, k=kb)
    want = q.quantize(g).planes()
    assert len(planes) == len(want)
    for got_p, (want_p, _) in zip(planes, want):
        np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))


def test_bwd_ops_dispatch():
    from repro.kernels import ops
    g, w8, a8, scal = _bwd_data(20, 24, 12)
    for mode, kb in _BWD_MODES:
        o = ops.dgrad_op(g, w8, scal, mode=mode, k=kb)
        ok = ops.dgrad_op(g, w8, scal, mode=mode, k=kb, force_kernel=True)
        np.testing.assert_array_equal(np.asarray(o), np.asarray(ok))
        o = ops.wgrad_op(a8, g, scal, mode=mode, k=kb)
        ok = ops.wgrad_op(a8, g, scal, mode=mode, k=kb, force_kernel=True)
        np.testing.assert_array_equal(np.asarray(o), np.asarray(ok))


# --------------------------------------------------------------------------
# fused UBN kernel
# --------------------------------------------------------------------------

_UBN_W = dict(k_mu=16, k_sigma=16, k_bn=16, k_gamma=8, k_beta=8,
              eps=2.0 ** -8)


@pytest.mark.parametrize("m,n", [(16, 32), (33, 48), (100, 24), (1, 8),
                                 (7, 130)])
@pytest.mark.parametrize("kind", ["rms", "layer", "batch"])
def test_ubn_sweep(m, n, kind):
    x = jax.random.normal(jax.random.PRNGKey(0), (m, n)) * 0.5
    gamma = jax.random.normal(jax.random.PRNGKey(1), (n,)) * 0.2 + 1.0
    beta = (None if kind == "rms"
            else jax.random.normal(jax.random.PRNGKey(2), (n,)) * 0.1)
    got = ubn_norm(x, gamma, beta, kind=kind, bt=16, interpret=True,
                   **_UBN_W)
    want = ref.ubn_norm_ref(x, gamma, beta, kind=kind, **_UBN_W)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ubn_zero_rows_no_nan():
    """Padded/degenerate rows (all zeros) must normalize to 0, not NaN."""
    x = jnp.zeros((5, 16))
    gamma = jnp.ones((16,))
    for kind in ("rms", "layer", "batch"):
        beta = None if kind == "rms" else jnp.zeros((16,))
        y = ubn_norm(x, gamma, beta, kind=kind, bt=8, interpret=True,
                     **_UBN_W)
        assert not bool(jnp.isnan(y).any())
        np.testing.assert_array_equal(np.asarray(y), 0.0)


def test_ubn_ops_dispatch():
    from repro.kernels import ops
    x = jax.random.normal(jax.random.PRNGKey(0), (12, 20)) * 0.5
    gamma = jnp.ones((20,))
    for kind in ("rms", "layer", "batch"):
        beta = None if kind == "rms" else jnp.zeros((20,))
        o = ops.ubn_norm_op(x, gamma, beta, kind=kind)
        ok = ops.ubn_norm_op(x, gamma, beta, kind=kind, force_kernel=True)
        np.testing.assert_array_equal(np.asarray(o), np.asarray(ok))


def test_dispatch_report_banner():
    from repro.core import preset
    from repro.kernels import ops
    rep = ops.dispatch_report(preset("full8", "native"))
    assert set(rep["ops"]) == set(ops.OPS) and len(ops.OPS) == 10
    assert {"paged_attention", "flash_attention"} <= set(rep["ops"])
    assert rep["fused"] is True and rep["mode"] == "native"
    rep2 = ops.dispatch_report(
        preset("full8", "native").replace(fuse_kernels=False))
    assert rep2["fused"] is False
    banner = ops.dispatch_banner(preset("full8", "native"))
    assert "backend=" in banner and "bwd/ubn=fused" in banner
    assert "attn=fused" in banner
    assert "route=" in ops.dispatch_banner()


# --------------------------------------------------------------------------
# fused paged decode attention / flash attention
# --------------------------------------------------------------------------


def _paged_case(p, page, kv, g, dh, b, nb, seed=0):
    """Pages + a table exercising dead lanes (trash page 0), multi-page
    contexts crossing page boundaries, and ragged last pages."""
    r = np.random.default_rng(seed)
    kp = jnp.asarray(r.integers(-127, 128, (p, page, kv, dh)), jnp.int8)
    vp = jnp.asarray(r.integers(-127, 128, (p, page, kv, dh)), jnp.int8)
    q8 = jnp.asarray(r.integers(-127, 128, (b, kv * g, dh)), jnp.int8)
    table = np.zeros((b, nb), np.int32)
    q_pos = np.zeros((b,), np.int32)
    ids = list(range(1, p))
    for lane in range(1, b):                 # lane 0 stays dead
        n_blk = 1 + (lane % nb)
        take, ids = ids[:n_blk], ids[n_blk:] + ids[:n_blk]
        table[lane, :n_blk] = take
        q_pos[lane] = n_blk * page - 1 - (lane % page)   # ragged last page
    t_valid = int(q_pos.max()) + 1
    return q8, kp, vp, jnp.asarray(table), jnp.asarray(q_pos), t_valid


@pytest.mark.parametrize("p,page,kv,g,dh,b,nb", [
    (9, 4, 1, 1, 8, 2, 2),        # minimal
    (9, 4, 2, 2, 8, 3, 4),        # GQA, multi-page
    (17, 8, 2, 4, 16, 4, 3),      # wider GQA groups, bigger pages
    (9, 4, 4, 1, 8, 2, 2),        # MHA (g == 1)
    (5, 4, 2, 2, 8, 1, 1),        # single grid cell; every lane dead
])
def test_paged_attention_kernel_sweep(p, page, kv, g, dh, b, nb):
    from repro.kernels.paged_attention import paged_attention
    q8, kp, vp, table, q_pos, t_valid = _paged_case(p, page, kv, g, dh,
                                                    b, nb)
    scal = (jnp.float32(2 ** -6), jnp.float32(2 ** -7), jnp.float32(2 ** -7))
    sm = 1.0 / float(np.sqrt(dh))
    want = ref.paged_attention_ref(q8, kp, vp, table, q_pos, t_valid, *scal,
                                   sm_scale=sm)
    got = paged_attention(q8, kp, vp, table, q_pos, t_valid, *scal,
                          sm_scale=sm, interpret=True)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_paged_attention_op_dispatch():
    from repro.kernels import ops
    q8, kp, vp, table, q_pos, t_valid = _paged_case(9, 4, 2, 2, 8, 3, 4)
    scal = (jnp.float32(2 ** -6), jnp.float32(2 ** -7), jnp.float32(2 ** -7))
    sm = 1.0 / float(np.sqrt(8))
    o = ops.paged_attention_op(q8, kp, vp, table, q_pos, t_valid, *scal,
                               sm_scale=sm)
    ok = ops.paged_attention_op(q8, kp, vp, table, q_pos, t_valid, *scal,
                                sm_scale=sm, force_kernel=True)
    np.testing.assert_array_equal(np.asarray(o), np.asarray(ok))
    assert o.shape == (3, 4, 8) and o.dtype == jnp.float32


def test_paged_attention_out_of_range_table_clamps():
    from repro.kernels.paged_attention import paged_attention
    q8, kp, vp, table, q_pos, t_valid = _paged_case(9, 4, 2, 1, 8, 2, 2)
    bad = table.at[1, 0].set(99)          # clamps to the last page
    scal = (jnp.float32(2 ** -6), jnp.float32(2 ** -7), jnp.float32(2 ** -7))
    sm = 1.0 / float(np.sqrt(8))
    want = ref.paged_attention_ref(q8, kp, vp, bad, q_pos, t_valid, *scal,
                                   sm_scale=sm)
    got = paged_attention(q8, kp, vp, bad, q_pos, t_valid, *scal,
                          sm_scale=sm, interpret=True)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_paged_decode_attention_fused_bitexact_vs_gather_route():
    """The model-layer gate: fused streaming route == the page_gather +
    decode_attention composition, bit for bit (same qact epilogue)."""
    from repro.core import preset
    from repro.core.qtensor import QTensor, qt_carrier
    from repro.models import layers as L
    q8, kp, vp, table, q_pos, t_valid = _paged_case(9, 4, 2, 2, 8, 3, 4)
    b, h, dh = q8.shape
    qt = QTensor(q8.reshape(b, 1, h, dh), jnp.float32(2 ** -6), 8,
                 carrier=None)
    qt = qt.with_carrier()
    ks, vs = jnp.float32(2 ** -7), jnp.float32(2 ** -7)

    def run(fused):
        cfg = preset("full8", "native").replace(fuse_kernels=fused)
        out = L.paged_decode_attention(cfg, qt, kp, vp, table, ks, vs,
                                       q_pos=q_pos,
                                       t_valid=jnp.int32(t_valid))
        return np.asarray(qt_carrier(out))

    np.testing.assert_array_equal(run(True), run(False))


@pytest.mark.parametrize("b,s,kv,g,dh,qc,kc", [
    (1, 8, 1, 1, 8, 4, 4),
    (2, 13, 2, 3, 8, 4, 4),       # ragged + GQA
    (2, 16, 2, 2, 16, 8, 4),      # uneven tile sizes
])
def test_flash_attention_kernel_sweep(b, s, kv, g, dh, qc, kc):
    """Kernel vs oracle on payload inputs.  The comparison is
    assert_allclose_fma (an explicit, ULP-derived FMA-contraction budget —
    jaxpr_utils.FMA_ULPS), never a hand-widened rtol: the online-rescale
    mul+add chains are subject to XLA FMA contraction, which interpret-mode
    Pallas and the eagerly-structured oracle may apply differently.  The
    CPU-dispatched route models actually execute is anchored BITWISE to the
    oracle in the same sweep, so the tolerance cannot leak into model
    numbers."""
    from jaxpr_utils import assert_allclose_fma, assert_bitwise_oracle
    from repro.kernels.ops import flash_attention_op
    from repro.kernels.paged_attention import flash_attention
    r = np.random.default_rng(3)
    h = kv * g
    q8 = jnp.asarray(r.integers(-127, 128, (b, s, h, dh)), jnp.int8)
    k8 = jnp.asarray(r.integers(-127, 128, (b, s, kv, dh)), jnp.int8)
    v8 = jnp.asarray(r.integers(-127, 128, (b, s, kv, dh)), jnp.int8)
    sp, tp = -s % qc, -s % kc
    q8 = jnp.pad(q8, ((0, 0), (0, sp), (0, 0), (0, 0)))
    k8 = jnp.pad(k8, ((0, 0), (0, tp), (0, 0), (0, 0)))
    v8 = jnp.pad(v8, ((0, 0), (0, tp), (0, 0), (0, 0)))
    pos = jnp.arange(s)
    qp, kp = jnp.pad(pos, (0, sp)), jnp.pad(pos, (0, tp))
    kval = jnp.pad(jnp.ones((s,), jnp.int32), (0, tp))
    scal = (jnp.float32(2 ** -7),) * 3
    kw = dict(causal=True, sm_scale=1.0 / float(np.sqrt(dh)), q_chunk=qc,
              kv_chunk=kc)
    want = ref.flash_attention_ref(q8, k8, v8, qp, kp, kval, *scal, **kw)
    got = flash_attention(q8, k8, v8, qp, kp, kval, *scal, **kw,
                          interpret=True)
    assert_allclose_fma(want, got)
    # the dispatched (CPU -> oracle) path IS the reference, bit for bit
    assert_bitwise_oracle(flash_attention_op, ref.flash_attention_ref,
                          q8, k8, v8, qp, kp, kval, *scal, **kw)


def test_flash_attention_noncausal_matches_ref():
    from jaxpr_utils import assert_allclose_fma, assert_bitwise_oracle
    from repro.kernels.ops import flash_attention_op
    from repro.kernels.paged_attention import flash_attention
    r = np.random.default_rng(5)
    b, s, kv, g, dh = 2, 8, 2, 1, 8
    q8 = jnp.asarray(r.integers(-127, 128, (b, s, kv * g, dh)), jnp.int8)
    k8 = jnp.asarray(r.integers(-127, 128, (b, s, kv, dh)), jnp.int8)
    v8 = jnp.asarray(r.integers(-127, 128, (b, s, kv, dh)), jnp.int8)
    pos = jnp.arange(s)
    kval = jnp.ones((s,), jnp.int32)
    scal = (jnp.float32(2 ** -7),) * 3
    kw = dict(causal=False, sm_scale=1.0 / float(np.sqrt(dh)), q_chunk=4,
              kv_chunk=4)
    want = ref.flash_attention_ref(q8, k8, v8, pos, pos, kval, *scal, **kw)
    got = flash_attention(q8, k8, v8, pos, pos, kval, *scal, **kw,
                          interpret=True)
    assert_allclose_fma(want, got)
    assert_bitwise_oracle(flash_attention_op, ref.flash_attention_ref,
                          q8, k8, v8, pos, pos, kval, *scal, **kw)


def test_chunked_attention_fused_bitexact_and_grads():
    """Fused flash forward == unfused pure-JAX chunked path bitwise (under
    jit, the way models run it); gradients agree because the fused bwd IS
    the vjp of the unfused body."""
    from repro.core import preset, qact
    from repro.core.qtensor import qt_carrier
    from repro.models import layers as L
    r = np.random.default_rng(7)
    b, s, kv, g, dh = 2, 13, 2, 3, 8
    h = kv * g
    x = jnp.asarray(r.normal(size=(b, s, h, dh)), jnp.float32) * 0.3
    kx = jnp.asarray(r.normal(size=(b, s, kv, dh)), jnp.float32) * 0.3
    vx = jnp.asarray(r.normal(size=(b, s, kv, dh)), jnp.float32) * 0.3
    pos = jnp.arange(s)

    def run(fused, inputs):
        cfg = preset("full8", "native").replace(fuse_kernels=fused)

        def f(x, kx, vx):
            q, k, v = (qact(cfg, "none", t) for t in (x, kx, vx))
            out = L.chunked_attention(cfg, q, k, v, causal=True, q_pos=pos,
                                      k_pos=pos, q_chunk=4, kv_chunk=4)
            return jnp.sum(qt_carrier(out) ** 2)

        val, grads = jax.jit(jax.value_and_grad(f, argnums=(0, 1, 2)))(
            *inputs)
        return val, grads

    vf, gf = run(True, (x, kx, vx))
    vu, gu = run(False, (x, kx, vx))
    assert np.asarray(vf) == np.asarray(vu)
    for a, b_ in zip(gf, gu):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-6, atol=1e-7)


def test_fused_decode_jaxpr_streams_pages():
    """Acceptance: with the kernel dispatch forced, the fused decode trace
    contains NO standalone page-gather result and NO dense (B, T, ...) KV
    intermediate outside a pallas body — the gathered cache never exists.
    The unfused trace (contrast) does contain it."""
    from repro.core import preset
    from repro.core.qtensor import QTensor
    from repro.kernels import ops
    from repro.models import layers as L
    q8, kp, vp, table, q_pos, t_valid = _paged_case(9, 4, 2, 2, 8, 3, 4)
    b, h, dh = q8.shape
    page, kv = kp.shape[1], kp.shape[2]
    nb = table.shape[1]
    qt = QTensor(q8.reshape(b, 1, h, dh), jnp.float32(2 ** -6), 8)
    qt = qt.with_carrier()
    ks, vs = jnp.float32(2 ** -7), jnp.float32(2 ** -7)
    dense = {(b, nb, page, kv, dh), (b, nb * page, kv, dh)}

    def trace(fused):
        from jaxpr_utils import fresh_trace
        cfg = preset("full8", "native").replace(fuse_kernels=fused)
        orig = ops._on_tpu
        ops._on_tpu = lambda: True
        try:
            # fresh_trace: retracing under the patched _on_tpu must not
            # share a cache entry with the unpatched route
            return fresh_trace(
                lambda q: L.paged_decode_attention(
                    cfg, q, kp, vp, table, ks, vs, q_pos=q_pos,
                    t_valid=jnp.int32(t_valid)), qt)
        finally:
            ops._on_tpu = orig

    def dense_kv(jaxpr):
        return [e for e in ops.eqns_outside_pallas(jaxpr.jaxpr)
                if e[1] in dense and e[2] == jnp.int8]

    fused = trace(True)
    assert not dense_kv(fused)
    assert sum(e[0] == "pallas_call"
               for e in ops.eqns_outside_pallas(fused.jaxpr)) >= 2  # 2 passes
    assert dense_kv(trace(False))       # contrast: gather route has it
