"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(requirement (c): per-kernel allclose against ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.backward import bwd_dgrad, bwd_wgrad
from repro.kernels.page_gather import page_gather
from repro.kernels.qmatmul import qmatmul
from repro.kernels.quantize import cq_stochastic, quantize_fused
from repro.kernels.selective_scan import selective_scan
from repro.kernels.ubn import ubn_norm


@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (128, 128, 128),
                                   (256, 512, 128), (100, 130, 70),
                                   (1, 256, 64), (37, 64, 129)])
@pytest.mark.parametrize("blocks", [(32, 32, 64), (128, 128, 128)])
def test_qmatmul_sweep(m, k, n, blocks):
    bm, bn, bk = blocks
    a = jax.random.randint(jax.random.PRNGKey(0), (m, k), -128, 128,
                           jnp.int8)
    b = jax.random.randint(jax.random.PRNGKey(1), (k, n), -128, 128,
                           jnp.int8)
    got = qmatmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.qmatmul_ref(a, b)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qmatmul_int32_accumulation_no_overflow_in_int8_domain():
    # worst case: K * 127 * 127 must accumulate exactly in int32
    k = 1024
    a = jnp.full((8, k), 127, jnp.int8)
    b = jnp.full((k, 8), 127, jnp.int8)
    got = qmatmul(a, b, interpret=True)
    assert int(got[0, 0]) == k * 127 * 127


@pytest.mark.parametrize("shape", [(16, 16), (100, 70), (256, 300), (1, 8)])
@pytest.mark.parametrize("inv", [128.0, 4.0, 1 / 64.0])
def test_quantize_sweep(shape, inv):
    x = jax.random.normal(jax.random.PRNGKey(0), shape) * 3
    got = quantize_fused(x, jnp.float32(inv), bm=64, bn=64, interpret=True)
    want = ref.quantize_ref(x, jnp.float32(inv), 127.0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", [(32, 32), (100, 70)])
@pytest.mark.parametrize("dr", [128.0, 64.0])
def test_cq_stochastic_sweep(shape, dr):
    x = jax.random.normal(jax.random.PRNGKey(0), shape)
    bits = jax.random.bits(jax.random.PRNGKey(1), shape, jnp.uint32)
    got = cq_stochastic(x, bits, jnp.float32(37.0), dr=dr, bm=64, bn=64,
                        interpret=True)
    want = ref.cq_stochastic_ref(x, bits, jnp.float32(37.0), dr)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b,s,d,n", [(1, 16, 8, 4), (2, 48, 24, 4),
                                     (2, 64, 32, 16), (1, 33, 10, 2)])
def test_selective_scan_sweep(b, s, d, n):
    k = jax.random.PRNGKey(0)
    a = jnp.exp(-jax.random.uniform(k, (b, s, d, n)))
    bb = jax.random.normal(jax.random.PRNGKey(1), (b, s, d, n)) * 0.1
    c = jax.random.normal(jax.random.PRNGKey(2), (b, s, n))
    got = selective_scan(a, bb, c, bd=8, bs=16, interpret=True)
    want = ref.selective_scan_ref(a, bb, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_selective_scan_long_dependency():
    """State must persist across seq blocks (VMEM scratch carry)."""
    b, s, d, n = 1, 64, 4, 2
    a = jnp.ones((b, s, d, n)) * 0.99
    bb = jnp.zeros((b, s, d, n)).at[:, 0].set(1.0)   # impulse at t=0
    c = jnp.ones((b, s, n))
    y = selective_scan(a, bb, c, bd=4, bs=8, interpret=True)
    # response at t is n * 0.99^t — nonzero far beyond the first block
    want = n * 0.99 ** jnp.arange(s)
    np.testing.assert_allclose(np.asarray(y[0, :, 0]), np.asarray(want),
                               rtol=1e-4)


@pytest.mark.parametrize("p,page,d,b,nb", [(8, 4, 16, 2, 3), (32, 8, 64, 4, 4),
                                           (5, 2, 8, 1, 5)])
def test_page_gather_sweep(p, page, d, b, nb):
    pages = jax.random.randint(jax.random.PRNGKey(0), (p, page, d),
                               -128, 128, jnp.int8)
    table = jax.random.randint(jax.random.PRNGKey(1), (b, nb), 0, p,
                               jnp.int32)
    got = page_gather(pages, table, interpret=True)
    want = ref.page_gather_ref(pages, table)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_page_gather_clamps_out_of_range():
    """Dead lanes carry id 0 / garbage ids; both must clamp, not wrap."""
    pages = jnp.arange(4 * 2 * 4, dtype=jnp.int8).reshape(4, 2, 4)
    table = jnp.asarray([[-3, 99]], jnp.int32)
    got = page_gather(pages, table, interpret=True)
    np.testing.assert_array_equal(np.asarray(got[0, 0]),
                                  np.asarray(pages[0]))
    np.testing.assert_array_equal(np.asarray(got[0, 1]),
                                  np.asarray(pages[3]))


def test_page_gather_op_dispatch_trailing_dims():
    from repro.kernels import ops
    pages = jax.random.randint(jax.random.PRNGKey(0), (6, 4, 2, 8),
                               -128, 128, jnp.int8)
    table = jax.random.randint(jax.random.PRNGKey(1), (3, 2), 0, 6,
                               jnp.int32)
    got = ops.page_gather_op(pages, table)
    assert got.shape == (3, 2, 4, 2, 8)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.page_gather_ref(pages, table)))
    got2 = ops.page_gather_op(pages, table, force_kernel=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))


def test_ops_dispatch_cpu_oracle():
    from repro.kernels import ops
    a = jax.random.randint(jax.random.PRNGKey(0), (16, 16), -128, 128,
                           jnp.int8)
    got = ops.qmatmul_op(a, a)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.qmatmul_ref(a, a)))
    got2 = ops.qmatmul_op(a, a, force_kernel=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))


# --------------------------------------------------------------------------
# fused requantize epilogue
# --------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [(16, 32, 16), (37, 70, 19),
                                   (128, 256, 64), (1, 17, 5)])
@pytest.mark.parametrize("inv", [2.0 ** -10, 2.0 ** -6, 2.0 ** -14])
def test_qmatmul_requant_sweep(m, k, n, inv):
    a = jax.random.randint(jax.random.PRNGKey(0), (m, k), -128, 128,
                           jnp.int8)
    b = jax.random.randint(jax.random.PRNGKey(1), (k, n), -128, 128,
                           jnp.int8)
    got = qmatmul(a, b, jnp.float32(inv), bm=32, bn=32, bk=64,
                  interpret=True)
    want = ref.qmatmul_requant_ref(a, b, jnp.float32(inv))
    assert got.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qmatmul_requant_saturates():
    a = jnp.full((8, 64), 127, jnp.int8)
    b = jnp.full((64, 8), 127, jnp.int8)
    got = qmatmul(a, b, jnp.float32(1.0), interpret=True)   # way over range
    assert int(got[0, 0]) == 127 and got.dtype == jnp.int8


# --------------------------------------------------------------------------
# fused-prologue backward kernels (dgrad / wgrad)
# --------------------------------------------------------------------------

_BWD_MODES = [("affine", 8), ("affine", 16), ("flag", 8)]


def _bwd_data(m, k, n, scale=0.3):
    g = jax.random.normal(jax.random.PRNGKey(2), (m, n)) * scale
    w8 = jax.random.randint(jax.random.PRNGKey(3), (k, n), -128, 128,
                            jnp.int8)
    a8 = jax.random.randint(jax.random.PRNGKey(4), (m, k), -128, 128,
                            jnp.int8)
    step = jnp.float32(2.0 ** -9)
    scal = jnp.stack([1.0 / step, step * 2.0 ** -7, step * 2.0 ** -14])
    return g, w8, a8, scal


@pytest.mark.parametrize("m,k,n", [(16, 32, 16), (37, 70, 19), (6, 32, 16),
                                   (128, 128, 128), (1, 13, 33)])
@pytest.mark.parametrize("mode,kb", _BWD_MODES)
def test_bwd_dgrad_sweep(m, k, n, mode, kb):
    g, w8, _, scal = _bwd_data(m, k, n)
    got = bwd_dgrad(g, w8, scal, mode=mode, k=kb, bm=32, bk=32, bn=16,
                    interpret=True)
    want = ref.dgrad_ref(g, w8, scal, mode=mode, k=kb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,k,n", [(16, 32, 16), (37, 70, 19), (6, 32, 16),
                                   (128, 128, 128), (1, 13, 33)])
@pytest.mark.parametrize("mode,kb", _BWD_MODES)
def test_bwd_wgrad_sweep(m, k, n, mode, kb):
    g, _, a8, scal = _bwd_data(m, k, n)
    got = bwd_wgrad(a8, g, scal, mode=mode, k=kb, bm=32, bk=32, bn=16,
                    interpret=True)
    want = ref.wgrad_ref(a8, g, scal, mode=mode, k=kb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("mode,kb", _BWD_MODES)
def test_bwd_prologue_matches_quantizer_payloads(mode, kb):
    """The kernels' in-prologue quantize must equal Quantizer.quantize —
    the contract that makes the fused route bit-exact vs the legacy path."""
    from repro.core.qtensor import get_quantizer
    g = jax.random.normal(jax.random.PRNGKey(5), (24, 40)) * 0.4
    name = "flag" if mode == "flag" else "sq"
    q = get_quantizer(name, kb)
    plan = q.fused_plan(g)
    assert plan is not None and plan[0] == mode
    steps = plan[1]
    planes = ref.bwd_error_planes_ref(g, 1.0 / steps[0], mode=mode, k=kb)
    want = q.quantize(g).planes()
    assert len(planes) == len(want)
    for got_p, (want_p, _) in zip(planes, want):
        np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))


def test_bwd_ops_dispatch():
    from repro.kernels import ops
    g, w8, a8, scal = _bwd_data(20, 24, 12)
    for mode, kb in _BWD_MODES:
        o = ops.dgrad_op(g, w8, scal, mode=mode, k=kb)
        ok = ops.dgrad_op(g, w8, scal, mode=mode, k=kb, force_kernel=True)
        np.testing.assert_array_equal(np.asarray(o), np.asarray(ok))
        o = ops.wgrad_op(a8, g, scal, mode=mode, k=kb)
        ok = ops.wgrad_op(a8, g, scal, mode=mode, k=kb, force_kernel=True)
        np.testing.assert_array_equal(np.asarray(o), np.asarray(ok))


# --------------------------------------------------------------------------
# fused UBN kernel
# --------------------------------------------------------------------------

_UBN_W = dict(k_mu=16, k_sigma=16, k_bn=16, k_gamma=8, k_beta=8,
              eps=2.0 ** -8)


@pytest.mark.parametrize("m,n", [(16, 32), (33, 48), (100, 24), (1, 8),
                                 (7, 130)])
@pytest.mark.parametrize("kind", ["rms", "layer", "batch"])
def test_ubn_sweep(m, n, kind):
    x = jax.random.normal(jax.random.PRNGKey(0), (m, n)) * 0.5
    gamma = jax.random.normal(jax.random.PRNGKey(1), (n,)) * 0.2 + 1.0
    beta = (None if kind == "rms"
            else jax.random.normal(jax.random.PRNGKey(2), (n,)) * 0.1)
    got = ubn_norm(x, gamma, beta, kind=kind, bt=16, interpret=True,
                   **_UBN_W)
    want = ref.ubn_norm_ref(x, gamma, beta, kind=kind, **_UBN_W)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ubn_zero_rows_no_nan():
    """Padded/degenerate rows (all zeros) must normalize to 0, not NaN."""
    x = jnp.zeros((5, 16))
    gamma = jnp.ones((16,))
    for kind in ("rms", "layer", "batch"):
        beta = None if kind == "rms" else jnp.zeros((16,))
        y = ubn_norm(x, gamma, beta, kind=kind, bt=8, interpret=True,
                     **_UBN_W)
        assert not bool(jnp.isnan(y).any())
        np.testing.assert_array_equal(np.asarray(y), 0.0)


def test_ubn_ops_dispatch():
    from repro.kernels import ops
    x = jax.random.normal(jax.random.PRNGKey(0), (12, 20)) * 0.5
    gamma = jnp.ones((20,))
    for kind in ("rms", "layer", "batch"):
        beta = None if kind == "rms" else jnp.zeros((20,))
        o = ops.ubn_norm_op(x, gamma, beta, kind=kind)
        ok = ops.ubn_norm_op(x, gamma, beta, kind=kind, force_kernel=True)
        np.testing.assert_array_equal(np.asarray(o), np.asarray(ok))


def test_dispatch_report_banner():
    from repro.core import preset
    from repro.kernels import ops
    rep = ops.dispatch_report(preset("full8", "native"))
    assert set(rep["ops"]) == set(ops.OPS) and len(ops.OPS) == 8
    assert rep["fused"] is True and rep["mode"] == "native"
    rep2 = ops.dispatch_report(
        preset("full8", "native").replace(fuse_kernels=False))
    assert rep2["fused"] is False
    banner = ops.dispatch_banner(preset("full8", "native"))
    assert "backend=" in banner and "bwd/ubn=fused" in banner
    assert "route=" in ops.dispatch_banner()
