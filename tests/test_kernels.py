"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(requirement (c): per-kernel allclose against ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.page_gather import page_gather
from repro.kernels.qmatmul import qmatmul
from repro.kernels.quantize import cq_stochastic, quantize_fused
from repro.kernels.selective_scan import selective_scan


@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (128, 128, 128),
                                   (256, 512, 128), (100, 130, 70),
                                   (1, 256, 64), (37, 64, 129)])
@pytest.mark.parametrize("blocks", [(32, 32, 64), (128, 128, 128)])
def test_qmatmul_sweep(m, k, n, blocks):
    bm, bn, bk = blocks
    a = jax.random.randint(jax.random.PRNGKey(0), (m, k), -128, 128,
                           jnp.int8)
    b = jax.random.randint(jax.random.PRNGKey(1), (k, n), -128, 128,
                           jnp.int8)
    got = qmatmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.qmatmul_ref(a, b)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qmatmul_int32_accumulation_no_overflow_in_int8_domain():
    # worst case: K * 127 * 127 must accumulate exactly in int32
    k = 1024
    a = jnp.full((8, k), 127, jnp.int8)
    b = jnp.full((k, 8), 127, jnp.int8)
    got = qmatmul(a, b, interpret=True)
    assert int(got[0, 0]) == k * 127 * 127


@pytest.mark.parametrize("shape", [(16, 16), (100, 70), (256, 300), (1, 8)])
@pytest.mark.parametrize("inv", [128.0, 4.0, 1 / 64.0])
def test_quantize_sweep(shape, inv):
    x = jax.random.normal(jax.random.PRNGKey(0), shape) * 3
    got = quantize_fused(x, jnp.float32(inv), bm=64, bn=64, interpret=True)
    want = ref.quantize_ref(x, jnp.float32(inv), 127.0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", [(32, 32), (100, 70)])
@pytest.mark.parametrize("dr", [128.0, 64.0])
def test_cq_stochastic_sweep(shape, dr):
    x = jax.random.normal(jax.random.PRNGKey(0), shape)
    bits = jax.random.bits(jax.random.PRNGKey(1), shape, jnp.uint32)
    got = cq_stochastic(x, bits, jnp.float32(37.0), dr=dr, bm=64, bn=64,
                        interpret=True)
    want = ref.cq_stochastic_ref(x, bits, jnp.float32(37.0), dr)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b,s,d,n", [(1, 16, 8, 4), (2, 48, 24, 4),
                                     (2, 64, 32, 16), (1, 33, 10, 2)])
def test_selective_scan_sweep(b, s, d, n):
    k = jax.random.PRNGKey(0)
    a = jnp.exp(-jax.random.uniform(k, (b, s, d, n)))
    bb = jax.random.normal(jax.random.PRNGKey(1), (b, s, d, n)) * 0.1
    c = jax.random.normal(jax.random.PRNGKey(2), (b, s, n))
    got = selective_scan(a, bb, c, bd=8, bs=16, interpret=True)
    want = ref.selective_scan_ref(a, bb, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_selective_scan_long_dependency():
    """State must persist across seq blocks (VMEM scratch carry)."""
    b, s, d, n = 1, 64, 4, 2
    a = jnp.ones((b, s, d, n)) * 0.99
    bb = jnp.zeros((b, s, d, n)).at[:, 0].set(1.0)   # impulse at t=0
    c = jnp.ones((b, s, n))
    y = selective_scan(a, bb, c, bd=4, bs=8, interpret=True)
    # response at t is n * 0.99^t — nonzero far beyond the first block
    want = n * 0.99 ** jnp.arange(s)
    np.testing.assert_allclose(np.asarray(y[0, :, 0]), np.asarray(want),
                               rtol=1e-4)


@pytest.mark.parametrize("p,page,d,b,nb", [(8, 4, 16, 2, 3), (32, 8, 64, 4, 4),
                                           (5, 2, 8, 1, 5)])
def test_page_gather_sweep(p, page, d, b, nb):
    pages = jax.random.randint(jax.random.PRNGKey(0), (p, page, d),
                               -128, 128, jnp.int8)
    table = jax.random.randint(jax.random.PRNGKey(1), (b, nb), 0, p,
                               jnp.int32)
    got = page_gather(pages, table, interpret=True)
    want = ref.page_gather_ref(pages, table)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_page_gather_clamps_out_of_range():
    """Dead lanes carry id 0 / garbage ids; both must clamp, not wrap."""
    pages = jnp.arange(4 * 2 * 4, dtype=jnp.int8).reshape(4, 2, 4)
    table = jnp.asarray([[-3, 99]], jnp.int32)
    got = page_gather(pages, table, interpret=True)
    np.testing.assert_array_equal(np.asarray(got[0, 0]),
                                  np.asarray(pages[0]))
    np.testing.assert_array_equal(np.asarray(got[0, 1]),
                                  np.asarray(pages[3]))


def test_page_gather_op_dispatch_trailing_dims():
    from repro.kernels import ops
    pages = jax.random.randint(jax.random.PRNGKey(0), (6, 4, 2, 8),
                               -128, 128, jnp.int8)
    table = jax.random.randint(jax.random.PRNGKey(1), (3, 2), 0, 6,
                               jnp.int32)
    got = ops.page_gather_op(pages, table)
    assert got.shape == (3, 2, 4, 2, 8)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.page_gather_ref(pages, table)))
    got2 = ops.page_gather_op(pages, table, force_kernel=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))


def test_ops_dispatch_cpu_oracle():
    from repro.kernels import ops
    a = jax.random.randint(jax.random.PRNGKey(0), (16, 16), -128, 128,
                           jnp.int8)
    got = ops.qmatmul_op(a, a)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.qmatmul_ref(a, a)))
    got2 = ops.qmatmul_op(a, a, force_kernel=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))
